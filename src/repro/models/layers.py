"""Shared transformer building blocks: norms, RoPE, GQA attention, GLU MLPs,
and MoE (top-k routed experts with capacity dispatch, optional shared expert).

Pure-JAX (no flax): params are nested dicts of jnp arrays, apply functions
are free functions. Layer-stacked variants (leading L dim on every param)
feed ``jax.lax.scan`` in the decoder (`repro.models.transformer`).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden
    capacity_factor: float = 1.25
    shared_expert: bool = False  # dense residual branch (Arctic / Llama-4)
    group_size: int = 512        # GShard dispatch group (tokens per group)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    activation: str = "swiglu"  # swiglu | geglu
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    scale_embed: bool = False   # gemma-style sqrt(d_model) embedding scale
    dtype: jnp.dtype = jnp.bfloat16
    # Training-time knobs
    remat_policy: str = "full"  # none | full | dots
    loss_chunk: int = 512       # sequence-chunked cross entropy
    # Serving-time knobs
    use_flash_kernel: bool = False        # Pallas path (TPU target)
    attn_impl: Optional[str] = None        # None=auto | full | chunked
    decode_attn_impl: Optional[str] = None # None=auto | full | chunked
    # Cost-probe knobs (launch/dryrun.py): scan bodies are unrolled inside a
    # trip-1 loop so cost_analysis() counts every layer exactly once.
    scan_unroll: int = 1
    flash_block: Optional[int] = None      # force flash KV block size

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def n_params(self) -> int:
        """Total parameter count (for MODEL_FLOPS = 6·N·D roofline term)."""
        c = self
        emb = c.vocab * c.d_model
        attn = c.d_model * (c.qkv_dim + 2 * c.kv_dim) + c.qkv_dim * c.d_model
        if c.moe is None:
            mlp = 3 * c.d_model * c.d_ff
        else:
            mlp = c.moe.n_experts * 3 * c.d_model * c.moe.d_ff
            mlp += c.d_model * c.moe.n_experts  # router
            if c.moe.shared_expert:
                mlp += 3 * c.d_model * c.d_ff
        norms = 2 * c.d_model
        per_layer = attn + mlp + norms
        head = 0 if c.tie_embeddings else c.d_model * c.vocab
        return emb + c.n_layers * per_layer + c.d_model + head

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        c = self
        if c.moe is None:
            return self.n_params
        emb = c.vocab * c.d_model
        attn = c.d_model * (c.qkv_dim + 2 * c.kv_dim) + c.qkv_dim * c.d_model
        mlp = c.moe.top_k * 3 * c.d_model * c.moe.d_ff + c.d_model * c.moe.n_experts
        if c.moe.shared_expert:
            mlp += 3 * c.d_model * c.d_ff
        head = 0 if c.tie_embeddings else c.d_model * c.vocab
        return emb + c.n_layers * (attn + mlp + 2 * c.d_model) + c.d_model + head


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm in fp32 accumulation, cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype=jnp.bfloat16) -> jax.Array:
    # Stored as delta from 1.0 (gemma convention); rms_norm adds 1.
    return jnp.zeros((d,), dtype=dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies [head_dim//2] (fp32)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [B, S, N, Dh]; positions: [B, S] or [S]."""
    inv_freq = rope_frequencies(x.shape[-1], theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * inv_freq  # [B,S,Dh/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------


def init_attention(key: jax.Array, cfg: LMConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.qkv_dim, cfg.kv_dim
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, qd)) * s).astype(cfg.dtype),
        "wk": (jax.random.normal(k2, (d, kvd)) * s).astype(cfg.dtype),
        "wv": (jax.random.normal(k3, (d, kvd)) * s).astype(cfg.dtype),
        "wo": (jax.random.normal(k4, (qd, d)) * (qd ** -0.5)).astype(cfg.dtype),
    }


def gqa_attention(
    q: jax.Array,          # [B, Sq, H, Dh]
    k: jax.Array,          # [B, Sk, KV, Dh]
    v: jax.Array,          # [B, Sk, KV, Dh]
    mask: Optional[jax.Array],  # broadcastable to [B, H, Sq, Sk] (bool) or None
    scale: Optional[float] = None,
) -> jax.Array:
    """Reference grouped-query attention (XLA path). Returns [B, Sq, H, Dh].

    The Pallas flash path (``repro.kernels.flash_attention``) replaces this
    for long prefill; this einsum formulation is the oracle + default.
    """
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    groups = h // kv
    scale = dh ** -0.5 if scale is None else scale
    qg = q.reshape(b, sq, kv, groups, dh)
    # §Perf D2: keep bf16 dot inputs + f32 accumulation. Pre-casting the
    # KV cache to f32 materialized a full-precision copy of the cache per
    # layer per decode step (dry-run: 2x the whole-cache traffic).
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        # mask arrives [B?, H?, Sq, Sk]; reshape H -> (KV, G)
        mask_ = jnp.broadcast_to(mask, (b, h, sq, k.shape[1])) if mask.ndim == 4 else mask
        mask_ = mask_.reshape(b, kv, groups, sq, k.shape[1])
        logits = jnp.where(mask_, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out.reshape(b, sq, h, dh)


def causal_mask(sq: int, sk: int, offset: int = 0) -> jax.Array:
    """[1, 1, Sq, Sk] boolean causal mask; query i attends to keys <= i+offset."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    return (ki <= qi)[None, None]


# ---------------------------------------------------------------------------
# Dense GLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key: jax.Array, cfg: LMConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = cfg.d_ff if d_ff is None else d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "w_gate": (jax.random.normal(k1, (d, d_ff)) * d ** -0.5).astype(cfg.dtype),
        "w_up": (jax.random.normal(k2, (d, d_ff)) * d ** -0.5).astype(cfg.dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * d_ff ** -0.5).astype(cfg.dtype),
    }


def glu_mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    gate = x @ params["w_gate"]
    up = x @ params["w_up"]
    if activation == "swiglu":
        act = jax.nn.silu(gate)
    elif activation == "geglu":
        act = jax.nn.gelu(gate, approximate=True)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return (act * up) @ params["w_down"]


# ---------------------------------------------------------------------------
# Mixture of Experts (capacity-based top-k dispatch via scatter/gather)
# ---------------------------------------------------------------------------


def init_moe(key: jax.Array, cfg: LMConfig) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    keys = jax.random.split(key, 5)
    d, f, e = cfg.d_model, m.d_ff, m.n_experts
    params = {
        "router": (jax.random.normal(keys[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(keys[1], (e, d, f)) * d ** -0.5).astype(cfg.dtype),
        "w_up": (jax.random.normal(keys[2], (e, d, f)) * d ** -0.5).astype(cfg.dtype),
        "w_down": (jax.random.normal(keys[3], (e, f, d)) * f ** -0.5).astype(cfg.dtype),
    }
    if m.shared_expert:
        params["shared"] = init_mlp(keys[4], cfg)
    return params


def moe_groups(n_tokens: int, moe: MoEConfig) -> tuple[int, int]:
    """(n_groups, tokens_per_group) for GShard dispatch. Powers-of-two
    token counts (all assigned shapes) split evenly; tiny batches use one
    group."""
    if n_tokens <= moe.group_size:
        return 1, n_tokens
    g = n_tokens // moe.group_size
    while n_tokens % g:
        g -= 1
    return g, n_tokens // g


def moe_capacity(tokens_per_group: int, moe: MoEConfig) -> int:
    cap = int(tokens_per_group * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(cap - cap % -8, 8)  # round UP to a lane-friendly multiple of 8


def moe_mlp(params: dict, x: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """Top-k routed experts, GShard grouped-einsum dispatch with drops.

    x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    Tokens reshape to [G, Tg, D] groups; dispatch/combine are one-hot
    einsums [G, Tg, E, C] with per-group capacity C — the formulation
    GSPMD partitions cleanly (groups shard over (pod, data); experts and
    their weights over model). Keeping Tg small (``group_size``) bounds
    the dispatch-einsum overhead to a few percent of expert FLOPs while
    the [G,Tg,E,C] mask stays tens-of-MB per device. Scatter/gather
    dispatch (tutel-style) defeats the GSPMD partitioner — it replicates
    the [E,C,D] buffers (dry-run: 153 GiB/device on arctic-480b).
    Overflow tokens drop (they keep the shared/residual path) — GShard
    semantics.
    """
    m = cfg.moe
    assert m is not None
    b, s, d = x.shape
    t = b * s
    g, tg = moe_groups(t, m)
    cap = moe_capacity(tg, m)
    k = m.top_k
    xt = x.reshape(t, d)

    # bf16 matmul, f32 logits: casting xt to f32 materializes a full-token
    # f32 copy per layer (dry-run: +1.75 GiB/layer/device on arctic-480b)
    gate_logits = (xt @ params["router"].astype(xt.dtype)).astype(jnp.float32)
    top_w, top_e = jax.lax.top_k(gate_logits, k)                # [T, k]
    top_w = jax.nn.softmax(top_w, axis=-1)

    # Load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e
    probs = jax.nn.softmax(gate_logits, axis=-1)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], m.n_experts,
                                      dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux_loss = m.n_experts * jnp.sum(density * density_proxy)

    # Slot positions: GShard priority — slot-0 of every token in the group
    # first, then slot-1, ... (k-major exclusive cumsum).
    oh = jax.nn.one_hot(top_e.reshape(g, tg, k), m.n_experts, dtype=jnp.int32)
    ohk = oh.transpose(0, 2, 1, 3).reshape(g, k * tg, m.n_experts)
    pos = jnp.cumsum(ohk, axis=1) - ohk                          # exclusive
    keep = (pos < cap) & (ohk > 0)
    disp_kc = jnp.where(keep, pos, cap)                          # cap = drop
    # [G, kTg, E, C] one-hot over capacity (index==cap -> all-zero row).
    disp = jax.nn.one_hot(disp_kc, cap, dtype=cfg.dtype)
    disp = disp.reshape(g, k, tg, m.n_experts, cap).transpose(0, 2, 1, 3, 4)
    dispatch = jnp.sum(disp, axis=2)                             # [G,Tg,E,C]
    wk = top_w.reshape(g, tg, k).astype(cfg.dtype)
    combine = jnp.einsum("gtkec,gtk->gtec", disp, wk)
    dispatch = shd.logical(dispatch, "dp", None, "expert", None)
    combine = shd.logical(combine, "dp", None, "expert", None)

    # Dispatch -> expert FFN -> combine, all as einsums.
    xg = shd.logical(x.reshape(g, tg, d).astype(cfg.dtype), "dp", None, None)
    buf = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    buf = shd.logical(buf, "dp", "expert", None, None)
    gate = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
    out_buf = jnp.einsum("gecf,efd->gecd", act * up, params["w_down"])
    out_buf = shd.logical(out_buf, "dp", "expert", None, None)
    y = jnp.einsum("gtec,gecd->gtd", combine, out_buf)

    y = y.reshape(t, d)
    if m.shared_expert:
        y = y + glu_mlp(params["shared"], xt, cfg.activation)
    return y.reshape(b, s, d), aux_loss
