"""Flash attention in pure XLA with a memory-lean custom VJP.

Why this exists (dry-run finding, EXPERIMENTS.md §Perf iteration 0): naive
attention at train_4k materializes [B,H,S,S] fp32 scores (~34 GiB/device
for yi-6b) and autodiff through an online-softmax scan checkpoints every
block carry — both blow the 16 GiB v5e budget. Flash semantics fix it:

  fwd: online-softmax over KV blocks; residuals = (q, k, v, out, lse) only.
  bwd: recompute P blockwise from lse; accumulate dq as a scan carry and
       emit dk/dv per block — no [S, S] tensor ever exists in either pass.

GQA note: K/V are expanded to the full head count here (repeat along the
head axis) so every tensor carries an H dim that the `model` mesh axis
shards cleanly (merged KV·G dims are unshardable when kv·g doesn't factor
through 16 — DESIGN §4). The Pallas TPU kernel
(`repro.kernels.flash_attention`) implements the same contract with VMEM
tiling; this module is the XLA fallback + its numerical oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd

_NEG_INF = -1e30


def _expand_kv(x: jax.Array, h: int) -> jax.Array:
    """[B, S, KV, D] -> [B, S, H, D] by repeating each kv head h//kv times."""
    kv = x.shape[2]
    if kv == h:
        return x
    return jnp.repeat(x, h // kv, axis=2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    block: int = 1024) -> jax.Array:
    """Causal attention. q: [B,Sq,H,D]; k/v: [B,Sk,KV,D] -> [B,Sq,H,D].

    Causal alignment: query i attends to keys <= i + (Sk - Sq), i.e. the
    queries are the LAST Sq positions of the key sequence (standard for
    both full training (Sq==Sk) and chunked prefill (Sq<Sk)).
    """
    out, _ = _flash_fwd(q, k, v, block)
    return out


def _blocks(x: jax.Array, block: int):
    b, s, h, d = x.shape
    n = s // block
    return x.reshape(b, n, block, h, d).transpose(1, 0, 2, 3, 4)  # [n,B,blk,H,D]


def _flash_fwd(q, k, v, block):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sk % block == 0, f"kv len {sk} not divisible by block {block}"
    offset = sk - sq
    scale = d ** -0.5
    kf = _blocks(_expand_kv(k, h), block)
    vf = _blocks(_expand_kv(v, h), block)
    q_pos = jnp.arange(sq) + offset

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, i = xs
        key_pos = i * block + jnp.arange(block)
        # §Perf D2/P1: bf16 dot inputs, f32 accumulation — no materialized
        # f32 copies of q/k/v; p cast to the input dtype for the PV matmul
        # (MXU-native, f32 accumulation via preferred_element_type).
        s_blk = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                           preferred_element_type=jnp.float32) * scale
        mask = key_pos[None, :] <= q_pos[:, None]
        s_blk = jnp.where(mask[None, None], s_blk, _NEG_INF)
        m_cur = jnp.max(s_blk, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s_blk - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    n = sk // block
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kf, vf, jnp.arange(n)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3).astype(q.dtype)
    lse = m + jnp.log(l_safe)
    return out, (q, k, v, out, lse)


def _flash_bwd(block, res, dout):
    q, k, v, out, lse = res
    b, sq, h, d = q.shape
    sk = k.shape[1]
    kv = k.shape[2]
    offset = sk - sq
    scale = d ** -0.5
    kf = _blocks(_expand_kv(k, h), block)
    vf = _blocks(_expand_kv(v, h), block)
    # §Perf A2: the output cotangent arrives sharded like the wo
    # projection (merged h*d over `model`); p/ds are sequence-sharded when
    # heads don't divide the axis (A1). The mismatched einsum made GSPMD
    # ALL-GATHER the [B,H,Sq,blk] probability tiles (22% of arctic
    # collective bytes). Re-pin dout to the attention's own layout.
    if shd.active_mesh() is not None and h % shd.mesh_axis_size("model"):
        dout = shd.logical(dout, "batch", "kv_seq", None, None)
    do = dout.transpose(0, 2, 1, 3)                           # [B,H,Sq,D]
    of = out.transpose(0, 2, 1, 3)
    delta = jnp.einsum("bhqd,bhqd->bhq", do, of,
                       preferred_element_type=jnp.float32)    # [B,H,Sq]
    q_pos = jnp.arange(sq) + offset
    in_dt = q.dtype

    def step(dq_acc, xs):
        kb, vb, i = xs
        key_pos = i * block + jnp.arange(block)
        s_blk = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                           preferred_element_type=jnp.float32) * scale
        mask = key_pos[None, :] <= q_pos[:, None]
        s_blk = jnp.where(mask[None, None], s_blk, _NEG_INF)
        p = jnp.exp(s_blk - lse[..., None])                   # [B,H,Sq,blk]
        dv_blk = jnp.einsum("bhqk,bhqd->bkhd", p.astype(in_dt), do,
                            preferred_element_type=jnp.float32)
        dp = jnp.einsum("bhqd,bkhd->bhqk", do, vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None])                      # [B,H,Sq,blk]
        dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds.astype(in_dt), kb,
                                     preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bhqk,bqhd->bkhd", ds.astype(in_dt), q,
                            preferred_element_type=jnp.float32) * scale
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, h, d), jnp.float32)
    n = sk // block
    dq, (dk_blocks, dv_blocks) = jax.lax.scan(
        step, dq0, (kf, vf, jnp.arange(n)))

    def _unblock(xb):  # [n,B,blk,H,D] -> [B,Sk,H,D]
        return xb.transpose(1, 0, 2, 3, 4).reshape(b, sk, h, d)

    dk = _unblock(dk_blocks)   # qs already carries the scale
    dv = _unblock(dv_blocks)
    if kv != h:  # fold grouped-head grads back onto the kv heads
        g = h // kv
        dk = dk.reshape(b, sk, kv, g, d).sum(axis=3)
        dv = dv.reshape(b, sk, kv, g, d).sum(axis=3)
    return ((dq * scale).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
