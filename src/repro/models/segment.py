"""Segment ops for message passing — the JAX-native sparse substrate.

JAX sparse is BCOO-only, so all GNN/recsys message passing in this repo is
built from ``jax.ops.segment_sum``/``segment_max`` over edge-index arrays
(DESIGN §2). Padded edges point at a dummy segment (index = num_segments)
and are sliced off, keeping everything shape-static for jit/pjit.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def segment_sum(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data: jax.Array, segment_ids: jax.Array, num_segments: int) -> jax.Array:
    tot = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones(data.shape[:1], data.dtype), segment_ids, num_segments)
    return tot / jnp.maximum(cnt, 1.0).reshape((-1,) + (1,) * (data.ndim - 1))


def segment_softmax(scores: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Numerically-stable softmax within segments.

    scores: [E, ...] with segment dim leading. Empty segments produce zeros.
    This is GAT's edge-softmax (SDDMM -> per-destination normalize).
    """
    seg_max = segment_max(scores, segment_ids, num_segments)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = scores - seg_max[segment_ids]
    exp = jnp.exp(shifted)
    seg_sum = segment_sum(exp, segment_ids, num_segments)
    return exp / jnp.maximum(seg_sum[segment_ids], 1e-30)


def scatter_mean_by(graph_ids: jax.Array, node_feats: jax.Array,
                    n_graphs: int) -> jax.Array:
    """Graph-level readout: mean of node features per graph id."""
    return segment_mean(node_feats, graph_ids, n_graphs)


def pad_edges(src, dst, n_edges_max: int, dummy_segment: int,
              feats: Optional[jax.Array] = None):
    """Pad edge arrays to a static size; padded edges hit ``dummy_segment``."""
    e = src.shape[0]
    if e > n_edges_max:
        raise ValueError(f"{e} edges exceed static budget {n_edges_max}")
    pad = n_edges_max - e
    src = jnp.pad(src, (0, pad), constant_values=dummy_segment)
    dst = jnp.pad(dst, (0, pad), constant_values=dummy_segment)
    if feats is not None:
        feats = jnp.pad(feats, ((0, pad),) + ((0, 0),) * (feats.ndim - 1))
        return src, dst, feats
    return src, dst
