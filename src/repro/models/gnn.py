"""Graph attention network (GAT, Velickovic et al. 2018) on segment ops.

Assigned arch ``gat-cora``: 2 layers, 8 hidden units x 8 heads, attention
aggregator. The same module runs all four assigned shapes:

* ``full_graph_sm``  — Cora full-batch (2708 nodes / 10556 edges / f=1433)
* ``minibatch_lg``   — fanout-(15,10) sampled training on a Reddit-scale
                       graph (``retrieval/sampler.py`` provides the sampler)
* ``ogb_products``   — full-batch 2.45M nodes / 61.9M edges / f=100
* ``molecule``       — 128 graphs x 30 nodes batched via graph-id readout

Message passing = SDDMM (edge scores) -> segment-softmax -> SpMM
(segment-sum), the JAX-native formulation of sparse attention aggregation.
Edge arrays are padded to static shapes; padded edges target the dummy
node slot N (features carry one extra zero row) — see `segment.pad_edges`.

SkewRoute link (DESIGN §5): the per-destination attention distribution this
model produces over a query-anchored subgraph is itself a retrieval score
distribution — `repro.retrieval.scorer.GATScorer` reuses these layers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import segment as seg


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    aggregator: str = "attn"
    negative_slope: float = 0.2
    dtype: jnp.dtype = jnp.float32
    remat: bool = False

    def layer_dims(self, d_feat: int, n_classes: int) -> list[tuple[int, int, int]]:
        """[(d_in, n_heads, d_out)] per layer. Hidden layers concat heads;
        the output layer uses 1 averaged head onto n_classes (GAT paper)."""
        dims = []
        d_in = d_feat
        for _ in range(self.n_layers - 1):
            dims.append((d_in, self.n_heads, self.d_hidden))
            d_in = self.n_heads * self.d_hidden
        dims.append((d_in, 1, n_classes))
        return dims


def init_gat_layer(key: jax.Array, d_in: int, heads: int, d_out: int,
                   dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s = (2.0 / d_in) ** 0.5
    return {
        "w": (jax.random.normal(k1, (d_in, heads * d_out)) * s).astype(dtype),
        "a_src": (jax.random.normal(k2, (heads, d_out)) * s).astype(dtype),
        "a_dst": (jax.random.normal(k3, (heads, d_out)) * s).astype(dtype),
        "bias": jnp.zeros((heads * d_out,), dtype),
    }


def init_params(key: jax.Array, cfg: GNNConfig, d_feat: int, n_classes: int) -> dict:
    dims = cfg.layer_dims(d_feat, n_classes)
    keys = jax.random.split(key, len(dims))
    return {"gnn": {f"layer{i}": init_gat_layer(k, *d, cfg.dtype)
                    for i, (k, d) in enumerate(zip(keys, dims))}}


def gat_layer(p: dict, x: jax.Array, src: jax.Array, dst: jax.Array,
              n_nodes: int, heads: int, d_out: int, cfg: GNNConfig,
              final: bool) -> jax.Array:
    """One GAT layer. x: [N+1, d_in] (slot N = dummy for padded edges).

    Returns [N+1, heads*d_out] (concat) or [N+1, d_out] (mean, final layer).
    """
    h = shd.logical(x @ p["w"], "node", None)             # [N+1, H*D]
    hh = h.reshape(-1, heads, d_out)
    # SDDMM: per-edge attention logits from source/destination projections.
    e_src = jnp.sum(hh * p["a_src"], axis=-1)             # [N+1, H]
    e_dst = jnp.sum(hh * p["a_dst"], axis=-1)
    logits = e_src[src] + e_dst[dst]                      # [E, H]
    logits = jax.nn.leaky_relu(logits, cfg.negative_slope)
    logits = shd.logical(logits, "edge", None)
    # Edge softmax per destination (dummy slot absorbs padded edges).
    alpha = seg.segment_softmax(logits, dst, n_nodes + 1)
    msg = alpha[..., None] * hh[src]                      # [E, H, D]
    agg = seg.segment_sum(msg, dst, n_nodes + 1)          # [N+1, H, D]
    if final:
        out = jnp.mean(agg, axis=1)                       # average heads
    else:
        out = jax.nn.elu(agg.reshape(-1, heads * d_out) + p["bias"])
    return out


def forward(params: dict, cfg: GNNConfig, feats: jax.Array, src: jax.Array,
            dst: jax.Array, d_feat: int, n_classes: int) -> jax.Array:
    """feats: [N, d_feat] -> logits [N, n_classes]. Appends the dummy row."""
    n = feats.shape[0]
    x = jnp.concatenate([feats, jnp.zeros((1, feats.shape[1]), feats.dtype)], 0)
    dims = cfg.layer_dims(d_feat, n_classes)
    for i, (d_in, heads, d_out) in enumerate(dims):
        x = gat_layer(params["gnn"][f"layer{i}"], x, src, dst, n, heads,
                      d_out, cfg, final=(i == len(dims) - 1))
    return x[:n]


def node_loss(params: dict, cfg: GNNConfig, batch: dict, d_feat: int,
              n_classes: int) -> jax.Array:
    """Masked node-classification cross-entropy.

    batch: feats [N, F], src/dst [E], labels [N], label_mask [N] bool.
    """
    logits = forward(params, cfg, batch["feats"], batch["src"], batch["dst"],
                     d_feat, n_classes).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(batch["labels"], 0)[:, None],
                               axis=-1)[:, 0]
    per_node = logz - gold
    mask = batch["label_mask"].astype(jnp.float32)
    return jnp.sum(per_node * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def minibatch_loss(params: dict, cfg: GNNConfig, batch: dict, d_feat: int,
                   n_classes: int) -> jax.Array:
    """Sampled-subgraph loss: only the first ``n_seeds`` rows are seeds."""
    return node_loss(params, cfg, batch, d_feat, n_classes)


def graph_loss(params: dict, cfg: GNNConfig, batch: dict, d_feat: int,
               n_classes: int) -> jax.Array:
    """Batched-small-graph classification (``molecule`` shape).

    batch: feats [B*N, F], src/dst [B*E], graph_ids [B*N], labels [B].
    """
    node_logits = forward(params, cfg, batch["feats"], batch["src"],
                          batch["dst"], d_feat, n_classes)
    n_graphs = batch["labels"].shape[0]
    graph_logits = seg.scatter_mean_by(batch["graph_ids"], node_logits,
                                       n_graphs).astype(jnp.float32)
    logz = jax.nn.logsumexp(graph_logits, axis=-1)
    gold = jnp.take_along_axis(graph_logits, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(logz - gold)
