"""Decoder-only transformer LM family (dense GQA + MoE variants).

Covers the five assigned LM architectures (internlm2-20b, yi-6b, gemma-7b,
llama4-scout-17b-a16e, arctic-480b) through `LMConfig`. Design points:

* **scan over layers** with stacked params — one layer of HLO regardless of
  depth; compile time and HLO size stay bounded for the 48-layer dry-runs.
* **chunked (online-softmax) attention** for long prefill — an XLA-level
  flash formulation (`attention_impl="chunked"`), so 32k-token prefill never
  materializes an [Sq, Sk] score matrix. The Pallas kernel
  (`repro.kernels.flash_attention`) is the TPU fast path for the same math.
* **sequence-chunked cross-entropy** — logits are produced a chunk at a
  time under `jax.checkpoint`, so the [B, S, V] tensor (2 TB for gemma's
  256k vocab at train_4k) never exists.
* three entry points: `train_loss` (train_4k), `prefill` (prefill_32k),
  `decode_step` (decode_32k / long_500k).

Sharding is annotated with logical axes via `repro.distributed.sharding`
so the same model code runs single-host and on the (pod, data, model) mesh.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import flash
from repro.models import layers as L
from repro.models.layers import LMConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key: jax.Array, cfg: LMConfig) -> dict:
    k_attn, k_mlp = jax.random.split(key)
    p = {
        "ln_attn": L.init_rms_norm(cfg.d_model, cfg.dtype),
        "ln_mlp": L.init_rms_norm(cfg.d_model, cfg.dtype),
        "attn": L.init_attention(k_attn, cfg),
    }
    if cfg.moe is None:
        p["mlp"] = L.init_mlp(k_mlp, cfg)
    else:
        p["moe"] = L.init_moe(k_mlp, cfg)
    return p


def init_params(key: jax.Array, cfg: LMConfig) -> dict:
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) *
                  cfg.d_model ** -0.5).astype(cfg.dtype),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "ln_final": L.init_rms_norm(cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                             * cfg.d_model ** -0.5).astype(cfg.dtype)
    return params


def param_spec(cfg: LMConfig):
    """ShapeDtypeStruct pytree of params — dry-run stand-in, no allocation."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


# ---------------------------------------------------------------------------
# Attention paths
# ---------------------------------------------------------------------------


def _full_attention(q, k, v, *, causal_offset: int, kv_len: Optional[jax.Array]):
    sq, sk = q.shape[1], k.shape[1]
    mask = L.causal_mask(sq, sk, offset=causal_offset)
    if kv_len is not None:  # decode: only cache positions < kv_len are valid
        mask = jnp.logical_and(mask, (jnp.arange(sk) < kv_len)[None, None, None, :])
    return L.gqa_attention(q, k, v, mask)


def _chunked_attention(q, k, v, *, causal_offset: int,
                       kv_len: Optional[jax.Array], block: int = 1024):
    """Online-softmax attention, scanning KV blocks (XLA flash formulation).

    Never materializes [Sq, Sk]; peak extra memory is one [B, KV, G, Sq,
    block] score tile. Matches `_full_attention` to fp32 accumulation
    tolerance (property-tested in tests/test_transformer.py).
    """
    b, sq, h, dh = q.shape
    sk, kv = k.shape[1], k.shape[2]
    groups = h // kv
    scale = dh ** -0.5
    nblocks = -(-sk // block)
    pad = nblocks * block - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblocks, block, kv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblocks, block, kv, dh).transpose(1, 0, 2, 3, 4)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, kv, groups, dh)
    q_pos = jnp.arange(sq) + causal_offset

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kblk, vblk, blk_idx = xs
        key_pos = blk_idx * block + jnp.arange(block)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk.astype(jnp.float32))
        valid = key_pos[None, :] <= q_pos[:, None]          # causal
        valid = jnp.logical_and(valid, (key_pos < sk)[None, :])  # padding
        if kv_len is not None:
            valid = jnp.logical_and(valid, (key_pos < kv_len)[None, :])
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, groups, sq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((b, kv, groups, sq), dtype=jnp.float32)
    acc0 = jnp.zeros((b, kv, groups, sq, dh), dtype=jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (kb, vb, jnp.arange(nblocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def attention(q, k, v, cfg: LMConfig, *, causal_offset: int = 0,
              kv_len: Optional[jax.Array] = None, impl: Optional[str] = None):
    sq, sk = q.shape[1], k.shape[1]
    if impl is None:
        impl = cfg.attn_impl
    if impl is None:
        if kv_len is None and sq == sk and sq > 1:
            impl = "flash"        # train / prefill: memory-lean custom VJP
        elif sq == 1:
            impl = "full"         # decode: [B,H,1,Sk] scores are cheap and
                                  # shard over the seq axis (split-KV)
        else:
            impl = "chunked"
    if impl == "flash":
        block = cfg.flash_block or (1024 if (sk >= 1024 and sk % 1024 == 0)
                                    else sk)
        return flash.flash_attention(q, k, v, block)
    if impl == "chunked":
        return _chunked_attention(q, k, v, causal_offset=causal_offset, kv_len=kv_len)
    return _full_attention(q, k, v, causal_offset=causal_offset, kv_len=kv_len)


# ---------------------------------------------------------------------------
# Decoder layer
# ---------------------------------------------------------------------------


def _qkv(p_attn: dict, x: jax.Array, cfg: LMConfig, positions: jax.Array):
    b, s, _ = x.shape
    q = shd.logical(x @ p_attn["wq"], "batch", None, "model")
    k = shd.logical(x @ p_attn["wk"], "batch", None, "model")
    v = shd.logical(x @ p_attn["wv"], "batch", None, "model")
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    seq_sharded_training = (shd.spec_for("seq") is not None
                            and tuple(shd.spec_for("seq")) != (None,))
    if (cfg.n_heads % shd.mesh_axis_size("model") != 0 and s > 1
            and seq_sharded_training):
        # (scoped to TRAIN rules: in serving prefill the same constraint
        # ballooned arctic multi-pod peak memory 8.3 -> 31 GiB — measured
        # regression, see §Perf A1 scope note)
        # §Perf A1: 40/56-head archs don't divide the model axis; left to
        # itself GSPMD shards the head_dim CONTRACTION of the attention
        # dots and inserts an all-reduce per flash block (dry-run: 26% of
        # arctic-480b train collective bytes). Pin sequence sharding for
        # attention instead — softmax stays local, K/V are all-gathered
        # once per layer (134 MB vs 2.1 TB/device/step).
        q = shd.logical(q, "batch", "kv_seq", None, None)
        k = shd.logical(k, "batch", "kv_seq", None, None)
        v = shd.logical(v, "batch", "kv_seq", None, None)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def decoder_layer_train(p: dict, x: jax.Array, cfg: LMConfig,
                        positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Full-sequence layer (training / prefill). Returns (x, moe_aux)."""
    h = L.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h, cfg, positions)
    attn_out = attention(q, k, v, cfg)
    attn_out = attn_out.reshape(*x.shape[:2], cfg.qkv_dim)
    x = x + shd.logical(attn_out @ p["attn"]["wo"], "batch", None, None)

    h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.moe is None:
        y = L.glu_mlp(p["mlp"], h, cfg.activation)
        aux = jnp.zeros((), jnp.float32)
    else:
        y, aux = L.moe_mlp(p["moe"], h, cfg)
    x = shd.logical(x + y, "batch", "seq", None)
    return x, aux


def decoder_layer_decode(p: dict, x: jax.Array, cfg: LMConfig,
                         cache_k: jax.Array, cache_v: jax.Array,
                         pos: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token layer against a static-size KV cache.

    cache_k/v: [B, S, KV*Dh]; pos: scalar int32 — write index & mask bound.
    Returns (x, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    h = L.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(p["attn"], h, cfg, positions)
    k_flat = k.reshape(b, 1, cfg.kv_dim).astype(cache_k.dtype)
    v_flat = v.reshape(b, 1, cfg.kv_dim).astype(cache_v.dtype)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k_flat, (0, pos, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v_flat, (0, pos, 0))
    # §Perf D1 (refuted) / D3: an in-loop with_sharding_constraint on the
    # cache did NOT change traffic (GSPMD already kept the split-KV
    # layout) and risks materializing copies — constraints stay at the
    # jit boundary only.
    s = cache_k.shape[1]
    k_all = cache_k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v_all = cache_v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    attn_out = attention(q, k_all, v_all, cfg, causal_offset=pos,
                         kv_len=pos + 1, impl=cfg.decode_attn_impl)
    attn_out = attn_out.reshape(b, 1, cfg.qkv_dim)
    x = x + attn_out @ p["attn"]["wo"]

    h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.moe is None:
        y = L.glu_mlp(p["mlp"], h, cfg.activation)
    else:
        y, _ = L.moe_mlp(p["moe"], h, cfg)
    return x + y, cache_k, cache_v


# ---------------------------------------------------------------------------
# Remat policies
# ---------------------------------------------------------------------------

_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _maybe_remat(fn, cfg: LMConfig):
    if cfg.remat_policy == "none":
        return fn
    policy = _POLICIES[cfg.remat_policy]
    return jax.checkpoint(fn, policy=policy, prevent_cse=False)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def backbone(params: dict, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, jax.Array]:
    """Embed + all layers + final norm. tokens [B, S] -> (hidden [B, S, D], aux)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)  # gemma embed scaling
    x = shd.logical(x, "batch", None, None)
    positions = jnp.arange(s)

    def layer_fn(carry, p_l):
        x, aux = carry
        x, aux_l = decoder_layer_train(p_l, x, cfg, positions)
        return (x, aux + aux_l), None

    layer_fn = _maybe_remat(layer_fn, cfg)
    (x, aux), _ = jax.lax.scan(layer_fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"], unroll=cfg.scan_unroll)
    return L.rms_norm(x, params["ln_final"], cfg.norm_eps), aux


def _head_matrix(params: dict, cfg: LMConfig) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def chunked_ce_loss(params: dict, hidden: jax.Array, labels: jax.Array,
                    cfg: LMConfig) -> jax.Array:
    """Cross-entropy without materializing [B, S, V].

    Scans sequence chunks; each chunk's logits live only inside a
    jax.checkpoint region (recomputed in backward).
    """
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    head = _head_matrix(params, cfg)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def chunk_loss(h, lab):
        logits = (h @ head).astype(jnp.float32)         # [B, C, V]
        logits = shd.logical(logits, "batch", None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        valid = lab >= 0
        return jnp.sum(jnp.where(valid, logz - gold, 0.0)), jnp.sum(valid)

    def step(carry, xs):
        tot, cnt = carry
        h, lab = xs
        l, n = chunk_loss(h, lab)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.int32)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def train_loss(params: dict, batch: dict, cfg: LMConfig,
               aux_weight: float = 0.01) -> jax.Array:
    """batch = {"tokens": [B,S] int32, "labels": [B,S] int32 (-1 = pad)}."""
    hidden, aux = backbone(params, batch["tokens"], cfg)
    loss = chunked_ce_loss(params, hidden, batch["labels"], cfg)
    return loss + aux_weight * aux


def prefill(params: dict, tokens: jax.Array, cfg: LMConfig) -> tuple[jax.Array, dict]:
    """Prompt processing: tokens [B, S] -> (last-token logits [B, V], cache).

    Cache layout: {"k"/"v": [L, B, S, KV*Dh]} (flat KV dim — see DESIGN §4:
    merged KV·Dh always divides the model axis, per-head counts don't).
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)
    x = shd.logical(x, "batch", None, None)
    positions = jnp.arange(s)

    def layer_fn(x, p_l):
        h = L.rms_norm(x, p_l["ln_attn"], cfg.norm_eps)
        q, k, v = _qkv(p_l["attn"], h, cfg, positions)
        attn_out = attention(q, k, v, cfg).reshape(b, s, cfg.qkv_dim)
        x = x + attn_out @ p_l["attn"]["wo"]
        h = L.rms_norm(x, p_l["ln_mlp"], cfg.norm_eps)
        if cfg.moe is None:
            y = L.glu_mlp(p_l["mlp"], h, cfg.activation)
        else:
            y, _ = L.moe_mlp(p_l["moe"], h, cfg)
        kf = shd.logical(k.reshape(b, s, cfg.kv_dim), "batch", "kv_seq", None)
        vf = shd.logical(v.reshape(b, s, cfg.kv_dim), "batch", "kv_seq", None)
        return x + y, {"k": kf, "v": vf}

    x, cache = jax.lax.scan(layer_fn, x, params["layers"],
                            unroll=cfg.scan_unroll)
    hidden = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = (hidden[:, -1, :] @ _head_matrix(params, cfg)).astype(jnp.float32)
    return shd.logical(logits, "batch", "model"), cache


def decode_step(params: dict, cache: dict, tokens: jax.Array, pos: jax.Array,
                cfg: LMConfig) -> tuple[jax.Array, dict]:
    """One decode step. tokens [B, 1]; pos scalar int32 (current length).

    Returns (logits [B, V], updated cache). Cache: {"k"/"v": [L,B,S,KVD]}.
    """
    b = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)

    def layer_fn(x, xs):
        p_l, ck, cv = xs
        x, ck, cv = decoder_layer_decode(p_l, x, cfg, ck, cv, pos)
        return x, {"k": ck, "v": cv}

    x, new_cache = jax.lax.scan(layer_fn, x,
                                (params["layers"], cache["k"], cache["v"]),
                                unroll=cfg.scan_unroll)
    hidden = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    logits = (hidden[:, 0, :] @ _head_matrix(params, cfg)).astype(jnp.float32)
    return shd.logical(logits, "batch", "model"), new_cache


def init_cache(cfg: LMConfig, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, seq, cfg.kv_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_spec(cfg: LMConfig, batch: int, seq: int, dtype=jnp.bfloat16) -> dict:
    shape = (cfg.n_layers, batch, seq, cfg.kv_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}
