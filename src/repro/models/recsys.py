"""Recommendation/ranking models: DLRM, DCN-v2, DeepFM, DIEN.

Substrate notes (DESIGN §2): JAX has no native EmbeddingBag or CSR sparse,
so the embedding layer here is built from ``jnp.take`` over a single
concatenated table (one [sum_vocab, dim] array; per-field row offsets) plus
``segment_sum`` for multi-hot bags — this IS the system's embedding engine,
and `repro.kernels.embedding_bag` is its Pallas TPU fast path.

Each model exposes:
  * ``init_params(key, cfg)``
  * ``forward(params, cfg, batch) -> logits [B]`` (serve_p99 / serve_bulk)
  * ``loss(params, cfg, batch) -> BCE`` (train_batch)
  * ``retrieval_scores(params, cfg, batch) -> [B, n_candidates]``
    (retrieval_cand: one user representation dotted against the candidate
    item-embedding block — a single batched matmul, not a loop).

SkewRoute link: ``retrieval_scores``/``forward`` outputs are score
distributions over candidates; `examples/recsys_routing.py` routes between
a small and a large ranker on their skewness (DESIGN §5 generalization).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding as shd

# ---------------------------------------------------------------------------
# Published vocab tables
# ---------------------------------------------------------------------------

#: Criteo Terabyte (MLPerf DLRM benchmark) per-table row counts.
CRITEO_TB_VOCABS: tuple[int, ...] = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36)

#: Criteo Kaggle per-field vocab (DCN-v2 paper's dataset).
CRITEO_KAGGLE_VOCABS: tuple[int, ...] = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18,
    15, 286181, 105, 142572)

#: DIEN (Amazon Books): users / items / categories.
AMAZON_BOOKS_VOCABS = {"user": 543060, "item": 367983, "cat": 1601}


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str                       # dlrm | dcn_v2 | deepfm | dien
    n_dense: int
    n_sparse: int
    embed_dim: int
    vocab_sizes: tuple[int, ...]
    bot_mlp: tuple[int, ...] = ()
    top_mlp: tuple[int, ...] = ()
    deep_mlp: tuple[int, ...] = ()
    n_cross_layers: int = 0
    interaction: str = "dot"         # dot | cross | fm | augru
    # DIEN only
    seq_len: int = 0
    gru_dim: int = 0
    scan_unroll: int = 1  # cost-probe knob (see launch/dryrun.py)
    dtype: jnp.dtype = jnp.float32
    param_dtype: jnp.dtype = jnp.float32

    @property
    def total_vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def padded_vocab(self) -> int:
        """Row count padded to 512 so the table row-shards on any mesh."""
        t = self.total_vocab
        return -(-t // 512) * 512

    @property
    def row_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)

    @property
    def n_embedding_params(self) -> int:
        return self.total_vocab * self.embed_dim


# ---------------------------------------------------------------------------
# Embedding substrate
# ---------------------------------------------------------------------------


def init_tables(key: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """One concatenated [sum_vocab, dim] table (row-sharded over 'table')."""
    scale = cfg.embed_dim ** -0.5
    return (jax.random.normal(key, (cfg.padded_vocab, cfg.embed_dim)) *
            scale).astype(cfg.param_dtype)


def embedding_lookup(tables: jax.Array, cfg: RecsysConfig,
                     field_ids: jax.Array) -> jax.Array:
    """One-hot per-field lookup. field_ids: [B, F] local ids -> [B, F, dim]."""
    offsets = jnp.asarray(cfg.row_offsets)
    global_ids = field_ids + offsets[None, : field_ids.shape[1]]
    out = jnp.take(tables, global_ids, axis=0)
    return shd.logical(out, "batch", None, None)


def embedding_bag(tables: jax.Array, global_ids: jax.Array,
                  weights: Optional[jax.Array] = None,
                  combiner: str = "sum") -> jax.Array:
    """Multi-hot bag: global_ids [B, nnz] (-1 = pad) -> [B, dim].

    take + masked reduce == torch nn.EmbeddingBag(mode=combiner). The
    Pallas kernel `repro.kernels.embedding_bag` implements the same
    contract with VMEM-tiled gathers.
    """
    mask = (global_ids >= 0)
    rows = jnp.take(tables, jnp.maximum(global_ids, 0), axis=0)  # [B,nnz,dim]
    w = mask.astype(rows.dtype)
    if weights is not None:
        w = w * weights
    summed = jnp.einsum("bnd,bn->bd", rows, w)
    if combiner == "sum":
        return summed
    if combiner == "mean":
        return summed / jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
    raise ValueError(f"unknown combiner {combiner!r}")


# ---------------------------------------------------------------------------
# MLP helper
# ---------------------------------------------------------------------------


def init_mlp_stack(key: jax.Array, dims: Sequence[int], dtype,
                   prefix: str = "mlp") -> dict:
    keys = jax.random.split(key, len(dims) - 1)
    out = {}
    for i, (k, d_in, d_out) in enumerate(zip(keys, dims[:-1], dims[1:])):
        out[f"{prefix}{i}"] = {
            "w": (jax.random.normal(k, (d_in, d_out)) * (2.0 / d_in) ** 0.5).astype(dtype),
            "b": jnp.zeros((d_out,), dtype),
        }
    return out


def mlp_apply(params: dict, x: jax.Array, n_layers: int, prefix: str = "mlp",
              final_relu: bool = False) -> jax.Array:
    for i in range(n_layers):
        p = params[f"{prefix}{i}"]
        x = x @ p["w"] + p["b"]
        if i < n_layers - 1 or final_relu:
            x = jax.nn.relu(x)
    return x


def _bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels +
                    jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# DLRM (Naumov et al. 2019, MLPerf config)
# ---------------------------------------------------------------------------


def init_dlrm(key: jax.Array, cfg: RecsysConfig) -> dict:
    k_t, k_b, k_top = jax.random.split(key, 3)
    n_emb = cfg.n_sparse + 1  # +1 for the bottom-MLP dense embedding
    n_interactions = n_emb * (n_emb - 1) // 2
    top_in = cfg.embed_dim + n_interactions
    return {
        "tables": init_tables(k_t, cfg),
        "bot": init_mlp_stack(k_b, (cfg.n_dense,) + cfg.bot_mlp, cfg.dtype, "bot"),
        "top": init_mlp_stack(k_top, (top_in,) + cfg.top_mlp, cfg.dtype, "top"),
    }


def dlrm_forward(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    dense = batch["dense"].astype(cfg.dtype)                   # [B, 13]
    dense_emb = mlp_apply(params["bot"], dense, len(cfg.bot_mlp), "bot",
                          final_relu=True)                     # [B, 128]
    sparse = embedding_lookup(params["tables"], cfg, batch["sparse"])
    z = jnp.concatenate([dense_emb[:, None, :], sparse.astype(cfg.dtype)], 1)
    # Pairwise dot interaction (upper triangle, no self terms).
    zz = jnp.einsum("bnd,bmd->bnm", z, z)
    n = z.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    inter = zz[:, iu, ju]                                      # [B, n(n-1)/2]
    top_in = jnp.concatenate([dense_emb, inter], axis=1)
    return mlp_apply(params["top"], top_in, len(cfg.top_mlp), "top")[:, 0]


# ---------------------------------------------------------------------------
# DCN-v2 (Wang et al. 2021) — full-rank cross layers, parallel deep tower
# ---------------------------------------------------------------------------


def init_dcn(key: jax.Array, cfg: RecsysConfig) -> dict:
    k_t, k_c, k_d, k_f = jax.random.split(key, 4)
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = {}
    for i, k in enumerate(jax.random.split(k_c, cfg.n_cross_layers)):
        cross[f"cross{i}"] = {
            "w": (jax.random.normal(k, (d0, d0)) * d0 ** -0.5).astype(cfg.dtype),
            "b": jnp.zeros((d0,), cfg.dtype),
        }
    return {
        "tables": init_tables(k_t, cfg),
        "cross": cross,
        "deep": init_mlp_stack(k_d, (d0,) + cfg.deep_mlp, cfg.dtype, "deep"),
        "final": init_mlp_stack(k_f, (d0 + cfg.deep_mlp[-1], 1), cfg.dtype, "final"),
    }


def dcn_forward(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    emb = embedding_lookup(params["tables"], cfg, batch["sparse"])
    x0 = jnp.concatenate(
        [batch["dense"].astype(cfg.dtype), emb.reshape(emb.shape[0], -1).astype(cfg.dtype)], 1)
    x = x0
    for i in range(cfg.n_cross_layers):
        p = params["cross"][f"cross{i}"]
        x = x0 * (x @ p["w"] + p["b"]) + x                      # DCN-v2 cross
    deep = mlp_apply(params["deep"], x0, len(cfg.deep_mlp), "deep",
                     final_relu=True)
    out = jnp.concatenate([x, deep], axis=1)
    return mlp_apply(params["final"], out, 1, "final")[:, 0]


# ---------------------------------------------------------------------------
# DeepFM (Guo et al. 2017)
# ---------------------------------------------------------------------------


def init_deepfm(key: jax.Array, cfg: RecsysConfig) -> dict:
    k_t, k_w, k_d = jax.random.split(key, 3)
    d_in = cfg.n_sparse * cfg.embed_dim
    return {
        "tables": init_tables(k_t, cfg),
        "fm": {"w1": (jax.random.normal(k_w, (cfg.padded_vocab, 1)) * 0.01
                      ).astype(cfg.param_dtype)},  # first-order weights
        "deep": init_mlp_stack(k_d, (d_in,) + cfg.deep_mlp + (1,), cfg.dtype, "deep"),
    }


def deepfm_forward(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    offsets = jnp.asarray(cfg.row_offsets)
    gids = batch["sparse"] + offsets[None, : batch["sparse"].shape[1]]
    emb = jnp.take(params["tables"], gids, axis=0).astype(cfg.dtype)  # [B,F,d]
    first = jnp.take(params["fm"]["w1"], gids, axis=0)[..., 0].astype(cfg.dtype)
    fm1 = jnp.sum(first, axis=1)
    # Second order: 1/2 ((sum v)^2 - sum v^2), summed over embed dim.
    sum_v = jnp.sum(emb, axis=1)
    sum_v2 = jnp.sum(emb * emb, axis=1)
    fm2 = 0.5 * jnp.sum(sum_v * sum_v - sum_v2, axis=1)
    deep = mlp_apply(params["deep"], emb.reshape(emb.shape[0], -1),
                     len(cfg.deep_mlp) + 1, "deep")[:, 0]
    return fm1 + fm2 + deep


# ---------------------------------------------------------------------------
# DIEN (Zhou et al. 2019) — GRU interest extraction + AUGRU evolution
# ---------------------------------------------------------------------------


def _init_gru(key: jax.Array, d_in: int, d_h: int, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    s_in, s_h = (1.0 / d_in) ** 0.5, (1.0 / d_h) ** 0.5
    return {
        "wx": (jax.random.normal(k1, (d_in, 3 * d_h)) * s_in).astype(dtype),
        "wh": (jax.random.normal(k2, (d_h, 3 * d_h)) * s_h).astype(dtype),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def _gru_cell(p: dict, h: jax.Array, x: jax.Array,
              att: Optional[jax.Array] = None) -> jax.Array:
    """GRU step; with ``att`` it's AUGRU (attention scales the update gate)."""
    gx = x @ p["wx"] + p["b"]
    gh = h @ p["wh"]
    rx, ux, cx = jnp.split(gx, 3, axis=-1)
    rh, uh, ch = jnp.split(gh, 3, axis=-1)
    r = jax.nn.sigmoid(rx + rh)
    u = jax.nn.sigmoid(ux + uh)
    c = jnp.tanh(cx + r * ch)  # reset gate scales the hidden contribution
    if att is not None:
        u = u * att[:, None]
    return (1.0 - u) * h + u * c


def init_dien(key: jax.Array, cfg: RecsysConfig) -> dict:
    keys = jax.random.split(key, 6)
    d_item = 2 * cfg.embed_dim  # item + category embedding concat
    mlp_in = cfg.gru_dim + d_item + cfg.embed_dim  # interest + target + user
    return {
        "tables": init_tables(keys[0], cfg),
        "gru": _init_gru(keys[1], d_item, cfg.gru_dim, cfg.dtype),
        "augru": _init_gru(keys[2], cfg.gru_dim, cfg.gru_dim, cfg.dtype),
        "att": {"w": (jax.random.normal(keys[3], (cfg.gru_dim, d_item)) *
                      cfg.gru_dim ** -0.5).astype(cfg.dtype)},
        "deep": init_mlp_stack(keys[4], (mlp_in,) + cfg.deep_mlp + (1,),
                               cfg.dtype, "deep"),
        "proj": {"w": (jax.random.normal(keys[5], (cfg.gru_dim, d_item)) *
                       cfg.gru_dim ** -0.5).astype(cfg.dtype)},
    }


def _dien_embed(params: dict, cfg: RecsysConfig, batch: dict):
    """DIEN fields: user_id | target (item, cat) | history [S] (item, cat)."""
    offsets = cfg.row_offsets
    user_off, item_off, cat_off = 0, offsets[1], offsets[2]
    tables = params["tables"]
    user = jnp.take(tables, batch["user_id"] + user_off, axis=0)
    t_item = jnp.take(tables, batch["target_item"] + item_off, axis=0)
    t_cat = jnp.take(tables, batch["target_cat"] + cat_off, axis=0)
    h_item = jnp.take(tables, batch["hist_items"] + item_off, axis=0)
    h_cat = jnp.take(tables, batch["hist_cats"] + cat_off, axis=0)
    target = jnp.concatenate([t_item, t_cat], -1).astype(cfg.dtype)   # [B, 2d]
    hist = jnp.concatenate([h_item, h_cat], -1).astype(cfg.dtype)     # [B, S, 2d]
    return user.astype(cfg.dtype), target, hist


def dien_interest(params: dict, cfg: RecsysConfig, target: jax.Array,
                  hist: jax.Array, hist_mask: jax.Array) -> jax.Array:
    """GRU over history -> attention vs target -> AUGRU. Returns [B, gru_dim]."""
    b = hist.shape[0]
    h0 = jnp.zeros((b, cfg.gru_dim), cfg.dtype)
    xs = hist.transpose(1, 0, 2)                       # [S, B, 2d]
    ms = hist_mask.astype(cfg.dtype).T                 # [S, B]

    def gru_step(h, x_m):
        x, m = x_m
        h_new = _gru_cell(params["gru"], h, x)
        h = m[:, None] * h_new + (1 - m[:, None]) * h
        return h, h

    _, states = jax.lax.scan(gru_step, h0, (xs, ms),
                             unroll=cfg.scan_unroll)  # [S, B, H]

    # Attention of target on interest states (DIN-style bilinear score).
    scores = jnp.einsum("sbh,hd,bd->sb", states, params["att"]["w"], target)
    scores = jnp.where(ms > 0, scores, -1e30)
    att = jax.nn.softmax(scores, axis=0)               # over time

    def augru_step(h, s_a_m):
        s, a, m = s_a_m
        h_new = _gru_cell(params["augru"], h, s, att=a)
        h = m[:, None] * h_new + (1 - m[:, None]) * h
        return h, None

    h_final, _ = jax.lax.scan(augru_step, h0, (states, att, ms),
                              unroll=cfg.scan_unroll)
    return h_final


def dien_forward(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    user, target, hist = _dien_embed(params, cfg, batch)
    interest = dien_interest(params, cfg, target, hist, batch["hist_mask"])
    x = jnp.concatenate([interest, target, user], axis=-1)
    return mlp_apply(params["deep"], x, len(cfg.deep_mlp) + 1, "deep")[:, 0]


# ---------------------------------------------------------------------------
# Uniform API
# ---------------------------------------------------------------------------

_INIT = {"dlrm": init_dlrm, "dcn_v2": init_dcn, "deepfm": init_deepfm,
         "dien": init_dien}
_FWD = {"dlrm": dlrm_forward, "dcn_v2": dcn_forward, "deepfm": deepfm_forward,
        "dien": dien_forward}


def init_params(key: jax.Array, cfg: RecsysConfig) -> dict:
    return _INIT[cfg.model](key, cfg)


def forward(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    return _FWD[cfg.model](params, cfg, batch)


def loss(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    return _bce(forward(params, cfg, batch), batch["labels"])


def user_embedding(params: dict, cfg: RecsysConfig, batch: dict) -> jax.Array:
    """User-side tower representation for retrieval scoring [B, embed_dim]."""
    if cfg.model == "dlrm":
        return mlp_apply(params["bot"], batch["dense"].astype(cfg.dtype),
                         len(cfg.bot_mlp), "bot", final_relu=True)
    if cfg.model == "dien":
        user, target, hist = _dien_embed(params, cfg, batch)
        interest = dien_interest(params, cfg, target, hist, batch["hist_mask"])
        return interest @ params["proj"]["w"][:, : cfg.embed_dim]
    # dcn_v2 / deepfm: mean-pool the user-side field embeddings.
    emb = embedding_lookup(params["tables"], cfg, batch["sparse"])
    return jnp.mean(emb.astype(cfg.dtype), axis=1)


def retrieval_scores(params: dict, cfg: RecsysConfig, batch: dict,
                     candidate_ids: jax.Array) -> jax.Array:
    """Score [B] users against [C] candidate items: one batched matmul.

    candidate_ids are GLOBAL rows into the concatenated table; the gathered
    [C, dim] block is the candidate tower.
    """
    u = user_embedding(params, cfg, batch)                     # [B, d]
    cand = jnp.take(params["tables"], candidate_ids, axis=0)   # [C, d]
    cand = shd.logical(cand.astype(cfg.dtype), "candidate", None)
    d = min(u.shape[-1], cand.shape[-1])
    scores = u[:, :d] @ cand[:, :d].T                          # [B, C]
    return shd.logical(scores, "batch", "candidate")
