"""Declarative, serializable routing policy: the `RouteSpec`.

SkewRoute's whole pitch (paper §4) is that the router is training-free
plain floats — trivially replicated and hot-swapped. `RouteSpec` makes
the ENTIRE policy that trivial, not just the thresholds: metric, tier
topology, cost model, calibration policy, and difficulty backend live in
one frozen, schema-versioned dataclass that round-trips through JSON.
Replicas ship the policy as bytes (`spec.to_json()`), not Python
objects; `repro.api.build(spec)` turns it back into a running session.

Validation happens at construction: the embedded router parameters are
checked by actually building the :class:`~repro.core.router.RouterConfig`
(so every `RouterConfig` invariant — metric name, ascending thresholds,
``top_k >= 1``, ``cumulative_p`` in (0, 1] — is inherited, never
re-implemented), and the spec-level fields (tier names, shares,
calibration knobs, backend name) are checked here.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Optional, Sequence

from repro.api import backends as _backends
from repro.core.cost import CostModel
from repro.core.router import RouterConfig
from repro.policies import PolicySpec, policy_spec_from_dict
from repro.serving.admission import AdmissionSpec

SCHEMA_VERSION = 1

#: Snapshot ENVELOPE contract (version 2) — what
#: ``SkewRouteSession.snapshot()`` emits::
#:
#:     {
#:       "envelope_version": 2,
#:       "policy": <RouteSpec.to_dict()>,      # frozen; never mutates
#:       "state":  {                           # everything that does
#:         "policy_fingerprint": <policy_fingerprint(spec)>,
#:         "thresholds": [...],                # live (post-hot-swap)
#:         "next_id": int,
#:         "stats": <DispatcherStats.state_dict()>,
#:         "calibrator": <StreamingCalibrator.state_dict()> | null,
#:         "pipeline": <PipelineTelemetry.state_dict()> | null,
#:         "admission": <AdmissionController.state_dict()> | null,
#:         "policy_state": <RoutingPolicy.state_dict()> | null,
#:       },
#:     }
#:
#: The split is the multi-replica story: the POLICY half is immutable
#: and shipped once (or derived from the shared spec); the STATE half is
#: what replicas exchange every sync round (see
#: ``distributed.replica_sync`` / ``serving.fabric``), stamped with the
#: policy fingerprint so state can never silently cross policies.
#: ``restore()`` also accepts the legacy flat version-1 layout
#: (``{"schema_version": 1, "spec": ..., <state keys inline>}``) behind
#: a warn-once deprecation shim.
ENVELOPE_VERSION = 2

CALIBRATION_POLICIES = ("static", "streaming")


def policy_fingerprint(spec: "RouteSpec") -> str:
    """Short stable digest of a policy: sha256 over the spec's canonical
    (sorted-key) JSON. State halves carry it so a replica refuses state
    minted under any other policy — cheaper to compare and to log than
    the full spec dict, and unlike object identity it survives the
    JSON round trip."""
    payload = spec.to_json(sort_keys=True)
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()[:16]


def _float_tuple(xs) -> tuple[float, ...]:
    return tuple(float(x) for x in xs)


@dataclasses.dataclass(frozen=True)
class CalibrationSpec:
    """How thresholds are maintained while serving.

    ``static``    — thresholds are fixed at whatever the spec says.
    ``streaming`` — a drift-aware :class:`~repro.core.streaming_calibrate.\
StreamingCalibrator` watches live tier shares and hot-swaps thresholds
    (knobs mirror its constructor).
    """

    policy: str = "static"
    target_shares: Optional[tuple[float, ...]] = None
    window: int = 4096
    min_samples: int = 256
    tolerance: float = 0.05
    cooldown: Optional[int] = None

    def __post_init__(self):
        if self.policy not in CALIBRATION_POLICIES:
            raise ValueError(f"unknown calibration policy {self.policy!r}; "
                             f"choose from {CALIBRATION_POLICIES}")
        # Mirror the StreamingCalibrator/SlidingWindow invariants so an
        # invalid policy fails at spec construction (and from_json), not
        # later inside build().
        if self.window < 2:
            raise ValueError(f"window must be >= 2, got {self.window}")
        if self.min_samples < 2:
            raise ValueError(f"min_samples must be >= 2, "
                             f"got {self.min_samples}")
        if self.min_samples > self.window:
            raise ValueError(
                f"min_samples ({self.min_samples}) > window "
                f"({self.window}) can never be reached — the window holds "
                f"at most `window` samples, so calibration would silently "
                f"never fire")
        if not 0.0 < self.tolerance < 1.0:
            raise ValueError(f"tolerance must be in (0, 1), "
                             f"got {self.tolerance}")
        if self.cooldown is not None and self.cooldown < 0:
            raise ValueError(f"cooldown must be >= 0, got {self.cooldown}")
        if self.target_shares is not None:
            object.__setattr__(self, "target_shares",
                               _float_tuple(self.target_shares))
        if self.policy == "streaming":
            if self.target_shares is None:
                raise ValueError("streaming calibration requires "
                                 "target_shares (one per tier, sum to 1)")
            s = self.target_shares
            if any(x < 0 for x in s) or abs(sum(s) - 1.0) > 1e-6:
                raise ValueError(f"target_shares must be >= 0 and sum to 1, "
                                 f"got {s}")

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "target_shares": (None if self.target_shares is None
                              else list(self.target_shares)),
            "window": self.window,
            "min_samples": self.min_samples,
            "tolerance": self.tolerance,
            "cooldown": self.cooldown,
        }


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """$-cost accounting knobs (maps onto :class:`repro.core.cost.CostModel`).

    ``cost_per_mtok = None`` means the paper's Table-4 pricing table; a
    mapping is normalized to a sorted item tuple so the frozen spec stays
    hashable (a policy value must be usable as a dict key / set member).
    """

    cost_per_mtok: Optional[Mapping[str, float]] = None
    n_triples: int = 100
    output_tokens: int = 120

    def __post_init__(self):
        if self.cost_per_mtok is not None:
            object.__setattr__(
                self, "cost_per_mtok",
                tuple(sorted((str(k), float(v))
                             for k, v in dict(self.cost_per_mtok).items())))
        if self.n_triples < 0:
            raise ValueError(f"n_triples must be >= 0, got {self.n_triples}")
        if self.output_tokens < 0:
            raise ValueError(f"output_tokens must be >= 0, "
                             f"got {self.output_tokens}")

    def build(self) -> CostModel:
        kw: dict[str, Any] = {"n_triples": self.n_triples,
                              "output_tokens": self.output_tokens}
        if self.cost_per_mtok is not None:
            kw["cost_per_mtok"] = dict(self.cost_per_mtok)
        return CostModel(**kw)

    def to_dict(self) -> dict:
        return {
            "cost_per_mtok": (None if self.cost_per_mtok is None
                              else dict(self.cost_per_mtok)),
            "n_triples": self.n_triples,
            "output_tokens": self.output_tokens,
        }


@dataclasses.dataclass(frozen=True)
class RouteSpec:
    """The entire routing policy as one frozen, JSON-round-trippable value.

    ``tier_names`` are display/telemetry labels (``len(thresholds) + 1``
    of them, smallest model first); ``tier_models`` are the cost-model
    keys (default: the names themselves, which matches the seed examples
    where names ARE paper model ids like ``qwen7b``).
    """

    metric: str = "gini"
    thresholds: tuple[float, ...] = (0.0,)
    cumulative_p: float = 0.95
    top_k: int = 100
    tier_names: tuple[str, ...] = ("small", "large")
    tier_models: Optional[tuple[str, ...]] = None
    backend: str = "auto"
    # Batch-size crossover of the ``auto`` backend: batches below this go
    # to the single-program XLA oracle, at/above it to the fused kernels.
    # Policy, not environment — serialized so every replica routes the
    # same request batch the same way. (Added with a default, so
    # schema-version-1 payloads without the key still load.)
    crossover_batch: int = _backends.DEFAULT_CROSSOVER_BATCH
    micro_batch: int = 8
    calibration: CalibrationSpec = dataclasses.field(
        default_factory=CalibrationSpec)
    cost: CostSpec = dataclasses.field(default_factory=CostSpec)
    # Load-aware admission control (cost-budget feedback + SLO tier-
    # spill); None disables it and reproduces pre-admission routing
    # bit-for-bit. (Added with a default, so schema-version-1 payloads
    # without the key still load.)
    admission: Optional[AdmissionSpec] = None
    # Routing policy: what the session DOES with the skew metrics
    # (`repro.policies` registry). None selects the default threshold
    # policy — today's compare, bit-for-bit — and is OMITTED from the
    # serialized dict so pre-policy payloads, envelopes, and fingerprints
    # are byte-identical. (Added with a default, so schema-version-1
    # payloads without the key still load.)
    policy: Optional[PolicySpec] = None
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.schema_version != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported RouteSpec schema_version "
                f"{self.schema_version!r}; this build understands "
                f"version {SCHEMA_VERSION}")
        if not isinstance(self.calibration, CalibrationSpec):
            raise TypeError("calibration must be a CalibrationSpec")
        if not isinstance(self.cost, CostSpec):
            raise TypeError("cost must be a CostSpec")
        object.__setattr__(self, "thresholds", _float_tuple(self.thresholds))
        object.__setattr__(self, "tier_names",
                           tuple(str(n) for n in self.tier_names))
        if self.tier_models is not None:
            object.__setattr__(self, "tier_models",
                               tuple(str(m) for m in self.tier_models))
        # Router invariants: inherit every RouterConfig check by building one.
        router = self.router_config()
        if len(self.tier_names) != router.n_tiers:
            raise ValueError(f"{router.n_tiers} tiers "
                             f"(len(thresholds) + 1) but "
                             f"{len(self.tier_names)} tier_names")
        if (self.tier_models is not None
                and len(self.tier_models) != router.n_tiers):
            raise ValueError(f"{router.n_tiers} tiers but "
                             f"{len(self.tier_models)} tier_models")
        if self.micro_batch < 1:
            raise ValueError(f"micro_batch must be >= 1, "
                             f"got {self.micro_batch}")
        if self.crossover_batch < 1:
            raise ValueError(f"crossover_batch must be >= 1, "
                             f"got {self.crossover_batch}")
        if (_backends.resolve_backend_name(self.backend)
                not in _backends.available_backends()):
            raise ValueError(
                f"unknown difficulty backend {self.backend!r}; "
                f"choose from {_backends.available_backends()}")
        if (self.calibration.policy == "streaming"
                and len(self.calibration.target_shares) != router.n_tiers):
            raise ValueError(
                f"{router.n_tiers} tiers but "
                f"{len(self.calibration.target_shares)} calibration "
                f"target_shares")
        if self.admission is not None:
            if not isinstance(self.admission, AdmissionSpec):
                raise TypeError("admission must be an AdmissionSpec or None")
            if self.calibration.policy != "streaming":
                raise ValueError(
                    "admission control requires streaming calibration — "
                    "its window is the quantile source for budget re-fits "
                    "and the spill marginal band; set "
                    "calibration=CalibrationSpec(policy='streaming', ...)")
            if router.n_tiers < 2:
                raise ValueError("admission control needs >= 2 tiers "
                                 "(there is nowhere to spill)")
        if self.policy is not None:
            if not isinstance(self.policy, PolicySpec):
                raise TypeError("policy must be a PolicySpec or None")
            # Cross-field invariants (tier counts, top_k bounds) live on
            # the policy spec itself.
            self.policy.validate(self)

    # -- derived views --------------------------------------------------------

    @property
    def n_tiers(self) -> int:
        return len(self.thresholds) + 1

    def router_config(self) -> RouterConfig:
        return RouterConfig(metric=self.metric, thresholds=self.thresholds,
                            cumulative_p=self.cumulative_p, top_k=self.top_k)

    def cost_model(self) -> CostModel:
        return self.cost.build()

    def models(self) -> tuple[str, ...]:
        return self.tier_models if self.tier_models is not None \
            else self.tier_names

    def with_thresholds(self, thresholds: Sequence[float]) -> "RouteSpec":
        """The hot-swap primitive: same policy, new plain-float thresholds."""
        return dataclasses.replace(self, thresholds=_float_tuple(thresholds))

    # -- serialization --------------------------------------------------------

    def to_dict(self) -> dict:
        d = {
            "schema_version": self.schema_version,
            "metric": self.metric,
            "thresholds": list(self.thresholds),
            "cumulative_p": self.cumulative_p,
            "top_k": self.top_k,
            "tier_names": list(self.tier_names),
            "tier_models": (None if self.tier_models is None
                            else list(self.tier_models)),
            "backend": self.backend,
            "crossover_batch": self.crossover_batch,
            "micro_batch": self.micro_batch,
            "calibration": self.calibration.to_dict(),
            "cost": self.cost.to_dict(),
            "admission": (None if self.admission is None
                          else self.admission.to_dict()),
        }
        # Omitted (not null) when default: keeps pre-policy payloads,
        # snapshot-envelope policy halves, and policy fingerprints
        # byte-identical to builds that predate the policy layer.
        if self.policy is not None:
            d["policy"] = self.policy.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RouteSpec":
        d = dict(d)
        version = d.get("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported RouteSpec schema_version {version!r}; "
                f"this build understands version {SCHEMA_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown RouteSpec fields {sorted(unknown)}; "
                             f"known fields: {sorted(known)}")
        calib = d.get("calibration")
        if isinstance(calib, Mapping):
            ck = {f.name for f in dataclasses.fields(CalibrationSpec)}
            unknown = set(calib) - ck
            if unknown:
                raise ValueError(f"unknown CalibrationSpec fields "
                                 f"{sorted(unknown)}")
            ts = calib.get("target_shares")
            d["calibration"] = CalibrationSpec(
                **{**dict(calib),
                   "target_shares": None if ts is None else tuple(ts)})
        cost = d.get("cost")
        if isinstance(cost, Mapping):
            ck = {f.name for f in dataclasses.fields(CostSpec)}
            unknown = set(cost) - ck
            if unknown:
                raise ValueError(f"unknown CostSpec fields {sorted(unknown)}")
            d["cost"] = CostSpec(**dict(cost))
        admission = d.get("admission")
        if isinstance(admission, Mapping):
            d["admission"] = AdmissionSpec.from_dict(admission)
        policy = d.get("policy")
        if isinstance(policy, Mapping):
            d["policy"] = policy_spec_from_dict(policy)
        for key in ("thresholds", "tier_names", "tier_models"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        return cls(**d)

    def to_json(self, **dumps_kwargs) -> str:
        return json.dumps(self.to_dict(), **dumps_kwargs)

    @classmethod
    def from_json(cls, payload: str) -> "RouteSpec":
        return cls.from_dict(json.loads(payload))

    @classmethod
    def from_router_config(cls, config: RouterConfig,
                           tier_names: Sequence[str],
                           **overrides) -> "RouteSpec":
        """Lift an old-API ``RouterConfig`` (+ tier names) into a spec."""
        return cls(metric=config.metric, thresholds=config.thresholds,
                   cumulative_p=config.cumulative_p, top_k=config.top_k,
                   tier_names=tuple(tier_names), **overrides)
