"""Mesh-sharded dispatch: the ``sharded`` difficulty backend.

The routing decision is embarrassingly request-parallel — every row's
skew metrics depend only on that row's top-K scores — so the
millions-of-users fan-out is a textbook ``shard_map``: split the
dispatch batch over the mesh's data axes (the logical ``"request"``
axis from `distributed/sharding.py`), run the SAME fused
retrieve-to-decision program per shard, and concatenate the tier ids.
Candidate scoring additionally shards the ``"candidate"`` axis (the
rules-table entry that sat unused since the sharding layer landed) over
the model axis, with one tiled ``all_gather`` reassembling the per-shard
logits before the global top-k.

Parity with the ``auto`` backend is bit-for-bit BY CONSTRUCTION, not by
tolerance:

* the oracle-vs-fused crossover is decided on the GLOBAL batch size
  (the wrapped :class:`~repro.api.backends.AutoBackend` picks), so a
  B=8 batch routes through the oracle program on every shard exactly as
  ``auto`` would route it unsharded;
* each shard runs the identical jitted programs
  (`core.router._decision_program` / `score_candidates` +
  `topk_sigmoid_decision`) on its contiguous row block — row-local
  float math, no cross-row reductions, no re-associated sums;
* per-shard bucket padding follows the dispatcher's convention (padded
  rows are well-defined garbage, sliced off on the way out).

The mesh is ENVIRONMENT, not policy: like interpret-vs-compiled it is
resolved at construction from the local devices and never serialized —
a `RouteSpec(backend="sharded")` restored on a 1-device host runs the
same program on a degenerate mesh and produces the same decisions.

Routing policies (`repro.policies`) compose transparently: the sharded
program emits the same threshold tiers/difficulty/metrics contract as
``auto``, and the policy transform (cascade escalation, depth pick,
mode pricing) runs on the gathered host-side result — so e.g. a
cascade spec routes bit-for-bit identically under ``sharded`` and
``auto`` (asserted in tests/test_sharded_backend.py).
"""

from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.api.backends import AutoBackend, DEFAULT_CROSSOVER_BATCH
from repro.core.router import (RetrievedRouteResult, RouteBatchResult,
                               RouterConfig, _decision_program,
                               _thresholds_array, score_candidates,
                               topk_sigmoid_decision)
from repro.distributed import sharding as shd
from repro.launch.mesh import make_auto_mesh
from repro.serving.scheduler import bucket_size

#: Per-SHARD batch buckets. Smaller than the dispatcher's global buckets
#: (8..4096): with R shards a global 1024-row batch is 128 rows each, and
#: a 1-bucket keeps the degenerate tiny-batch case from padding 8x.
SHARD_BUCKETS = (1, 8, 64, 256, 1024)


def make_dispatch_mesh(n_request: Optional[int] = None,
                       n_candidate: int = 1) -> Mesh:
    """A (data=n_request, model=n_candidate) mesh for sharded dispatch.

    ``n_request=None`` takes every local device not claimed by the
    candidate axis — the serving default (CI forces 8 host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``). Under
    `DEFAULT_RULES` the logical ``"request"`` axis lands on ``data`` and
    ``"candidate"`` on ``model``.
    """
    if n_candidate < 1:
        raise ValueError(f"n_candidate must be >= 1, got {n_candidate}")
    n_dev = jax.local_device_count()
    if n_request is None:
        n_request = max(1, n_dev // n_candidate)
    if n_request * n_candidate > n_dev:
        raise ValueError(
            f"dispatch mesh ({n_request} request x {n_candidate} "
            f"candidate) wants {n_request * n_candidate} devices but only "
            f"{n_dev} are visible")
    return make_auto_mesh((n_request, n_candidate), ("data", "model"))


def _dim(mesh: Mesh, axis) -> int:
    return shd._axis_size(mesh, axis)


class ShardedBackend:
    """Mesh-parallel dispatch over the logical ``request``/``candidate``
    axes — ``auto``'s crossover policy, fanned out with ``shard_map``.

    ``mesh=None`` builds the full-host dispatch mesh lazily on first
    use, so constructing the backend (e.g. during spec validation or
    ``available_backends()``) never touches device state.
    """

    name = "sharded"

    def __init__(self, crossover_batch: int = DEFAULT_CROSSOVER_BATCH,
                 interpret: Optional[bool] = None,
                 mesh: Optional[Mesh] = None):
        self.auto = AutoBackend(crossover_batch=crossover_batch,
                                interpret=interpret)
        self._mesh = mesh
        self._programs: dict[tuple, object] = {}

    def attach_obs(self, obs) -> None:
        """Crossover-pick counters live on the inner ``auto`` (small
        batches take its oracle path; sharded programs count as fused)."""
        self.auto.attach_obs(obs)

    # -- mesh plumbing --------------------------------------------------------

    @property
    def mesh(self) -> Mesh:
        if self._mesh is None:
            self._mesh = make_dispatch_mesh()
        return self._mesh

    @property
    def crossover_batch(self) -> int:
        return self.auto.crossover_batch

    @property
    def interpret(self) -> Optional[bool]:
        return self.auto.interpret

    def effective_interpret(self) -> bool:
        return self.auto.effective_interpret()

    def _specs(self) -> tuple[P, P, P, int, int]:
        """(row, vec, feat) PartitionSpecs + (request, candidate) sizes
        under the logical rules, resolved against this backend's mesh."""
        mesh = self.mesh
        with shd.use_mesh(mesh):
            row = shd.spec_for("request", None)          # [B, K] blocks
            vec = shd.spec_for("request")                # [B] blocks
            feat = shd.spec_for("request", "candidate", None)  # [B, N, D]
        r = _dim(mesh, shd.DEFAULT_RULES["request"])
        c = _dim(mesh, shd.DEFAULT_RULES["candidate"])
        return row, vec, feat, r, c

    def _pad_rows(self, b: int, r: int) -> int:
        """Global padded batch: every shard gets the same bucketed block."""
        return bucket_size(-(-b // r), SHARD_BUCKETS) * r

    # -- the DifficultyBackend contract ---------------------------------------

    def metrics(self, scores_desc, p_cdf: float = 0.95, n_valid=None):
        return self.route_batch(
            scores_desc,
            RouterConfig(metric="gini", thresholds=(0.0,),
                         cumulative_p=p_cdf), n_valid=n_valid).metrics

    def route_batch(self, scores_desc, config: RouterConfig, n_valid=None):
        scores = jnp.atleast_2d(jnp.asarray(scores_desc))
        b, k = scores.shape
        use_kernel = self.auto.pick(b)._use_kernel  # GLOBAL-size crossover
        interpret = self.effective_interpret()
        row, vec, _, r, _ = self._specs()
        bpad = self._pad_rows(b, r)
        ragged = n_valid is not None
        if ragged:
            nv = np.full(bpad, k, np.int32)
            nv[:b] = np.asarray(n_valid, np.int32)
            nv[b:] = 1  # padded rows: degenerate but well-defined
        if bpad != b:
            scores = jnp.concatenate(
                [scores, jnp.zeros((bpad - b, k), scores.dtype)])
        prog = self._batch_program(config.metric, config.cumulative_p,
                                   ragged, use_kernel, interpret, row, vec)
        thr = _thresholds_array(config.thresholds)
        if ragged:
            tiers, diff, metrics = prog(scores, jnp.asarray(nv), thr)
        else:
            tiers, diff, metrics = prog(scores, thr)
        return RouteBatchResult(tiers=tiers[:b], difficulty=diff[:b],
                                metrics=metrics[:b])

    def route_retrieved(self, feats, query_emb, params: Mapping,
                        config: RouterConfig,
                        n_cand=None) -> RetrievedRouteResult:
        feats = jnp.asarray(feats)
        qemb = jnp.asarray(query_emb)
        b, n, _ = feats.shape
        interp = self.effective_interpret()
        # same fallback as the auto/fused path: interpret-mode Pallas
        # loses to plain XLA on the scoring MLP, so off-TPU the fused
        # program traces the XLA implementations
        use_kernels = self.auto.pick(b)._use_kernel and not interp
        row, vec, feat, r, c = self._specs()
        # candidate-axis sharding needs an even split; otherwise the
        # candidate dim stays replicated (request-only parallelism)
        shard_cand = c > 1 and n % c == 0
        if not shard_cand:
            feat = P(feat[0], None, None)
        bpad = self._pad_rows(b, r)
        ragged = n_cand is not None
        if ragged:
            nc = np.full(bpad, n, np.int32)
            nc[:b] = np.asarray(n_cand, np.int32)
            nc[b:] = 1
        if bpad != b:
            feats = jnp.concatenate(
                [feats, jnp.zeros((bpad - b,) + feats.shape[1:],
                                  feats.dtype)])
            qemb = jnp.concatenate(
                [qemb, jnp.zeros((bpad - b, qemb.shape[1]), qemb.dtype)])
        k = min(config.top_k, n)
        prog = self._retrieved_prog(config.metric, config.cumulative_p, k,
                                    ragged, use_kernels, interp, shard_cand,
                                    row, vec, feat)
        thr = _thresholds_array(config.thresholds)
        args = (feats, qemb, params["w1_t"], params["w1_q"], params["b1"],
                params["w2"], params["b2"])
        if ragged:
            out = prog(*args, jnp.asarray(nc), thr)
        else:
            out = prog(*args, thr)
        idx, probs, nv, tiers, diff, metrics = out
        return RetrievedRouteResult(
            indices=idx[:b], probs=probs[:b], n_valid=nv[:b],
            tiers=tiers[:b], difficulty=diff[:b], metrics=metrics[:b])

    # -- cached shard_map programs --------------------------------------------

    def _batch_program(self, metric: str, p_cdf: float, ragged: bool,
                       use_kernel: bool, interpret: bool, row: P, vec: P):
        key = ("batch", metric, p_cdf, ragged, use_kernel, interpret)
        prog = self._programs.get(key)
        if prog is not None:
            return prog

        def body_ragged(scores_s, nv_s, thr):
            return _decision_program(
                scores_s, thr, nv_s, metric=metric, p_cdf=p_cdf,
                ragged=True, use_kernel=use_kernel, interpret=interpret)

        def body_dense(scores_s, thr):
            return _decision_program(
                scores_s, thr, None, metric=metric, p_cdf=p_cdf,
                ragged=False, use_kernel=use_kernel, interpret=interpret)

        in_specs = (row, vec, P()) if ragged else (row, P())
        prog = jax.jit(shd.shard_map_compat(
            body_ragged if ragged else body_dense, self.mesh,
            in_specs, (vec, vec, row)))
        self._programs[key] = prog
        return prog

    def _retrieved_prog(self, metric: str, p_cdf: float, top_k: int,
                        ragged: bool, use_kernels: bool, interpret: bool,
                        shard_cand: bool, row: P, vec: P, feat: P,
                        tile: int = 128):
        key = ("retrieved", metric, p_cdf, top_k, ragged, use_kernels,
               interpret, shard_cand)
        prog = self._programs.get(key)
        if prog is not None:
            return prog

        def tail(logits, nc_s, thr):
            if shard_cand:  # reassemble the candidate axis for global top-k
                logits = jax.lax.all_gather(logits, "model", axis=1,
                                            tiled=True)
            return topk_sigmoid_decision(
                logits, thr, nc_s, top_k=top_k, metric=metric, p_cdf=p_cdf,
                ragged=ragged, use_kernel=use_kernels, interpret=interpret)

        def body_ragged(feats_s, qemb_s, w1_t, w1_q, b1, w2, b2, nc_s, thr):
            logits = score_candidates(
                feats_s, qemb_s, w1_t, w1_q, b1, w2, b2,
                use_kernels=use_kernels, interpret=interpret, tile=tile)
            return tail(logits, nc_s, thr)

        def body_dense(feats_s, qemb_s, w1_t, w1_q, b1, w2, b2, thr):
            logits = score_candidates(
                feats_s, qemb_s, w1_t, w1_q, b1, w2, b2,
                use_kernels=use_kernels, interpret=interpret, tile=tile)
            return tail(logits, None, thr)

        qspec = P(row[0], None)
        params = (P(),) * 5
        in_specs = ((feat, qspec) + params + ((vec, P()) if ragged
                                             else (P(),)))
        out_specs = (row, row, vec, vec, vec, row)
        prog = jax.jit(shd.shard_map_compat(
            body_ragged if ragged else body_dense, self.mesh,
            in_specs, out_specs))
        self._programs[key] = prog
        return prog
