"""`SkewRouteSession`: the one blessed serving facade.

``session = repro.api.build(spec)`` composes everything the old surface
made callers hand-wire across four modules — threshold router, difficulty
backend, streaming calibrator, micro-batch queues, engine-bank runners,
cost telemetry — behind three verbs:

* ``session.route(scores)``          — batched tier assignment (fast path)
* ``session.submit(scores, items)``  — route AND pump per-tier micro-
  batches through the tier runners (needs ``runners=`` at build time)
* ``session.snapshot()/restore()``   — the complete mutable routing state
  (hot-swapped thresholds, calibrator window, telemetry counters) as a
  JSON-serializable dict, so multi-replica deployments can ship policy
  AND state as bytes.

The session owns no novel logic: it builds the same
:class:`~repro.serving.router_service.SkewRouteDispatcher` /
:class:`~repro.serving.pipeline.ServingPipeline` internals (suppressing
their deprecation shims), which keeps the old API importable during the
migration window.
"""

from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import (Callable, Mapping, Optional, Protocol, Sequence, Union,
                    runtime_checkable)

import numpy as np

from repro.api import backends as _backends
from repro.api.spec import (ENVELOPE_VERSION, SCHEMA_VERSION, RouteSpec,
                            policy_fingerprint)
from repro.obs import NULL_OBS, Observability
from repro.serving import _deprecation
from repro.serving.admission import AdmissionController
from repro.serving.pipeline import PipelineTelemetry, ServingPipeline
from repro.serving.router_service import (BatchDispatchResult, DispatchRecord,
                                          SkewRouteDispatcher)

Runners = Mapping[int, Callable[[list], object]]


@runtime_checkable
class EngineBankLike(Protocol):
    """Anything exporting per-tier runner callables (e.g. an
    :class:`~repro.serving.engine.EngineBank`)."""

    def runners(self) -> Runners: ...


class SkewRouteSession:
    """A running routing policy built from a :class:`RouteSpec`."""

    def __init__(self, spec: RouteSpec,
                 runners: Optional[Union[Runners, EngineBankLike]] = None,
                 obs: Optional[Observability] = None):
        self.spec = spec
        # Observability is RUNTIME configuration (like runners): an
        # `Observability` plane to record into, or None for the no-op
        # plane. Never serialized into the spec; metric VALUES ride the
        # snapshot envelope's state half when enabled (state["obs"]),
        # trace events never do (local measurement history).
        self.obs = obs or NULL_OBS
        # crossover_batch is policy and rides in the spec; interpret mode
        # is environment and is NEVER passed here — backends re-resolve
        # it per call (see repro.kernels.device.default_interpret), so a
        # snapshot taken on TPU restores cleanly on CPU and vice versa.
        backend_kwargs = ({"crossover_batch": spec.crossover_batch}
                          if spec.backend in ("auto", "sharded") else {})
        self.backend = _backends.make_backend(spec.backend, **backend_kwargs)
        if hasattr(self.backend, "attach_obs"):
            self.backend.attach_obs(self.obs)
        # One facade-level lock makes session verbs atomic w.r.t. each
        # other (the dispatcher's internal lock only covers its own
        # counters, not the pipeline queues a concurrent submit mutates).
        self._lock = threading.RLock()
        with _deprecation.suppress():
            # The routing policy (what to DO with the skew metrics):
            # spec.policy=None builds the default threshold policy —
            # today's compare, bit-for-bit.
            from repro.policies import build_policy
            self.policy = build_policy(
                spec.policy, n_tiers=spec.n_tiers, tier_models=spec.models(),
                cost_model=spec.cost_model())
            self.dispatcher = SkewRouteDispatcher(
                spec.router_config(), spec.models(),
                cost_model=spec.cost_model(), backend=self.backend,
                policy=self.policy, obs=self.obs)
            cal = spec.calibration
            if cal.policy == "streaming":
                self.dispatcher.attach_calibrator(
                    cal.target_shares, window=cal.window,
                    min_samples=cal.min_samples, tolerance=cal.tolerance,
                    cooldown=cal.cooldown)
            self.admission: Optional[AdmissionController] = None
            if spec.admission is not None:
                if runners is None:
                    raise ValueError(
                        "spec.admission is set but no runners were given; "
                        "admission control lives on the submit() path — "
                        "pass runners= (a {tier: callable} dict or an "
                        "EngineBank) to repro.api.build")
                self.admission = AdmissionController(
                    self.dispatcher.calibrator, spec.cost_model(),
                    spec.models(), spec.admission, obs=self.obs)
            self.pipeline: Optional[ServingPipeline] = None
            if runners is not None:
                if isinstance(runners, EngineBankLike):
                    runners = runners.runners()
                self.pipeline = ServingPipeline(
                    self.dispatcher, dict(runners),
                    micro_batch=spec.micro_batch,
                    admission=self.admission, obs=self.obs)

    # -- views ----------------------------------------------------------------

    @property
    def tier_names(self) -> tuple[str, ...]:
        return self.spec.tier_names

    @property
    def thresholds(self) -> tuple[float, ...]:
        """CURRENT thresholds (may differ from the spec after hot-swaps)."""
        return self.dispatcher.router.thresholds

    @property
    def stats(self):
        return self.dispatcher.stats

    @property
    def calibrator(self):
        return self.dispatcher.calibrator

    @property
    def executed(self) -> list:
        """Micro-batches run so far (`ExecutedBatch` telemetry objects);
        empty for runner-less sessions — the facade-safe way to reach
        per-batch runner results without touching pipeline internals."""
        return [] if self.pipeline is None else list(self.pipeline.executed)

    def current_spec(self) -> RouteSpec:
        """The spec as-of-now: original policy + live thresholds. Ship
        ``session.current_spec().to_json()`` to bring up a replica that
        starts from this session's calibration point."""
        return self.spec.with_thresholds(self.thresholds)

    # -- routing --------------------------------------------------------------

    def route(self, scores_desc: np.ndarray,
              n_valid: Optional[np.ndarray] = None,
              self_scores: Optional[np.ndarray] = None
              ) -> BatchDispatchResult:
        """[B, K] descending top-K scores -> full dispatch result (tiers,
        difficulty, all four metrics, per-request records).

        ``self_scores``: optional [B] engine self-uncertainty (higher =
        less confident) that confidence-aware policies (cascade) fold
        into the decision; ignored by the default threshold policy.
        """
        return self.dispatcher.dispatch_batch(
            np.atleast_2d(np.asarray(scores_desc)), n_valid=n_valid,
            return_details=True, self_scores=self_scores)

    def route_one(self, scores_desc: np.ndarray,
                  n_valid: Optional[int] = None) -> DispatchRecord:
        """One request (same fused path, batch of one)."""
        return self.dispatcher.dispatch(scores_desc, n_valid=n_valid)

    def route_retrieved(self, feats: np.ndarray, query_emb: np.ndarray,
                        scorer_params: Mapping,
                        n_cand: Optional[np.ndarray] = None):
        """End-to-end routing from candidate features: Pallas triple
        scoring -> device top-k -> skew metrics -> tier decision as ONE
        device program (no host hop between retrieval and dispatch).

        ``feats``: [B, N, Dt] per-query candidate features (see
        `repro.retrieval.scorer.batch_triple_features`); ``query_emb``:
        [B, Dq]; ``scorer_params``: the trained scorer weight dict (its
        layout is the kernel's). Returns a
        :class:`~repro.serving.router_service.RetrievedDispatchResult`
        — dispatcher telemetry and streaming calibration update exactly
        as for :meth:`route`.
        """
        return self.dispatcher.dispatch_retrieved(
            np.asarray(feats), np.asarray(query_emb), scorer_params,
            n_cand=n_cand)

    def submit(self, scores_desc: np.ndarray,
               payloads: Optional[Sequence] = None,
               n_valid: Optional[np.ndarray] = None,
               self_scores: Optional[np.ndarray] = None
               ) -> BatchDispatchResult:
        """Route a batch and pump full per-tier micro-batches through the
        tier runners. Requires the session to be built with ``runners=``.
        ``self_scores`` feeds confidence-aware policies as in
        :meth:`route`."""
        if self.pipeline is None:
            raise RuntimeError(
                "session was built without runners; pass runners= (a "
                "{tier: callable} dict or an EngineBank) to repro.api.build "
                "to use submit()")
        with self._lock:
            return self.pipeline.submit(
                np.atleast_2d(np.asarray(scores_desc)),
                payloads=payloads, n_valid=n_valid, self_scores=self_scores)

    def flush(self) -> int:
        """Drain partial micro-batches; returns requests executed."""
        with self._lock:
            return 0 if self.pipeline is None else self.pipeline.flush()

    def observe_tier_load(self, tier: int, queue_depth: int,
                          p99_latency: Optional[float] = None) -> None:
        """Feed a replica pool's load (waiting depth + p99, nan-safe) to
        the admission controller — whoever owns the TierSchedulers calls
        this before submitting (see serving.loadgen.runner)."""
        if self.admission is None:
            raise RuntimeError(
                "session has no admission controller; set spec.admission "
                "(an AdmissionSpec) to enable load-aware serving")
        with self._lock:
            self.admission.observe_tier_load(tier, queue_depth,
                                             p99_latency=p99_latency)

    def telemetry(self) -> dict:
        """Merged dispatcher + pipeline + admission counters
        (JSON-friendly)."""
        s = self.dispatcher.stats
        out = {
            "backend": self.backend.name,
            "thresholds": list(self.thresholds),
            **s.state_dict(),
            "large_call_ratio": s.large_call_ratio,
        }
        if self.pipeline is not None:
            out["pipeline"] = self.pipeline.stats()
        if self.admission is not None:
            out["admission"] = self.admission.telemetry()
        out["policy"] = self.policy.telemetry()
        if self.obs.enabled:
            out["obs"] = self.obs.telemetry()
        return out

    # -- serializable state ---------------------------------------------------

    def snapshot(self) -> dict:
        """The session as a schema-versioned ENVELOPE: a frozen ``policy``
        half (the spec) and a mutable ``state`` half (live thresholds,
        dispatcher telemetry, the streaming calibrator's exact window,
        the admission controller's full state) — the contract is
        documented at :data:`repro.api.spec.ENVELOPE_VERSION`.

        :meth:`restore` rebuilds all of it bit-exactly; the replica-sync
        fabric ships ONLY the ``state`` half (stamped with the policy
        fingerprint) between replicas that already share the policy.
        Pending micro-batch payloads are arbitrary Python objects and are
        NOT serializable: ``flush()`` before snapshotting.
        """
        # the session lock serializes against submit(); the dispatcher
        # lock against direct old-API dispatch_batch() callers
        with self._lock:
            if self.pipeline is not None:
                depths = {t: len(q) for t, q in self.pipeline.queues.items()
                          if len(q)}
                if depths:
                    raise RuntimeError(
                        f"cannot snapshot with pending micro-batch payloads "
                        f"(queue depths {depths}); call flush() first")
            d = self.dispatcher
            with d._lock:
                state = {
                    "policy_fingerprint": policy_fingerprint(self.spec),
                    "thresholds": list(d.router.thresholds),
                    "next_id": d._next_id,
                    "stats": d.stats.state_dict(),
                    "calibrator": (None if d.calibrator is None
                                   else d.calibrator.state_dict()),
                    "pipeline": None,
                    "admission": (None if self.admission is None
                                  else self.admission.state_dict()),
                    # None for stateless policies (the default threshold
                    # policy included), so default-policy envelopes stay
                    # shape-compatible with pre-policy builds.
                    "policy_state": d.policy.state_dict(),
                }
            if self.pipeline is not None:
                state["pipeline"] = self.pipeline.telemetry.state_dict()
            if self.obs.enabled:
                # Metric values ride the envelope ONLY for obs-enabled
                # sessions, so obs-less envelopes stay byte-identical to
                # pre-obs builds. Trace events deliberately do not ride
                # (a restored replica starts a fresh timeline).
                state["obs"] = self.obs.state_dict()
            return {
                "envelope_version": ENVELOPE_VERSION,
                "policy": self.spec.to_dict(),
                "state": state,
            }

    _STATE_KEYS = ("thresholds", "next_id", "stats", "calibrator",
                   "pipeline", "admission", "policy_state")

    def _state_of(self, snap: Mapping) -> Mapping:
        """Validate an envelope (or legacy flat v1 snapshot) against this
        session's policy and return its state half."""
        if "envelope_version" in snap:
            ver = snap["envelope_version"]
            if ver != ENVELOPE_VERSION:
                raise ValueError(
                    f"unsupported snapshot envelope_version {ver!r}; this "
                    f"build understands version {ENVELOPE_VERSION}")
            if snap.get("policy") != self.spec.to_dict():
                raise ValueError(
                    "snapshot was taken under a different RouteSpec; build "
                    "a session from RouteSpec.from_dict(snapshot['policy']) "
                    "instead")
            return snap["state"]
        # -- legacy flat v1: {"schema_version": 1, "spec": ..., <state>} --
        if snap.get("schema_version") != SCHEMA_VERSION:
            raise ValueError(
                f"unsupported snapshot schema_version "
                f"{snap.get('schema_version')!r}; this build understands "
                f"envelope version {ENVELOPE_VERSION} (and the legacy flat "
                f"version {SCHEMA_VERSION})")
        _deprecation.warn_once(
            "snapshot-v1",
            "flat v1 session snapshots are deprecated; re-snapshot to get "
            "the versioned policy/state envelope (see "
            "repro.api.spec.ENVELOPE_VERSION for the contract)")
        if snap["spec"] != self.spec.to_dict():
            raise ValueError("snapshot was taken under a different "
                             "RouteSpec; build a session from "
                             "RouteSpec.from_dict(snapshot['spec']) instead")
        return {k: snap.get(k) for k in self._STATE_KEYS}

    def restore(self, snap: Mapping) -> "SkewRouteSession":
        """Load a :meth:`snapshot` back into this session (in place).

        Accepts the versioned envelope AND (behind a warn-once shim) the
        legacy flat v1 layout. Either way the snapshot must come from a
        session with an IDENTICAL spec — restoring state across different
        policies is a category error the policy check turns into a loud
        one.
        """
        state = self._state_of(snap)
        with self._lock:
            return self._restore_locked(state)

    def restore_state(self, state: Mapping) -> "SkewRouteSession":
        """Load ONLY the ``state`` half of an envelope — what the replica
        fabric ships between sessions that already share the policy.

        The state's ``policy_fingerprint`` must match this session's
        spec: state minted under a different policy is refused loudly
        (there is no "close enough" for thresholds fit against another
        policy's calibration window).
        """
        fp = state.get("policy_fingerprint")
        ours = policy_fingerprint(self.spec)
        if fp != ours:
            raise ValueError(
                f"state policy_fingerprint {fp!r} does not match this "
                f"session's policy ({ours!r}); state only transfers "
                f"between sessions built from the SAME RouteSpec")
        with self._lock:
            return self._restore_locked(state)

    def _restore_locked(self, state: Mapping) -> "SkewRouteSession":
        if self.pipeline is not None and self.pipeline.pending():
            depths = {t: len(q) for t, q in self.pipeline.queues.items()
                      if len(q)}
            raise RuntimeError(
                f"cannot restore over pending micro-batch payloads "
                f"(queue depths {depths}); call flush() first")
        adm_state = state.get("admission")
        if (adm_state is None) != (self.admission is None):
            raise ValueError("stateshot and session disagree on whether "
                             "an admission controller is attached")
        d = self.dispatcher
        with d._lock:
            d.router = dataclasses.replace(
                d.router, thresholds=tuple(state["thresholds"]))
            d._next_id = int(state["next_id"])
            d.stats.load_state_dict(state["stats"])
            cal_state = state.get("calibrator")
            if (cal_state is None) != (d.calibrator is None):
                raise ValueError("stateshot and session disagree on whether "
                                 "a streaming calibrator is attached")
            if cal_state is not None:
                d.calibrator.load_state_dict(cal_state)
                d.router = d.calibrator.config
            # Absent in pre-policy (PR 8) envelopes and legacy v1 flats:
            # get() -> None, which every policy accepts as "reset to
            # spec-initial". A present-but-foreign block refuses loudly
            # inside load_state_dict.
            d.policy.load_state_dict(state.get("policy_state"))
        if adm_state is not None:
            self.admission.load_state_dict(adm_state)
        # pipeline presence may legitimately differ (runners are runtime,
        # not policy) — but state must never silently cross the gap
        pipe_state = state.get("pipeline")
        if pipe_state is not None and self.pipeline is None:
            warnings.warn(
                "stateshot carries pipeline telemetry but this session "
                "was built without runners; those counters are not "
                "restored", stacklevel=3)
        elif self.pipeline is not None:
            if pipe_state is None:
                warnings.warn(
                    "stateshot has no pipeline telemetry; this session's "
                    "pipeline counters are reset to zero", stacklevel=3)
                pipe_state = PipelineTelemetry(
                    tier_counts={t: 0 for t in self.pipeline.queues}
                ).state_dict()
            # the contract lives in ServingPipeline.load_telemetry: queue
            # payloads don't round-trip, counters restore on drained
            # queues only (and executed history resets to match)
            self.pipeline.load_telemetry(pipe_state)
        if self.obs.enabled:
            # Load the registry dump when the state carries one (absent
            # in obs-less / pre-obs envelopes -> registry resets), then
            # re-point every component's mirrors at its restored
            # counters so registry views and counter views agree no
            # matter where the state came from.
            self.obs.load_state_dict(state.get("obs"))
            d._obs_resync()
            if self.admission is not None:
                self.admission._obs_resync()
            if self.pipeline is not None:
                self.pipeline._obs_resync()
        return self

    @classmethod
    def from_snapshot(cls, snap: Mapping,
                      runners: Optional[Runners] = None,
                      obs: Optional[Observability] = None
                      ) -> "SkewRouteSession":
        """Stand up a replica directly from another session's snapshot
        (envelope or legacy flat v1)."""
        policy = snap.get("policy") if "envelope_version" in snap \
            else snap.get("spec")
        if policy is None:
            raise ValueError("snapshot has no policy half (expected "
                             "'policy' in an envelope or 'spec' in a "
                             "legacy flat v1 snapshot)")
        session = cls(RouteSpec.from_dict(policy), runners=runners, obs=obs)
        return session.restore(snap)


def build(spec: RouteSpec,
          runners: Optional[Runners] = None,
          obs: Optional[Observability] = None) -> SkewRouteSession:
    """The one entry point: declarative spec -> running session.

    ``obs``: an :class:`repro.obs.Observability` plane to record
    metrics + request traces into (runtime configuration, like
    ``runners`` — never part of the spec). Default: the no-op plane.
    """
    return SkewRouteSession(spec, runners=runners, obs=obs)
