"""Pluggable difficulty backends: ONE place that decides how skew metrics
are computed.

Before this module the interpret-vs-compiled choice and the oracle-vs-
kernel choice were re-derived ad hoc wherever dispatch happened
(`router_service`, `pipeline`, `launch/serve.py`). Now a
:class:`DifficultyBackend` is a named, swappable policy object:

* ``oracle`` — the readable XLA path (`repro.core.skewness`, via the
  kernel's stacked ref), still fused into ONE jitted decision program
  per batch. Ground truth; what offline evaluation wants — and the
  fastest path at small batch sizes, where Pallas launch/interpret
  overhead dominates.
* ``pallas`` — the fused single-pass skew kernel
  (`repro.kernels.skew_metrics`), interpret mode off-TPU.
* ``fused``  — the end-to-end program: `triple_score` Pallas scoring ->
  device top-k -> fused skew kernel -> threshold decision, chained in
  one jitted computation (scores never leave HBM). Same scores-in
  contract as ``pallas`` for :meth:`~DifficultyBackend.route_batch`,
  plus :meth:`route_retrieved` for candidate-features-in routing.
* ``auto``   — the production policy: a measured BATCH-SIZE CROSSOVER.
  Batches below ``crossover_batch`` go to the ``oracle`` program (which
  wins at small B — the seed's kernel-everywhere policy LOST to the
  oracle at B=1, 0.25–0.72x), batches at or above it go to the ``fused``
  kernels. The crossover is a serializable
  :class:`~repro.api.spec.RouteSpec` field so every replica agrees.

Interpret-vs-compiled is NEVER stored: every backend defers to
:func:`repro.kernels.device.default_interpret` at CALL time (compiled on
TPU, interpret elsewhere), so snapshots restored on a different host
re-resolve against the local devices.

Every backend produces the SAME contract: ``[B, K]`` descending-sorted
scores (+ optional ``[B]`` ``n_valid``) -> a full
:class:`~repro.core.router.RouteBatchResult` with the raw ``[B, 4]``
metric matrix in kernel column order, so the configured metric is always
a column select — never a recompile — regardless of backend.

Third-party backends (e.g. a mesh-sharded dispatch path, the ROADMAP's
next step) register with :func:`register_backend` and become selectable
from a :class:`~repro.api.spec.RouteSpec` by name.

Backends are POLICY-AGNOSTIC: they produce the threshold-tier ids plus
the raw metric matrix, and the dispatcher's routing policy
(`repro.policies` — cascade escalation, adaptive retrieval depth, mode
selection) transforms that decision host-side afterwards. That layering
is why every policy works identically under every backend, including
``sharded``.
"""

from __future__ import annotations

from typing import Callable, Mapping, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.router import (RetrievedRouteResult, RouteBatchResult,
                               RouterConfig, route_all_metrics,
                               route_retrieved)
from repro.kernels.device import default_interpret  # noqa: F401  (re-export)

# Measured on the BENCH_routing_fastpath.json CPU-interpret grid: the
# fused kernel path loses to the single-program oracle below ~32 rows
# (0.25–0.72x at B=1) and wins decisively from B=64 up (18–79x). On TPU
# the compiled kernel wins earlier — deployments set the spec field.
DEFAULT_CROSSOVER_BATCH = 32


@runtime_checkable
class DifficultyBackend(Protocol):
    """Computes skew metrics + tier assignments for score batches."""

    name: str

    def metrics(self, scores_desc: jax.Array,
                p_cdf: float = 0.95,
                n_valid: Optional[jax.Array] = None) -> jax.Array:
        """[B, K] descending scores -> [B, 4] raw metrics (kernel order)."""
        ...

    def route_batch(self, scores_desc: jax.Array, config: RouterConfig,
                    n_valid: Optional[jax.Array] = None) -> RouteBatchResult:
        """[B, K] -> tiers/difficulty/metrics under ``config``."""
        ...


class _SingleProgramBackend:
    """Shared machinery: both concrete backends run the whole
    metrics -> column-select -> threshold decision as ONE jitted device
    program (`core.router._decision_program`); they differ only in the
    metric implementation traced into it (``_use_kernel``) and in which
    scoring stage :meth:`route_retrieved` fuses in front.

    ``interpret=None`` defers to :func:`default_interpret` at call time,
    so a backend object built off-TPU keeps working if devices change.
    """

    _use_kernel: bool

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = interpret

    def effective_interpret(self) -> bool:
        """The interpret mode this call would use — resolved NOW, never
        replayed from construction or snapshot time."""
        return default_interpret() if self.interpret is None \
            else self.interpret

    def metrics(self, scores_desc, p_cdf: float = 0.95, n_valid=None):
        return self.route_batch(
            scores_desc,
            RouterConfig(metric="gini", thresholds=(0.0,),
                         cumulative_p=p_cdf), n_valid=n_valid).metrics

    def route_batch(self, scores_desc, config: RouterConfig, n_valid=None):
        return route_all_metrics(
            jnp.atleast_2d(jnp.asarray(scores_desc)), config,
            n_valid=None if n_valid is None else jnp.asarray(n_valid),
            interpret=self.effective_interpret(),
            use_kernel=self._use_kernel)

    def route_retrieved(self, feats, query_emb, params: Mapping,
                        config: RouterConfig,
                        n_cand=None) -> RetrievedRouteResult:
        """[B, N, Dt] candidate features + [B, Dq] queries -> full
        retrieve-to-decision output in one jitted program.

        Off-TPU the Pallas stages would run under the interpreter — a
        correctness tool that loses to plain XLA by >3x on the scoring
        MLP (measured: e2e B=64 cell at 0.3x before this fallback) — so
        when the call resolves to interpret mode the SAME fused program
        is traced from the XLA implementations instead. On TPU
        (interpret False) the real kernels run.
        """
        interp = self.effective_interpret()
        return route_retrieved(
            jnp.asarray(feats), jnp.asarray(query_emb), params, config,
            n_cand=None if n_cand is None else jnp.asarray(n_cand),
            interpret=interp,
            use_kernels=self._use_kernel and not interp)


class OracleBackend(_SingleProgramBackend):
    """XLA ground-truth backend (`core.skewness` metrics, stacked) — one
    jitted program per batch, no Pallas launch: the small-batch winner."""

    name = "oracle"
    _use_kernel = False

    def __init__(self):
        super().__init__(interpret=None)


class PallasBackend(_SingleProgramBackend):
    """Fused single-pass skew kernel backend (`kernels.skew_metrics`)."""

    name = "pallas"
    _use_kernel = True


class FusedBackend(PallasBackend):
    """The end-to-end device program: Pallas `triple_score` scoring ->
    device top-k -> fused skew kernel -> threshold decision, one jitted
    computation. For pre-scored batches it is the ``pallas`` fast path;
    :meth:`route_retrieved` is the scores-never-leave-HBM entry."""

    name = "fused"


class AutoBackend:
    """Batch-size crossover policy: ``oracle`` below ``crossover_batch``,
    the ``fused`` kernels at or above it.

    This is the bugfix for the seed's B=1 regression: ``auto`` used to be
    a blind alias for the kernel path, which loses to the single-program
    oracle at small batches (0.25–0.72x at B=1 on the tracked bench).
    The crossover is policy, not environment — it lives in
    :class:`~repro.api.spec.RouteSpec` so replicas agree — while the
    interpret-vs-compiled choice stays call-time per host.
    """

    name = "auto"

    def __init__(self, crossover_batch: int = DEFAULT_CROSSOVER_BATCH,
                 interpret: Optional[bool] = None):
        if crossover_batch < 1:
            raise ValueError(f"crossover_batch must be >= 1, "
                             f"got {crossover_batch}")
        self.crossover_batch = int(crossover_batch)
        self.oracle = OracleBackend()
        self.fused = FusedBackend(interpret=interpret)
        # crossover-pick counters; replaced with live instruments when a
        # session attaches its observability plane (attach_obs)
        from repro.obs.registry import NULL_INSTRUMENT
        self._m_pick = {"oracle": NULL_INSTRUMENT, "fused": NULL_INSTRUMENT}

    def attach_obs(self, obs) -> None:
        """Wire the session's observability plane in: which side of the
        batch-size crossover each dispatch lands on becomes a counter
        (``backend_pick_total{path=oracle|fused}``)."""
        self._m_pick = {
            path: obs.metrics.counter("backend_pick_total", path=path)
            for path in ("oracle", "fused")}

    @property
    def interpret(self) -> Optional[bool]:
        return self.fused.interpret

    def effective_interpret(self) -> bool:
        return self.fused.effective_interpret()

    def pick(self, batch_size: int) -> DifficultyBackend:
        """The crossover in one place (bench/telemetry introspect this)."""
        side = self.oracle if batch_size < self.crossover_batch \
            else self.fused
        self._m_pick["oracle" if side is self.oracle else "fused"].inc()
        return side

    def metrics(self, scores_desc, p_cdf: float = 0.95, n_valid=None):
        scores = jnp.atleast_2d(jnp.asarray(scores_desc))
        return self.pick(scores.shape[0]).metrics(scores, p_cdf=p_cdf,
                                                  n_valid=n_valid)

    def route_batch(self, scores_desc, config: RouterConfig, n_valid=None):
        scores = jnp.atleast_2d(jnp.asarray(scores_desc))
        return self.pick(scores.shape[0]).route_batch(scores, config,
                                                      n_valid=n_valid)

    def route_retrieved(self, feats, query_emb, params: Mapping,
                        config: RouterConfig, n_cand=None):
        return self.pick(jnp.asarray(feats).shape[0]).route_retrieved(
            feats, query_emb, params, config, n_cand=n_cand)


# --- registry ----------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., DifficultyBackend]] = {}


def register_backend(name: str,
                     factory: Callable[..., DifficultyBackend]) -> None:
    """Register a backend factory under ``name`` (RouteSpec-selectable)."""
    if not name or name == "auto":
        raise ValueError(f"invalid backend name {name!r}")
    _REGISTRY[name] = factory


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY)) + ("auto",)


def resolve_backend_name(name: str = "auto") -> str:
    """``auto`` is a first-class backend now (the crossover policy), no
    longer an alias: it resolves to itself. Kept for callers that log or
    validate backend names."""
    return name


def make_backend(name: str = "auto", **kwargs) -> DifficultyBackend:
    """Instantiate a difficulty backend by name (``auto`` = the batch-size
    crossover over oracle/fused — see module docstring; accepts
    ``crossover_batch=``)."""
    if name == "auto":
        return AutoBackend(**kwargs)
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown difficulty backend {name!r}; "
                         f"choose from {available_backends()}") from None
    return factory(**kwargs)


def _make_sharded(**kwargs) -> DifficultyBackend:
    """Lazy factory: the mesh-sharded dispatch backend (`api/sharded.py`)
    — imported on first use so merely listing backends never touches
    device state. Accepts ``crossover_batch=``/``mesh=``."""
    from repro.api.sharded import ShardedBackend
    return ShardedBackend(**kwargs)


register_backend("oracle", OracleBackend)
register_backend("pallas", PallasBackend)
register_backend("fused", FusedBackend)
register_backend("sharded", _make_sharded)
