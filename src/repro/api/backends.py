"""Pluggable difficulty backends: ONE place that decides how skew metrics
are computed.

Before this module the interpret-vs-compiled choice and the oracle-vs-
kernel choice were re-derived ad hoc wherever dispatch happened
(`router_service`, `pipeline`, `launch/serve.py`). Now a
:class:`DifficultyBackend` is a named, swappable policy object:

* ``oracle`` — the readable XLA path (`repro.core.skewness`, via the
  kernel's stacked ref). Ground truth; what offline evaluation wants.
* ``pallas`` — the fused single-pass kernel
  (`repro.kernels.skew_metrics`), interpret mode off-TPU.
* ``auto``   — the fused ``pallas`` kernel, with the interpret-vs-
  compiled choice made from device availability at CALL time
  (:func:`default_interpret`): compiled on TPU, interpret mode
  elsewhere (still one XLA computation per batch under jit).

Every backend produces the SAME contract: ``[B, K]`` descending-sorted
scores (+ optional ``[B]`` ``n_valid``) -> a full
:class:`~repro.core.router.RouteBatchResult` with the raw ``[B, 4]``
metric matrix in kernel column order, so the configured metric is always
a column select — never a recompile — regardless of backend.

Third-party backends (e.g. a mesh-sharded dispatch path, the ROADMAP's
next step) register with :func:`register_backend` and become selectable
from a :class:`~repro.api.spec.RouteSpec` by name.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.router import (RouteBatchResult, RouterConfig,
                               difficulty_from_metrics, route_from_difficulty)


def default_interpret() -> bool:
    """The one canonical device-availability check: Pallas kernels run
    compiled on TPU and in interpret mode everywhere else."""
    return jax.default_backend() != "tpu"


@runtime_checkable
class DifficultyBackend(Protocol):
    """Computes skew metrics + tier assignments for score batches."""

    name: str

    def metrics(self, scores_desc: jax.Array,
                p_cdf: float = 0.95,
                n_valid: Optional[jax.Array] = None) -> jax.Array:
        """[B, K] descending scores -> [B, 4] raw metrics (kernel order)."""
        ...

    def route_batch(self, scores_desc: jax.Array, config: RouterConfig,
                    n_valid: Optional[jax.Array] = None) -> RouteBatchResult:
        """[B, K] -> tiers/difficulty/metrics under ``config``."""
        ...


def _route_from_metrics(metrics: jax.Array,
                        config: RouterConfig) -> RouteBatchResult:
    diff = difficulty_from_metrics(metrics, config.metric)
    tiers = route_from_difficulty(diff, jnp.asarray(config.thresholds))
    return RouteBatchResult(tiers=tiers, difficulty=diff, metrics=metrics)


@functools.partial(jax.jit, static_argnames=("p_cdf", "ragged"))
def _oracle_metrics(scores_desc: jax.Array, p_cdf: float,
                    n_valid: Optional[jax.Array], ragged: bool) -> jax.Array:
    from repro.kernels.skew_metrics.ref import (mask_from_n_valid,
                                                skew_metrics_ref)
    mask = (mask_from_n_valid(n_valid, scores_desc.shape[-1])
            if ragged else None)
    return skew_metrics_ref(scores_desc, p_cdf=p_cdf, mask=mask)


class OracleBackend:
    """XLA ground-truth backend (`core.skewness` metrics, stacked)."""

    name = "oracle"

    def metrics(self, scores_desc, p_cdf: float = 0.95, n_valid=None):
        scores = jnp.atleast_2d(jnp.asarray(scores_desc))
        return _oracle_metrics(scores, p_cdf,
                               None if n_valid is None else jnp.asarray(n_valid),
                               ragged=n_valid is not None)

    def route_batch(self, scores_desc, config: RouterConfig, n_valid=None):
        return _route_from_metrics(
            self.metrics(scores_desc, config.cumulative_p, n_valid), config)


class PallasBackend:
    """Fused single-pass kernel backend (`kernels.skew_metrics`).

    ``interpret=None`` defers to :func:`default_interpret` at call time,
    so a backend object built off-TPU keeps working if devices change.
    """

    name = "pallas"

    def __init__(self, interpret: Optional[bool] = None):
        self.interpret = interpret

    def metrics(self, scores_desc, p_cdf: float = 0.95, n_valid=None):
        from repro.kernels.skew_metrics import ops as skew_ops
        scores = jnp.atleast_2d(jnp.asarray(scores_desc))
        return skew_ops.skew_metrics(
            scores, p_cdf=p_cdf,
            n_valid=None if n_valid is None else jnp.asarray(n_valid),
            interpret=self.interpret)

    def route_batch(self, scores_desc, config: RouterConfig, n_valid=None):
        from repro.core.router import route_all_metrics
        return route_all_metrics(
            jnp.atleast_2d(jnp.asarray(scores_desc)), config,
            n_valid=None if n_valid is None else jnp.asarray(n_valid),
            interpret=self.interpret)


# --- registry ----------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., DifficultyBackend]] = {}


def register_backend(name: str,
                     factory: Callable[..., DifficultyBackend]) -> None:
    """Register a backend factory under ``name`` (RouteSpec-selectable)."""
    if not name or name == "auto":
        raise ValueError(f"invalid backend name {name!r}")
    _REGISTRY[name] = factory


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY)) + ("auto",)


def resolve_backend_name(name: str = "auto") -> str:
    """``auto`` is an alias for ``pallas``; the actual device decision
    (compiled vs interpret) happens at call time via
    :func:`default_interpret`, not here."""
    return "pallas" if name == "auto" else name


def make_backend(name: str = "auto", **kwargs) -> DifficultyBackend:
    """Instantiate a difficulty backend by name (``auto`` = the fused
    kernel with call-time interpret fallback — see module docstring)."""
    concrete = resolve_backend_name(name)
    try:
        factory = _REGISTRY[concrete]
    except KeyError:
        raise ValueError(f"unknown difficulty backend {name!r}; "
                         f"choose from {available_backends()}") from None
    return factory(**kwargs)


register_backend("oracle", OracleBackend)
register_backend("pallas", PallasBackend)
