"""`repro.api` — the unified, declarative SkewRoute routing surface.

The entire routing policy is one JSON-round-trippable value; running it
is one call:

    from repro.api import RouteSpec, build

    spec = RouteSpec(metric="gini", thresholds=(theta,),
                     tier_names=("qwen7b", "qwen72b"))
    session = build(spec)                  # or build(spec, runners=bank)
    result = session.route(scores_desc)    # [B, K] -> tiers + telemetry

Policies ship between replicas as bytes (`spec.to_json()` /
`RouteSpec.from_json`), live state ships as `session.snapshot()` /
`restore()`. Difficulty computation is a named, registered backend
(``oracle`` | ``pallas`` | ``fused`` | ``auto``) — see
`repro.api.backends`; ``auto`` is the production batch-size crossover
(oracle below ``spec.crossover_batch``, the fused end-to-end kernels at
or above it).

What the session DOES with the skew metrics is a registered routing
policy (``threshold`` | ``cascade`` | ``adaptive_depth`` |
``mode_select``) selected by ``spec.policy`` — see `repro.policies`;
``policy=None`` is the default threshold compare, bit-for-bit the
pre-policy behavior.
"""

from repro.api.backends import (  # noqa: F401
    DEFAULT_CROSSOVER_BATCH,
    AutoBackend,
    DifficultyBackend,
    FusedBackend,
    OracleBackend,
    PallasBackend,
    available_backends,
    default_interpret,
    make_backend,
    register_backend,
    resolve_backend_name,
)
from repro.api.spec import (  # noqa: F401
    ENVELOPE_VERSION,
    SCHEMA_VERSION,
    AdmissionSpec,
    CalibrationSpec,
    CostSpec,
    RouteSpec,
    policy_fingerprint,
)
from repro.api.session import (  # noqa: F401
    EngineBankLike,
    SkewRouteSession,
    build,
)
from repro.policies import (  # noqa: F401
    AdaptiveDepthPolicySpec,
    CascadePolicySpec,
    ModeSelectPolicySpec,
    PolicySpec,
    ThresholdPolicySpec,
    available_policies,
    build_policy,
    policy_spec_from_dict,
)
