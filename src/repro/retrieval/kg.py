"""Knowledge-graph triple store with CSR adjacency.

Triples are (head, relation, tail) int32 arrays (Freebase-style). The CSR
layout (edges sorted by head + offsets) supports O(1) per-entity
neighborhood slicing for k-hop retrieval and the fanout neighbor sampler
shared with the GNN minibatch shape.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class KnowledgeGraph:
    heads: np.ndarray        # [E] int32
    rels: np.ndarray         # [E] int32
    tails: np.ndarray        # [E] int32
    n_entities: int
    n_relations: int
    # CSR over heads (built by `build`)
    order: np.ndarray = None       # edge permutation sorted by head
    offsets: np.ndarray = None     # [n_entities + 1]

    @classmethod
    def build(cls, heads, rels, tails, n_entities, n_relations) -> "KnowledgeGraph":
        heads = np.asarray(heads, np.int32)
        rels = np.asarray(rels, np.int32)
        tails = np.asarray(tails, np.int32)
        order = np.argsort(heads, kind="stable").astype(np.int32)
        counts = np.bincount(heads, minlength=n_entities)
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(heads, rels, tails, int(n_entities), int(n_relations),
                   order, offsets)

    @property
    def n_triples(self) -> int:
        return len(self.heads)

    def out_edges(self, entity: int) -> np.ndarray:
        """Edge indices whose head is ``entity``."""
        lo, hi = self.offsets[entity], self.offsets[entity + 1]
        return self.order[lo:hi]

    def khop_edges(self, seeds, hops: int, max_edges: int = 4096) -> np.ndarray:
        """Edge indices of the <=``hops``-hop out-neighborhood of seeds."""
        frontier = list(np.atleast_1d(seeds))
        seen_nodes = set(frontier)
        edges: list[int] = []
        for _ in range(hops):
            nxt = []
            for e in frontier:
                for ei in self.out_edges(int(e)):
                    if len(edges) >= max_edges:
                        return np.asarray(edges, np.int32)
                    edges.append(int(ei))
                    t = int(self.tails[ei])
                    if t not in seen_nodes:
                        seen_nodes.add(t)
                        nxt.append(t)
            frontier = nxt
            if not frontier:
                break
        return np.asarray(edges, np.int32)

    def distances_from(self, seed: int, max_hops: int = 4) -> dict[int, int]:
        """BFS hop distance from ``seed`` (for DDE features)."""
        dist = {int(seed): 0}
        frontier = [int(seed)]
        for h in range(1, max_hops + 1):
            nxt = []
            for e in frontier:
                for ei in self.out_edges(e):
                    t = int(self.tails[ei])
                    if t not in dist:
                        dist[t] = h
                        nxt.append(t)
            frontier = nxt
            if not frontier:
                break
        return dist
