"""KG retrieval substrate: triple store, SubgraphRAG-style scorer,
neighbor sampler, and the synthetic Freebase-like KGQA benchmark."""
