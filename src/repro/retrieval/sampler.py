"""Fanout neighbor sampler (GraphSAGE-style) over the CSR triple store.

Shared by (a) the GNN ``minibatch_lg`` shape — fanout-(15,10) sampled
subgraphs padded to static sizes — and (b) KG retrieval candidate pooling.
Host-side numpy (like real loaders); outputs are jit-ready padded arrays.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.retrieval.kg import KnowledgeGraph


@dataclasses.dataclass
class SampledSubgraph:
    """Padded, statically-shaped sampled subgraph.

    node_ids: [n_nodes_max] global ids (-1 pad); src/dst: [n_edges_max]
    LOCAL indices (dummy = n_valid slot handled by the model); seed_mask
    marks the seed rows (loss rows).
    """
    node_ids: np.ndarray
    src: np.ndarray
    dst: np.ndarray
    n_valid_nodes: int
    seed_mask: np.ndarray


def sample_subgraph(kg: KnowledgeGraph, seeds: np.ndarray,
                    fanouts: tuple[int, ...],
                    n_nodes_max: int, n_edges_max: int,
                    seed: int = 0) -> SampledSubgraph:
    rng = np.random.default_rng(seed)
    local = {int(s): i for i, s in enumerate(seeds)}
    node_list = [int(s) for s in seeds]
    src_l: list[int] = []
    dst_l: list[int] = []
    frontier = list(seeds)
    for fanout in fanouts:
        nxt = []
        for node in frontier:
            edges = kg.out_edges(int(node))
            if len(edges) == 0:
                continue
            pick = rng.choice(edges, size=min(fanout, len(edges)),
                              replace=False)
            for ei in pick:
                t = int(kg.tails[ei])
                if t not in local:
                    if len(node_list) >= n_nodes_max:
                        continue
                    local[t] = len(node_list)
                    node_list.append(t)
                    nxt.append(t)
                if len(src_l) < n_edges_max:
                    # message flows neighbor -> node (dst = aggregating node)
                    src_l.append(local[t])
                    dst_l.append(local[int(node)])
        frontier = nxt
    n_valid = len(node_list)
    dummy = n_valid  # model appends a dummy row at n_valid
    node_ids = np.full(n_nodes_max, -1, np.int32)
    node_ids[:n_valid] = node_list
    src = np.full(n_edges_max, dummy, np.int32)
    dst = np.full(n_edges_max, dummy, np.int32)
    src[: len(src_l)] = src_l
    dst[: len(dst_l)] = dst_l
    seed_mask = np.zeros(n_nodes_max, bool)
    seed_mask[: len(seeds)] = True
    return SampledSubgraph(node_ids=node_ids, src=src, dst=dst,
                           n_valid_nodes=n_valid, seed_mask=seed_mask)
