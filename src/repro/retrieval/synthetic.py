"""Synthetic Freebase-like KGQA benchmark (CWQ / WebQSP analogues).

No Freebase dump ships in this container, so the paper's experimental
setting is reconstructed generatively (DESIGN §7.2):

* KG: power-law out-degree (Freebase-like), latent entity/relation
  embeddings with compositional structure — tail ~ head + relation + noise
  so a trained scorer can actually learn relevance.
* Queries: a random reasoning chain of ``hops`` relations from a topic
  entity; the query embedding is the composed chain signature + noise.
  Hop mix follows the paper's Table 2 (WebQSP: 65.5/34.5/0; CWQ:
  40.9/38.3/20.8 split over 1/2/>=3 hops).
* Ground truth per query: the gold chain edges (positives for scorer
  training), the answer entity, and the hop count (the paper's difficulty
  notion, §3.2).

The emergent phenomenon the paper relies on — 1-hop queries give the
scorer one dominant triple (high skew), multi-hop queries spread scores
over the chain and its neighborhood (low skew) — arises here from the
chain structure rather than being injected by hand.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.retrieval.kg import KnowledgeGraph

HOP_MIX = {
    "webqsp": {1: 0.655, 2: 0.345},
    "cwq": {1: 0.409, 2: 0.383, 3: 0.147, 4: 0.061},
}


@dataclasses.dataclass
class SyntheticKGQA:
    kg: KnowledgeGraph
    entity_emb: np.ndarray     # [n_entities, d]
    relation_emb: np.ndarray   # [n_relations, d]
    queries: list              # list[Query]


@dataclasses.dataclass
class Query:
    topic: int
    query_emb: np.ndarray
    gold_edges: np.ndarray     # edge ids of the reasoning chain
    answer: int
    hops: int


def make_kg(n_entities: int = 20_000, n_relations: int = 200,
            avg_degree: float = 8.0, d_emb: int = 32,
            seed: int = 0) -> tuple[KnowledgeGraph, np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    ent = rng.normal(0, 1, (n_entities, d_emb)).astype(np.float32)
    rel = rng.normal(0, 1, (n_relations, d_emb)).astype(np.float32)
    # power-law out-degree (Zipf-ish, clipped)
    deg = np.minimum(rng.zipf(1.7, n_entities), 200)
    deg = np.maximum((deg * avg_degree / deg.mean()).astype(np.int64), 1)
    n_edges = int(deg.sum())
    heads = np.repeat(np.arange(n_entities, dtype=np.int32), deg)
    rels = rng.integers(0, n_relations, n_edges).astype(np.int32)
    # compositional tails: nearest entity to head_emb + rel_emb (+ noise),
    # searched within a random candidate pool (exact NN over 20k x many
    # edges is needless — the pool keeps structure while staying O(E * P)).
    pool = rng.integers(0, n_entities, (n_edges, 16))
    target = ent[heads] + rel[rels] + rng.normal(0, 0.3, (n_edges, d_emb))
    dists = np.linalg.norm(ent[pool] - target[:, None, :], axis=-1)
    tails = pool[np.arange(n_edges), dists.argmin(1)].astype(np.int32)
    kg = KnowledgeGraph.build(heads, rels, tails, n_entities, n_relations)
    return kg, ent, rel


def make_queries(kg: KnowledgeGraph, ent: np.ndarray, rel: np.ndarray,
                 n_queries: int, dataset: str = "cwq",
                 query_noise: float = 0.25, seed: int = 1) -> list[Query]:
    rng = np.random.default_rng(seed)
    mix = HOP_MIX[dataset]
    hop_choices = np.asarray(list(mix.keys()))
    hop_probs = np.asarray(list(mix.values()))
    hop_probs = hop_probs / hop_probs.sum()
    queries: list[Query] = []
    attempts = 0
    while len(queries) < n_queries and attempts < n_queries * 20:
        attempts += 1
        hops = int(rng.choice(hop_choices, p=hop_probs))
        topic = int(rng.integers(0, kg.n_entities))
        node, chain = topic, []
        ok = True
        for _ in range(hops):
            edges = kg.out_edges(node)
            if len(edges) == 0:
                ok = False
                break
            ei = int(edges[rng.integers(0, len(edges))])
            chain.append(ei)
            node = int(kg.tails[ei])
        if not ok:
            continue
        # query signature: topic + sum of chain relations (what a language
        # encoder would extract from the natural-language question)
        sig = ent[topic] + rel[kg.rels[chain]].sum(0)
        q_emb = (sig + rng.normal(0, query_noise, sig.shape)).astype(np.float32)
        queries.append(Query(topic=topic, query_emb=q_emb,
                             gold_edges=np.asarray(chain, np.int32),
                             answer=node, hops=hops))
    return queries


def make_dataset(dataset: str = "cwq", n_queries: int = 800,
                 n_entities: int = 20_000, seed: int = 0) -> SyntheticKGQA:
    kg, ent, rel = make_kg(n_entities=n_entities, seed=seed)
    queries = make_queries(kg, ent, rel, n_queries, dataset=dataset,
                           seed=seed + 1)
    return SyntheticKGQA(kg=kg, entity_emb=ent, relation_emb=rel,
                         queries=queries)


def candidate_edges(kg: KnowledgeGraph, q: Query, max_edges: int = 512,
                    seed: int = 0) -> np.ndarray:
    """Retrieval candidate pool: the topic's k-hop neighborhood + the gold
    chain + random negatives (SubgraphRAG scores such a pool per query)."""
    rng = np.random.default_rng(seed + q.topic)
    local = kg.khop_edges(q.topic, hops=max(q.hops, 2), max_edges=max_edges // 2)
    n_rand = max_edges - len(local) - len(q.gold_edges)
    randoms = rng.integers(0, kg.n_triples, max(n_rand, 0)).astype(np.int32)
    pool = np.unique(np.concatenate([q.gold_edges, local, randoms]))
    rng.shuffle(pool)
    return pool[:max_edges]
