"""SubgraphRAG-style triple scorer (the retrieval stage SkewRoute reads).

A lightweight MLP scores each candidate triple against the query
(paper §2: "SubgraphRAG employs a lightweight MLP to score independent
triples"). Features per triple: [head_emb, rel_emb, tail_emb, DDE, SIM]:
DDE is the directional-distance encoding of head/tail from the topic
entity (one-hot over hop distance, SubgraphRAG §3); SIM are four
query-triple dot products (q·h, q·r, q·t, q·(h+r)) — the role the frozen
text-encoder similarity plays in SubgraphRAG's feature stack. Positives
are upweighted in the BCE (1-4 gold edges vs ~250 candidates — unweighted
training collapses to all-negative, measured in the first calibration
run).

The weight layout matches `repro.kernels.triple_score` exactly (W1 split
into triple-side and query-side halves) so the Pallas kernel is a drop-in
for the serving path and this module doubles as its training harness.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.kg import KnowledgeGraph
from repro.retrieval.synthetic import Query, candidate_edges

MAX_DDE_HOPS = 4  # distance buckets: 0..3, >=4/unreachable


@dataclasses.dataclass(frozen=True)
class ScorerConfig:
    d_emb: int = 32
    d_hidden: int = 128
    lr: float = 3e-3
    top_k: int = 100

    @property
    def d_triple(self) -> int:
        return 3 * self.d_emb + 2 * (MAX_DDE_HOPS + 1) + 4  # +SIM features

    @property
    def d_query(self) -> int:
        return self.d_emb


def init_scorer(key: jax.Array, cfg: ScorerConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt, dq, h = cfg.d_triple, cfg.d_query, cfg.d_hidden
    return {
        "w1_t": (jax.random.normal(k1, (dt, h)) * (2 / dt) ** 0.5).astype(jnp.float32),
        "w1_q": (jax.random.normal(k2, (dq, h)) * (2 / dq) ** 0.5).astype(jnp.float32),
        "b1": jnp.zeros((h,), jnp.float32),
        "w2": (jax.random.normal(k3, (h, 1)) * (2 / h) ** 0.5).astype(jnp.float32),
        "b2": jnp.zeros((1,), jnp.float32),
    }


def dde_features(kg: KnowledgeGraph, topic: int, edge_ids: np.ndarray) -> np.ndarray:
    """One-hot hop distance of head & tail from the topic entity."""
    dist = kg.distances_from(topic, MAX_DDE_HOPS)
    def onehot(node):
        d = min(dist.get(int(node), MAX_DDE_HOPS), MAX_DDE_HOPS)
        v = np.zeros(MAX_DDE_HOPS + 1, np.float32)
        v[d] = 1.0
        return v
    h = np.stack([onehot(kg.heads[e]) for e in edge_ids])
    t = np.stack([onehot(kg.tails[e]) for e in edge_ids])
    return np.concatenate([h, t], axis=1)


def triple_features(kg: KnowledgeGraph, ent: np.ndarray, rel: np.ndarray,
                    q: Query, edge_ids: np.ndarray) -> np.ndarray:
    h, r, t = (ent[kg.heads[edge_ids]], rel[kg.rels[edge_ids]],
               ent[kg.tails[edge_ids]])
    d = h.shape[1]
    qv = q.query_emb / np.sqrt(d)
    sim = np.stack([h @ qv, r @ qv, t @ qv, (h + r) @ qv], axis=1)
    return np.concatenate([h, r, t, dde_features(kg, q.topic, edge_ids),
                           sim], axis=1).astype(np.float32)


def score_fn(params: dict, triples: jax.Array, query: jax.Array) -> jax.Array:
    """XLA scoring path (oracle of the Pallas kernel). [N,Dt],[Dq] -> [N]."""
    h = jax.nn.relu(triples @ params["w1_t"]
                    + query @ params["w1_q"] + params["b1"])
    return (h @ params["w2"])[:, 0] + params["b2"][0]


def bce_loss(params: dict, triples: jax.Array, query: jax.Array,
             labels: jax.Array, pos_weight: float = 32.0) -> jax.Array:
    logits = score_fn(params, triples, query)
    per = (jnp.maximum(logits, 0) - logits * labels
           + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    w = 1.0 + (pos_weight - 1.0) * labels
    return jnp.sum(per * w) / jnp.sum(w)


@jax.jit
def _adam_step(params, opt_m, opt_v, step, triples, query, labels, lr):
    loss, grads = jax.value_and_grad(bce_loss)(params, triples, query, labels)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step.astype(jnp.float32) + 1.0
    opt_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_m, grads)
    opt_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_v, grads)
    params = jax.tree.map(
        lambda p, m, v: p - lr * (m / (1 - b1 ** t)) /
        (jnp.sqrt(v / (1 - b2 ** t)) + eps), params, opt_m, opt_v)
    return params, opt_m, opt_v, step + 1, loss


def train_scorer(data, cfg: ScorerConfig, n_steps: int = 300,
                 batch_queries: int = 8, max_cands: int = 256,
                 seed: int = 0, log_every: int = 0) -> dict:
    """Train the scorer on synthetic KGQA gold chains (BCE on edge labels)."""
    rng = np.random.default_rng(seed)
    params = init_scorer(jax.random.key(seed), cfg)
    kg, ent, rel = data.kg, data.entity_emb, data.relation_emb
    # Pre-build per-query candidate features once (host-side data pipeline).
    cache = []
    for q in data.queries[: min(len(data.queries), 400)]:
        edges = candidate_edges(kg, q, max_edges=max_cands, seed=seed)
        feats = triple_features(kg, ent, rel, q, edges)
        labels = np.isin(edges, q.gold_edges).astype(np.float32)
        cache.append((feats, q.query_emb, labels))
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)
    step_c = jnp.zeros((), jnp.int32)
    for step in range(n_steps):
        idx = rng.integers(0, len(cache), batch_queries)
        losses = []
        for i in idx:
            feats, qemb, labels = cache[i]
            params, opt_m, opt_v, step_c, loss = _adam_step(
                params, opt_m, opt_v, step_c, jnp.asarray(feats),
                jnp.asarray(qemb), jnp.asarray(labels), cfg.lr)
            losses.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"scorer step {step}: loss {np.mean(losses):.4f}")
    return params


def retrieve(params: dict, kg: KnowledgeGraph, ent, rel, q: Query,
             cfg: ScorerConfig, max_cands: int = 512,
             seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Top-K retrieval for one query -> (edge_ids desc-by-score, scores)."""
    edges = candidate_edges(kg, q, max_edges=max_cands, seed=seed)
    feats = triple_features(kg, ent, rel, q, edges)
    scores = np.asarray(score_fn(params, jnp.asarray(feats),
                                 jnp.asarray(q.query_emb)))
    k = min(cfg.top_k, len(edges))
    order = np.argsort(-scores)[:k]
    probs = 1.0 / (1.0 + np.exp(-scores[order]))  # paper scores are [0,1]
    return edges[order], probs.astype(np.float32)


# -- batched device-side retrieval (feeds the fused routing program) ----------


def kernel_weights(params: dict) -> tuple:
    """The scorer weights in the Pallas `triple_score` kernel's argument
    order — the drop-in contract made explicit (and the one place that
    would break loudly if either layout ever drifted)."""
    return (params["w1_t"], params["w1_q"], params["b1"],
            params["w2"], params["b2"])


def batch_triple_features(kg: KnowledgeGraph, ent, rel,
                          queries: list, max_cands: int = 512,
                          seed: int = 0
                          ) -> tuple[np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
    """Stack per-query candidate features into one ragged device batch.

    Returns ``(feats [B, N, Dt], query_embs [B, Dq], edge_ids [B, N],
    n_cand [B])`` where N is the largest candidate count in the batch;
    rows are zero-padded past ``n_cand`` (edge_ids pad with -1). This is
    the host-side data-pipeline half; everything after it — scoring,
    top-k, skew, tier decision — runs as one device program
    (`repro.core.router.route_retrieved`).
    """
    per_query = []
    for q in queries:
        edges = candidate_edges(kg, q, max_edges=max_cands, seed=seed)
        per_query.append((edges, triple_features(kg, ent, rel, q, edges)))
    n = max(len(edges) for edges, _ in per_query)
    dt = per_query[0][1].shape[1]
    b = len(queries)
    feats = np.zeros((b, n, dt), np.float32)
    edge_ids = np.full((b, n), -1, np.int64)
    n_cand = np.zeros(b, np.int32)
    for i, (edges, f) in enumerate(per_query):
        feats[i, :len(edges)] = f
        edge_ids[i, :len(edges)] = edges
        n_cand[i] = len(edges)
    qembs = np.stack([q.query_emb for q in queries]).astype(np.float32)
    return feats, qembs, edge_ids, n_cand


def retrieve_batch(params: dict, kg: KnowledgeGraph, ent, rel,
                   queries: list, cfg: ScorerConfig, max_cands: int = 512,
                   seed: int = 0, interpret: bool | None = None
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched top-K retrieval on device: one fused kernel program scores
    every query's candidates and top-ks them — the batched counterpart of
    :func:`retrieve` (same edge ids and probs per query).

    Returns ``(edge_ids [B, K], probs [B, K], n_valid [B])``; rows with
    fewer than K candidates pad edge_ids with -1 / probs with 0 past
    ``n_valid``.
    """
    from repro.kernels.triple_score import ops as ts_ops

    feats, qembs, edge_ids, n_cand = batch_triple_features(
        kg, ent, rel, queries, max_cands=max_cands, seed=seed)
    n = feats.shape[1]
    logits = np.asarray(ts_ops.triple_score_batched(
        jnp.asarray(feats), jnp.asarray(qembs), *kernel_weights(params),
        interpret=interpret))
    logits = np.where(np.arange(n)[None, :] < n_cand[:, None],
                      logits, -np.inf)
    k = min(cfg.top_k, n)
    vals, idx = jax.lax.top_k(jnp.asarray(logits), k)
    idx, vals = np.asarray(idx), np.asarray(vals)
    n_valid = np.minimum(n_cand, k).astype(np.int32)
    probs = np.where(np.isfinite(vals),
                     1.0 / (1.0 + np.exp(-vals)), 0.0).astype(np.float32)
    out_edges = np.take_along_axis(edge_ids, idx, axis=1)
    out_edges[np.arange(k)[None, :] >= n_valid[:, None]] = -1
    return out_edges, probs, n_valid
