"""Public wrapper for flash-decode (TPU native / interpret elsewhere)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention import kernel, ref


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array,
                     block_k: int = kernel.DEFAULT_BLOCK_K) -> jax.Array:
    on_tpu = jax.default_backend() == "tpu"
    return kernel.decode_attention(q, k, v, kv_len, block_k=block_k,
                                   interpret=not on_tpu)


decode_ref = ref.decode_ref
