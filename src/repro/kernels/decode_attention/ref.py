"""Pure-jnp oracle for flash-decode."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
               kv_len: jax.Array) -> jax.Array:
    """q: [B, H, Dh]; k/v: [B, KV, S, Dh] -> [B, H, Dh]."""
    b, h, dh = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * dh ** -0.5
    mask = jnp.arange(s)[None, None, :] < kv_len
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
