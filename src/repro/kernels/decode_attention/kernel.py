"""Flash-decode Pallas kernel: one query token vs a long KV cache.

The cost SkewRoute tries to avoid paying on the big tier is dominated by
exactly this op (decode_32k / long_500k shapes): a [B, H, Dh] query
attending to a [B, KV, S, Dh] cache. Tiling: grid (B, KV, S/bk) with the
cache dimension sequential; online-softmax state for the whole q-head
GROUP of a kv head ([G, Dh] accumulator) lives in VMEM scratch — GQA means
one cache block load serves G query heads (arithmetic intensity x G).

The valid cache length arrives as a scalar in SMEM; blocks past it are
skipped entirely (``pl.when``), so a 500k-slot cache at position 10k reads
only ceil(10k/bk) blocks — the split-KV analogue of FlashDecoding.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256
_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, scale: float, block_k: int):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = len_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * block_k

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [G, dh]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [G, bk]
        key_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(key_pos < kv_len, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q: [B, H, Dh]; k/v: [B, KV, S, Dh]; kv_len: scalar int32.

    Returns [B, H, Dh] — attention of the single new token over cache
    positions < kv_len.
    """
    b, h, dh = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    if s % block_k:
        raise ValueError(f"cache len {s} not divisible by block {block_k}")
    qg = q.reshape(b, kv, g, dh)
    grid = (b, kv, s // block_k)
    kernel = functools.partial(_decode_kernel, scale=dh ** -0.5,
                               block_k=block_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, g, dh), lambda bb, hh, ik: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bb, hh, ik: (bb, hh, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda bb, hh, ik: (bb, hh, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda bb, hh, ik: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), qg, k, v)
    return out.reshape(b, h, dh)
