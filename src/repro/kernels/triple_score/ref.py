"""Pure-jnp oracle for the fused triple scorer."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def triple_score_ref(triple_feats, query_emb, w1_t, w1_q, b1, w2, b2):
    """[N,Dt] x [Q,Dq] -> [Q,N] 2-layer-MLP relevance scores."""
    t32 = triple_feats.astype(jnp.float32)
    q32 = query_emb.astype(jnp.float32)
    h = (t32 @ w1_t.astype(jnp.float32))[None, :, :] \
        + (q32 @ w1_q.astype(jnp.float32) + b1)[:, None, :]
    h = jax.nn.relu(h)
    return (h @ w2.astype(jnp.float32))[..., 0] + b2[0]


def triple_score_batched_ref(triple_feats, query_emb, w1_t, w1_q, b1, w2, b2):
    """Per-query candidates: [B,N,Dt] x [B,Dq] -> [B,N] scores."""
    t32 = triple_feats.astype(jnp.float32)
    q32 = query_emb.astype(jnp.float32)
    h = t32 @ w1_t.astype(jnp.float32) \
        + (q32 @ w1_q.astype(jnp.float32) + b1)[:, None, :]
    h = jax.nn.relu(h)
    return (h @ w2.astype(jnp.float32))[..., 0] + b2[0]
