"""Fused SubgraphRAG triple-scorer Pallas kernel.

The retrieval hot path (paper §2: scorer R over candidate triples): for a
query batch, millions of candidate triples each get a relevance score from
a 2-layer MLP over [triple_features ++ query_embedding]. Because the query
part is shared across all triples of a query, the kernel splits the first
layer as

    h = relu(T @ W1_t  +  (q @ W1_q + b1))     score = h @ w2 + b2

and keeps all weights + the per-query bias VMEM-resident while streaming
128-triple tiles from HBM — one pass, no [N, hidden] intermediate in HBM.
The GPU baseline (SubgraphRAG) runs this as separate GEMM + bias + GEMM
launches with the hidden activations round-tripping through HBM.

Grid: (queries, triple_tiles); both parallel.
VMEM: W1_t [Dt, H] + tile [128, Dt] + h [128, H] — for Dt=1156, H=1024
(paper-scale) ≈ 5 MiB, within budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 128


def _score_kernel(t_ref, qb_ref, w1_ref, w2_ref, b2_ref, o_ref):
    t = t_ref[...].astype(jnp.float32)            # [tile, Dt]
    w1 = w1_ref[...].astype(jnp.float32)          # [Dt, H]
    qb = qb_ref[...].astype(jnp.float32)          # [1, H] query bias
    h = jax.lax.dot(t, w1, preferred_element_type=jnp.float32) + qb
    h = jnp.maximum(h, 0.0)
    w2 = w2_ref[...].astype(jnp.float32)          # [H, 1]
    score = jax.lax.dot(h, w2, preferred_element_type=jnp.float32)
    o_ref[...] = (score[:, 0] + b2_ref[0]).astype(o_ref.dtype)[None]


def _pad_to(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def triple_score(triple_feats: jax.Array, query_emb: jax.Array,
                 w1_t: jax.Array, w1_q: jax.Array, b1: jax.Array,
                 w2: jax.Array, b2: jax.Array,
                 tile: int = DEFAULT_TILE, interpret: bool = False) -> jax.Array:
    """Score N triples for Q queries.

    triple_feats: [N, Dt]; query_emb: [Q, Dq]; w1_t: [Dt, H]; w1_q: [Dq, H];
    b1: [H]; w2: [H, 1]; b2: [1]  ->  scores [Q, N].
    """
    n, dt = triple_feats.shape
    q_count = query_emb.shape[0]
    h_dim = w1_t.shape[1]
    if n % tile:
        raise ValueError(f"N={n} not divisible by tile={tile}")
    # Per-query first-layer bias, computed once (tiny GEMM).
    q_bias = (query_emb.astype(jnp.float32) @ w1_q.astype(jnp.float32)
              + b1.astype(jnp.float32))                       # [Q, H]
    grid = (q_count, n // tile)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, dt), lambda iq, it: (it, 0)),
            pl.BlockSpec((1, h_dim), lambda iq, it: (iq, 0)),
            pl.BlockSpec((dt, h_dim), lambda iq, it: (0, 0)),
            pl.BlockSpec((h_dim, 1), lambda iq, it: (0, 0)),
            pl.BlockSpec((1,), lambda iq, it: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda iq, it: (iq, it)),
        out_shape=jax.ShapeDtypeStruct((q_count, n), jnp.float32),
        interpret=interpret,
    )(triple_feats, q_bias, w1_t, w2, b2)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def triple_score_batched(triple_feats: jax.Array, query_emb: jax.Array,
                         w1_t: jax.Array, w1_q: jax.Array, b1: jax.Array,
                         w2: jax.Array, b2: jax.Array,
                         tile: int = DEFAULT_TILE,
                         interpret: bool = False) -> jax.Array:
    """Per-query candidate sets: each query scores only ITS OWN triples.

    triple_feats: [B, N, Dt]; query_emb: [B, Dq] -> scores [B, N].

    Same kernel body as :func:`triple_score` — the [B, N, Dt] batch is
    flattened to [B*Npad, Dt] and the block index map walks each query's
    own slice (block ``iq * tiles_per_query + it``), so weights and the
    per-query bias stay VMEM-resident exactly as in the shared-candidate
    variant. N is padded up to the tile size internally; padded rows are
    zero-feature triples whose scores are sliced off before returning
    (callers masking ragged candidate sets still pass their own
    ``n_cand`` downstream — see `repro.core.router.route_retrieved`).
    """
    b, n, dt = triple_feats.shape
    h_dim = w1_t.shape[1]
    npad = _pad_to(n, tile)
    feats = jnp.pad(triple_feats, ((0, 0), (0, npad - n), (0, 0)))
    flat = feats.reshape(b * npad, dt)
    q_bias = (query_emb.astype(jnp.float32) @ w1_q.astype(jnp.float32)
              + b1.astype(jnp.float32))                      # [B, H]
    tiles_per_query = npad // tile
    grid = (b, tiles_per_query)
    out = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, dt),
                         lambda iq, it: (iq * (npad // tile) + it, 0)),
            pl.BlockSpec((1, h_dim), lambda iq, it: (iq, 0)),
            pl.BlockSpec((dt, h_dim), lambda iq, it: (0, 0)),
            pl.BlockSpec((h_dim, 1), lambda iq, it: (0, 0)),
            pl.BlockSpec((1,), lambda iq, it: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda iq, it: (iq, it)),
        out_shape=jax.ShapeDtypeStruct((b, npad), jnp.float32),
        interpret=interpret,
    )(flat, q_bias, w1_t, w2, b2)
    return out[:, :n]
