"""Public wrapper for the fused triple scorer."""

from __future__ import annotations

import jax

from repro.kernels.triple_score import kernel, ref


def triple_score(triple_feats, query_emb, w1_t, w1_q, b1, w2, b2,
                 tile: int = kernel.DEFAULT_TILE):
    on_tpu = jax.default_backend() == "tpu"
    return kernel.triple_score(triple_feats, query_emb, w1_t, w1_q, b1,
                               w2, b2, tile=tile, interpret=not on_tpu)


triple_score_ref = ref.triple_score_ref
