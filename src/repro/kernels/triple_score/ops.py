"""Public wrappers for the fused triple scorer.

Both entry points resolve the compiled-vs-interpret choice at CALL time
via the canonical :func:`repro.kernels.device.default_interpret` check
(``interpret=None``), so an op reference captured off-TPU keeps working
when devices change — the same contract as the skew-metrics wrapper.
"""

from __future__ import annotations

from typing import Optional

from repro.kernels.device import default_interpret
from repro.kernels.triple_score import kernel, ref


def triple_score(triple_feats, query_emb, w1_t, w1_q, b1, w2, b2,
                 tile: int = kernel.DEFAULT_TILE,
                 interpret: Optional[bool] = None):
    """Shared candidate set: [N,Dt] x [Q,Dq] -> [Q,N]."""
    if interpret is None:
        interpret = default_interpret()
    return kernel.triple_score(triple_feats, query_emb, w1_t, w1_q, b1,
                               w2, b2, tile=tile, interpret=interpret)


def triple_score_batched(triple_feats, query_emb, w1_t, w1_q, b1, w2, b2,
                         tile: int = kernel.DEFAULT_TILE,
                         interpret: Optional[bool] = None):
    """Per-query candidate sets: [B,N,Dt] x [B,Dq] -> [B,N] (N padded to
    the tile size internally — any N works)."""
    if interpret is None:
        interpret = default_interpret()
    return kernel.triple_score_batched(triple_feats, query_emb, w1_t, w1_q,
                                       b1, w2, b2, tile=tile,
                                       interpret=interpret)


triple_score_ref = ref.triple_score_ref
triple_score_batched_ref = ref.triple_score_batched_ref
