"""The one canonical device-availability check for Pallas kernels.

Every layer that launches a kernel (kernel ops wrappers, the difficulty
backends in `repro.api`, the fused routing program in `repro.core.router`)
defers to this function AT CALL TIME: compiled on TPU, interpret mode
everywhere else. Keeping it here — the lowest layer, imported by
everything above — means the interpret-vs-compiled choice is never baked
into a serialized policy or session snapshot: a snapshot taken on TPU and
restored on CPU re-resolves against the restoring host's devices.
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels run compiled on TPU and in interpret mode elsewhere."""
    return jax.default_backend() != "tpu"
