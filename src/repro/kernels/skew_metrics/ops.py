"""Public wrapper for the fused skew-metrics kernel.

`skew_metrics` is the serving fast path: one fused pass producing all
four difficulty metrics, so downstream metric selection is a column
lookup (``METRIC_COLUMNS.index(name)``) instead of a recompile. On
non-TPU backends the kernel runs in Pallas interpret mode, which still
compiles to a single XLA computation under jit — batched dispatch stays
one device call either way.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.device import default_interpret
from repro.kernels.skew_metrics import kernel, ref
from repro.kernels.skew_metrics.kernel import METRIC_COLUMNS  # noqa: F401


def skew_metrics(scores_desc, p_cdf: float = 0.95,
                 n_valid: Optional[jax.Array] = None,
                 interpret: Optional[bool] = None):
    """[B, K] descending-sorted (+ optional [B] n_valid) -> [B, 4].

    ``n_valid`` is clamped to [1, K] (empty rows become one degenerate
    entry; see kernel docstring)."""
    if interpret is None:
        interpret = default_interpret()
    return kernel.skew_metrics(scores_desc, n_valid=n_valid, p_cdf=p_cdf,
                               interpret=interpret)


skew_metrics_ref = ref.skew_metrics_ref
mask_from_n_valid = ref.mask_from_n_valid
