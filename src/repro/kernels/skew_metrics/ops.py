"""Public wrapper for the fused skew-metrics kernel."""

from __future__ import annotations

import jax

from repro.kernels.skew_metrics import kernel, ref

METRIC_COLUMNS = ("area", "cumulative", "entropy", "gini")


def skew_metrics(scores_desc, p_cdf: float = 0.95):
    on_tpu = jax.default_backend() == "tpu"
    return kernel.skew_metrics(scores_desc, p_cdf=p_cdf,
                               interpret=not on_tpu)


skew_metrics_ref = ref.skew_metrics_ref
