"""Fused skewness-metric Pallas kernel — the SkewRoute router fast path.

Every request pays this op (paper Algorithm 1): given the top-K retrieval
scores (descending-sorted, as emitted by top-k), compute all four
difficulty metrics in ONE pass over a [rows, K] tile:

  col 0  area          sum(minmax-normalized)
  col 1  cumulative-k  #contexts to reach CDF >= P
  col 2  entropy       -sum p log2 p
  col 3  gini          (K+1 - 2 sum (K-i+1) s'_i / sum) / K

The descending order is exploited twice: the CDF needs no sort, and the
ascending-rank weights for Gini are just reversed descending ranks —
`repro.core.skewness` (the XLA oracle) sorts twice instead.

Grid: row tiles; one [rows_tile, K] VMEM block, four VPU reductions, one
[rows_tile, 4] store. K=100 pads to 128 lanes with -inf-aware masking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 8
_EPS = 1e-12


def _skew_kernel(s_ref, o_ref, *, k_valid: int, p_cdf: float):
    s = s_ref[...].astype(jnp.float32)                     # [rows, Kpad]
    rows, kpad = s.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, kpad), 1)
    valid = col < k_valid

    # min-max normalize (masked)
    s_hi = jnp.max(jnp.where(valid, s, -jnp.inf), axis=1, keepdims=True)
    s_lo = jnp.min(jnp.where(valid, s, jnp.inf), axis=1, keepdims=True)
    norm = jnp.where(valid, (s - s_lo) / (s_hi - s_lo + _EPS), 0.0)
    area = jnp.sum(norm, axis=1)

    # probability normalization (shift only if negatives, like the oracle)
    shifted = jnp.where(valid, s - jnp.minimum(s_lo, 0.0), 0.0)
    total = jnp.sum(shifted, axis=1, keepdims=True)
    prob = shifted / (total + _EPS)

    # cumulative-k: scores arrive descending, so CDF = running sum
    cdf = jnp.cumsum(prob, axis=1)
    below = jnp.where(valid, (cdf < p_cdf - _EPS).astype(jnp.float32), 0.0)
    cum_k = jnp.minimum(jnp.sum(below, axis=1) + 1.0, float(k_valid))

    # entropy (bits)
    plogp = jnp.where(prob > _EPS, prob * (jnp.log(prob + _EPS) / jnp.log(2.0)),
                      0.0)
    entropy = -jnp.sum(plogp, axis=1)

    # gini: ascending rank of column j (descending data) = k_valid - j
    asc_rank = (k_valid - col).astype(jnp.float32)         # 1-indexed
    weight = jnp.where(valid, k_valid - asc_rank + 1.0, 0.0)
    weighted = jnp.sum(weight * shifted, axis=1)
    tot = total[:, 0]
    gini = (k_valid + 1.0 - 2.0 * weighted / (tot + _EPS)) / k_valid
    gini = jnp.clip(gini, 0.0, 1.0)

    o_ref[...] = jnp.stack([area, cum_k, entropy, gini], axis=1)


@functools.partial(jax.jit, static_argnames=("p_cdf", "row_tile", "interpret"))
def skew_metrics(scores_desc: jax.Array, p_cdf: float = 0.95,
                 row_tile: int = DEFAULT_ROW_TILE,
                 interpret: bool = False) -> jax.Array:
    """scores_desc: [B, K] descending-sorted -> [B, 4] (area, k@P, H, gini)."""
    b, k = scores_desc.shape
    kpad = -(-k // 128) * 128
    bpad = -(-b // row_tile) * row_tile
    s = jnp.pad(scores_desc, ((0, bpad - b), (0, kpad - k)))
    out = pl.pallas_call(
        functools.partial(_skew_kernel, k_valid=k, p_cdf=p_cdf),
        grid=(bpad // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, kpad), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, 4), jnp.float32),
        interpret=interpret,
    )(s)
    return out[:b]
