"""Fused skewness-metric Pallas kernel — the SkewRoute router fast path.

Every request pays this op (paper Algorithm 1): given the top-K retrieval
scores (descending-sorted, as emitted by top-k), compute all four
difficulty metrics in ONE pass over a [rows, K] tile:

  col 0  area          sum(minmax-normalized)
  col 1  cumulative-k  #contexts to reach CDF >= P
  col 2  entropy       -sum p log2 p
  col 3  gini          (K+1 - 2 sum (K-i+1) s'_i / sum) / K

The descending order is exploited twice: the CDF needs no sort, and the
paper's ascending-rank Gini weight (K - i + 1) collapses to (column + 1)
for descending data — `repro.core.skewness` (the XLA oracle) sorts twice
instead.

Ragged retrieval is first-class: an optional per-row ``n_valid`` vector
(matching the oracle's prefix-``mask`` support) rides along as a
[rows, 1] int32 block; every reduction masks columns >= n_valid and the
Gini/cumulative normalizers use the per-row count. All four metrics are
always emitted, so the router's metric choice is a column select — never
a recompile.

Grid: row tiles; one [rows_tile, K] VMEM block, four VPU reductions, one
[rows_tile, 4] store. K=100 pads to 128 lanes with mask-aware reductions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_TILE = 8
_EPS = 1e-12

METRIC_COLUMNS = ("area", "cumulative", "entropy", "gini")


def _skew_kernel(s_ref, nv_ref, o_ref, *, p_cdf: float):
    s = s_ref[...].astype(jnp.float32)                     # [rows, Kpad]
    rows, kpad = s.shape
    nv = nv_ref[...]                                       # [rows, 1] int32
    nvf = nv.astype(jnp.float32)                           # [rows, 1]
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, kpad), 1)
    valid = col < nv

    # min-max normalize (masked)
    s_hi = jnp.max(jnp.where(valid, s, -jnp.inf), axis=1, keepdims=True)
    s_lo = jnp.min(jnp.where(valid, s, jnp.inf), axis=1, keepdims=True)
    norm = jnp.where(valid, (s - s_lo) / (s_hi - s_lo + _EPS), 0.0)
    area = jnp.sum(norm, axis=1)

    # probability normalization (shift only if negatives, like the oracle)
    shifted = jnp.where(valid, s - jnp.minimum(s_lo, 0.0), 0.0)
    total = jnp.sum(shifted, axis=1, keepdims=True)
    prob = shifted / (total + _EPS)

    # cumulative-k: scores arrive descending, so CDF = running sum
    cdf = jnp.cumsum(prob, axis=1)
    below = jnp.where(valid, (cdf < p_cdf - _EPS).astype(jnp.float32), 0.0)
    cum_k = jnp.minimum(jnp.sum(below, axis=1) + 1.0, nvf[:, 0])

    # entropy (bits) — jnp.log2 to match the oracle's formulation exactly
    plogp = jnp.where(prob > _EPS, prob * jnp.log2(prob + _EPS), 0.0)
    entropy = -jnp.sum(plogp, axis=1)

    # gini: paper weight (n - asc_rank + 1) over ascending-sorted data is
    # just (col + 1) for descending-sorted data
    weight = jnp.where(valid, (col + 1).astype(jnp.float32), 0.0)
    weighted = jnp.sum(weight * shifted, axis=1)
    tot = total[:, 0]
    n1 = jnp.maximum(nvf[:, 0], 1.0)
    gini = (n1 + 1.0 - 2.0 * weighted / (tot + _EPS)) / n1
    gini = jnp.clip(gini, 0.0, 1.0)

    o_ref[...] = jnp.stack([area, cum_k, entropy, gini], axis=1)


@functools.partial(jax.jit,
                   static_argnames=("p_cdf", "row_tile", "interpret"))
def skew_metrics(scores_desc: jax.Array,
                 n_valid: jax.Array | None = None,
                 p_cdf: float = 0.95,
                 row_tile: int = DEFAULT_ROW_TILE,
                 interpret: bool = False) -> jax.Array:
    """[B, K] descending-sorted -> [B, 4] (area, k@P, H, gini).

    ``n_valid``: optional [B] int32 count of valid leading entries per row
    (ragged retrieval); defaults to K everywhere. Clamped to [1, K]: an
    empty retrieval (0) is treated as one degenerate entry — the oracle's
    all-false mask instead reports cumulative_k = 0, so route zero-hit
    requests before they reach the kernel.
    """
    b, k = scores_desc.shape
    kpad = -(-k // 128) * 128
    bpad = -(-b // row_tile) * row_tile
    s = jnp.pad(scores_desc, ((0, bpad - b), (0, kpad - k)))
    if n_valid is None:
        nv = jnp.full((b,), k, jnp.int32)
    else:
        nv = jnp.clip(jnp.asarray(n_valid, jnp.int32), 1, k)
    nv = jnp.pad(nv, (0, bpad - b), constant_values=1)[:, None]
    out = pl.pallas_call(
        functools.partial(_skew_kernel, p_cdf=p_cdf),
        grid=(bpad // row_tile,),
        in_specs=[pl.BlockSpec((row_tile, kpad), lambda i: (i, 0)),
                  pl.BlockSpec((row_tile, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((row_tile, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bpad, 4), jnp.float32),
        interpret=interpret,
    )(s, nv)
    return out[:b]
