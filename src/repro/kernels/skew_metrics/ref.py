"""Oracle: the four metrics from repro.core.skewness, stacked."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import skewness


def skew_metrics_ref(scores_desc, p_cdf: float = 0.95):
    """[B, K] descending-sorted -> [B, 4] (area, cum_k, entropy, gini)."""
    return jnp.stack([
        skewness.area_metric(scores_desc),
        skewness.cumulative_k(scores_desc, p_cdf),
        skewness.entropy_metric(scores_desc),
        skewness.gini_metric(scores_desc),
    ], axis=1)
