"""Oracle: the four metrics from repro.core.skewness, stacked."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import skewness


def mask_from_n_valid(n_valid: jax.Array, k: int) -> jax.Array:
    """[B] valid-prefix counts -> [B, K] boolean mask (descending top-k
    output is always a valid prefix)."""
    return jnp.arange(k)[None, :] < jnp.asarray(n_valid)[:, None]


def skew_metrics_ref(scores_desc, p_cdf: float = 0.95,
                     mask: Optional[jax.Array] = None):
    """[B, K] descending-sorted -> [B, 4] (area, cum_k, entropy, gini).

    ``mask`` mirrors the oracle's ragged support; the fused kernel's
    ``n_valid`` is the prefix special case (see ``mask_from_n_valid``).
    """
    return jnp.stack([
        skewness.area_metric(scores_desc, mask),
        skewness.cumulative_k(scores_desc, p_cdf, mask),
        skewness.entropy_metric(scores_desc, mask),
        skewness.gini_metric(scores_desc, mask),
    ], axis=1)
