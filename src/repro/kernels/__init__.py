"""Pallas TPU kernels for the perf-critical hot spots (DESIGN §6).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with an interpret/XLA fallback) and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
