"""Pallas TPU kernels for the perf-critical hot spots (DESIGN §6).

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper with an interpret/XLA fallback) and
ref.py (pure-jnp oracle used by the allclose test sweeps).

`repro.kernels.device.default_interpret` is the canonical call-time
compiled-vs-interpret decision shared by every wrapper.
"""

from repro.kernels.device import default_interpret  # noqa: F401
