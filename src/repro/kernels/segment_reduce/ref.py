"""Oracle: jax.ops.segment_sum over the same layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_sum_sorted_ref(rows, seg_ids, n_segments, rows_per_seg=None):
    safe = jnp.where(seg_ids >= 0, seg_ids, n_segments)
    out = jax.ops.segment_sum(rows, safe, num_segments=n_segments + 1)
    return out[:n_segments]
