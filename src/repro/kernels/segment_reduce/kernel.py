"""Sorted-segment-sum Pallas kernel (GNN aggregation / EmbeddingBag reduce).

The scatter half of message passing and the reduce half of EmbeddingBag
are both "sum rows [N, D] into segments given sorted segment ids". XLA
lowers this to scatter-adds; this kernel instead streams row tiles and
uses a ONE-HOT MATMUL on the MXU per tile:

    out_tile[segments, D] += onehot(local_seg, [tile, n_seg_tile]) ^T @ rows

Constraint (documented, checked by the wrapper): segment ids are sorted
ascending and each output tile of ``seg_tile`` segments receives rows
only from a bounded window — the caller supplies ``rows_per_seg_tile``
(static) mapping each segment tile to its row-tile window. For
embedding-bag (fixed nnz per bag) and padded GNN minibatches this is
exact; the irregular full-graph case stays on the XLA segment_sum path.

Grid: (segment_tiles,); rows window streamed in an inner loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(rows_ref, seg_ref, o_ref, *, seg_tile: int,
                   rows_per_tile: int):
    it = pl.program_id(0)
    seg_base = it * seg_tile
    rows = rows_ref[...].astype(jnp.float32)          # [rows_win, D]
    seg = seg_ref[0]                                  # [rows_win] int32
    local = seg - seg_base
    onehot = (local[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (rows_per_tile, seg_tile), 1)).astype(jnp.float32)
    # MXU: [seg_tile, rows_win] @ [rows_win, D]
    acc = jax.lax.dot_general(onehot, rows, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_segments", "seg_tile",
                                             "rows_per_seg", "interpret"))
def segment_sum_sorted(rows: jax.Array, seg_ids: jax.Array, n_segments: int,
                       rows_per_seg: int, seg_tile: int = 8,
                       interpret: bool = False) -> jax.Array:
    """rows: [N, D]; seg_ids: [N] sorted ascending with EXACTLY
    ``rows_per_seg`` rows per segment (embedding-bag layout; pad rows get
    seg_id = -1 and are dropped). Returns [n_segments, D] sums.
    """
    n, d = rows.shape
    if n != n_segments * rows_per_seg:
        raise ValueError(f"N={n} != n_segments*rows_per_seg "
                         f"({n_segments}x{rows_per_seg})")
    if n_segments % seg_tile:
        raise ValueError(f"n_segments={n_segments} not divisible by "
                         f"seg_tile={seg_tile}")
    rows_win = seg_tile * rows_per_seg
    return pl.pallas_call(
        functools.partial(_segsum_kernel, seg_tile=seg_tile,
                          rows_per_tile=rows_win),
        grid=(n_segments // seg_tile,),
        in_specs=[
            pl.BlockSpec((rows_win, d), lambda i: (i, 0)),
            pl.BlockSpec((1, rows_win), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((seg_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_segments, d), rows.dtype),
        interpret=interpret,
    )(rows, seg_ids.reshape(1, -1).astype(jnp.int32))
