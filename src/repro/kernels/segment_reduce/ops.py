"""Public wrapper: embedding-bag style sorted segment sum."""

from __future__ import annotations

import jax

from repro.kernels.segment_reduce import kernel, ref


def segment_sum_sorted(rows, seg_ids, n_segments, rows_per_seg,
                       seg_tile: int = 8):
    on_tpu = jax.default_backend() == "tpu"
    return kernel.segment_sum_sorted(rows, seg_ids, n_segments,
                                     rows_per_seg, seg_tile=seg_tile,
                                     interpret=not on_tpu)


def embedding_bag_fused(table, ids, n_bags, combiner: str = "sum"):
    """EmbeddingBag with the Pallas reduce: ids [B, nnz] (-1 pad) ->
    [B, dim]. Gather stays on XLA's native path; the reduce is the kernel."""
    import jax.numpy as jnp
    b, nnz = ids.shape
    rows = jnp.take(table, jnp.maximum(ids.reshape(-1), 0), axis=0)
    rows = jnp.where(ids.reshape(-1, 1) >= 0, rows, 0)
    seg = jnp.repeat(jnp.arange(b), nnz)
    out = segment_sum_sorted(rows, seg, b, nnz)
    if combiner == "mean":
        counts = jnp.maximum(jnp.sum(ids >= 0, axis=1, keepdims=True), 1)
        out = out / counts.astype(out.dtype)
    return out


segment_sum_sorted_ref = ref.segment_sum_sorted_ref
