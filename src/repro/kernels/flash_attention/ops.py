"""Public wrapper: Pallas flash attention with XLA fallback.

On TPU the Pallas kernel runs natively; elsewhere (CPU tests, dry-run
host devices) `interpret=True` executes the same kernel body through the
Pallas interpreter, and `repro.models.flash.flash_attention` provides the
production XLA fallback used by the sharded model code.
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention import kernel, ref


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    block_q: int = kernel.DEFAULT_BLOCK_Q,
                    block_k: int = kernel.DEFAULT_BLOCK_K) -> jax.Array:
    """[B,H,Sq,Dh] x [B,KV,Sk,Dh]^2 -> [B,H,Sq,Dh] (causal, Sq == Sk)."""
    on_tpu = jax.default_backend() == "tpu"
    return kernel.flash_attention(q, k, v, block_q=block_q, block_k=block_k,
                                  interpret=not on_tpu)


attention_ref = ref.attention_ref
