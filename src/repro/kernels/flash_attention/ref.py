"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q: [B, H, Sq, Dh]; k/v: [B, KV, Sk, Dh] -> [B, H, Sq, Dh]; causal."""
    b, h, sq, dh = q.shape
    kv, sk = k.shape[1], k.shape[2]
    g = h // kv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    q_pos = jnp.arange(sq)[:, None] + (sk - sq)
    mask = jnp.arange(sk)[None, :] <= q_pos
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
