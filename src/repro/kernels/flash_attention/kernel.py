"""Causal GQA flash-attention Pallas kernel (prefill path).

Tiling: grid (B, H, Sq/bq, Sk/bk); the last (KV) dimension is sequential
("arbitrary" semantics on TPU), so the online-softmax state (m, l, acc)
lives in VMEM scratch and persists across KV steps for a fixed (b, h, iq).
Blocks are MXU-aligned (bq = bk = 128 by default, head_dim a lane
multiple). K/V BlockSpecs index the kv head as ``h // group`` — no
materialized head repeat, unlike the XLA fallback (`repro.models.flash`).

Causal masking: KV blocks entirely above the diagonal are skipped via
``pl.when``; the diagonal block masks with a broadcasted-iota comparison.

VMEM budget per step (bq=bk=128, dh=128, fp32 scratch):
  q 64 KiB + k/v 64 KiB ea + acc 64 KiB + p 64 KiB + m/l 1 KiB < 0.5 MiB,
comfortably inside the ~16 MiB/core budget, leaving room for the compiler
to double-buffer the HBM->VMEM streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # Causal: query i sees keys <= i. Skip blocks fully above the diagonal.
    diag_possible = k_start <= q_start + block_q - 1

    @pl.when(diag_possible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, _NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])                    # [bq, bk]
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                # [bk, dh]
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot(p, v, preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: [B, H, Sq, Dh]; k/v: [B, KV, Sk, Dh] -> out [B, H, Sq, Dh].

    Causal; requires Sq == Sk (prefill) and block-divisible seq lens.
    """
    b, h, sq, dh = q.shape
    kv, sk = k.shape[1], k.shape[2]
    group = h // kv
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens ({sq},{sk}) not divisible by blocks "
                         f"({block_q},{block_k})")
    if sq != sk:
        raise NotImplementedError("prefill kernel expects Sq == Sk; decode "
                                  "uses repro.kernels.decode_attention")
    grid = (b, h, sq // block_q, sk // block_k)
    kernel = functools.partial(_flash_kernel, scale=dh ** -0.5,
                               block_q=block_q, block_k=block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
