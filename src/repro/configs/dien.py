"""dien: embed_dim 18, seq_len 100, gru_dim 108, MLP 200-80, AUGRU
interaction [arXiv:1809.03672; unverified]. Amazon-Books vocab."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.recsys import AMAZON_BOOKS_VOCABS, RecsysConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = RecsysConfig(
    name="dien", model="dien", n_dense=0, n_sparse=3, embed_dim=18,
    vocab_sizes=(AMAZON_BOOKS_VOCABS["user"], AMAZON_BOOKS_VOCABS["item"],
                 AMAZON_BOOKS_VOCABS["cat"]),
    deep_mlp=(200, 80), seq_len=100, gru_dim=108, interaction="augru")

ARCH = ArchSpec(arch_id="dien", family="recsys", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=1e-3),
                source="arXiv:1809.03672; unverified")
