"""Architecture registry plumbing: ArchSpec + per-family cell builders.

Every assigned architecture file exports ``ARCH: ArchSpec``. A *cell* is
one (architecture x input-shape) pair; ``build_cell`` returns everything
the dry-run / launcher needs to lower it on the active mesh:

    fn            step callable (closed over configs)
    args          tuple of ShapeDtypeStruct pytrees (NO device allocation)
    in_specs      PartitionSpec pytrees matching args
    out_specs     PartitionSpec pytree or None (let GSPMD infer)
    donate        argnums to donate (state/cache buffers)

The same builders are used with real arrays by examples/ and launch/.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import gnn as gnn_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.models.layers import LMConfig
from repro.models.gnn import GNNConfig
from repro.models.recsys import RecsysConfig
from repro.training import optimizer as opt_lib
from repro.training import train_loop

# ---------------------------------------------------------------------------
# Shape tables (assigned per family)
# ---------------------------------------------------------------------------

LM_SHAPES: dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# Edge arrays shard over (pod, data); counts pad up to a multiple of 512
# (padded edges hit the dummy node slot — segment.pad_edges semantics).
GNN_SHAPES: dict[str, dict] = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10752,
                          n_edges_real=10556, d_feat=1433, n_classes=7,
                          loss="node"),
    "minibatch_lg": dict(kind="train", n_nodes=169_984, n_edges=168_960,
                         d_feat=602, n_classes=41, loss="node",
                         note="fanout-(15,10) sampled subgraph of the "
                              "232,965-node / 114.6M-edge graph"),
    "ogb_products": dict(kind="train", n_nodes=2_449_029, n_edges=61_859_328,
                         n_edges_real=61_859_140, d_feat=100, n_classes=47,
                         loss="node"),
    "molecule": dict(kind="train", n_graphs=128, nodes_per_graph=30,
                     edges_per_graph=64, d_feat=32, n_classes=2, loss="graph"),
}

RECSYS_SHAPES: dict[str, dict] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                      # lm | gnn | recsys
    config: Any                      # LMConfig | GNNConfig | RecsysConfig
    optimizer: opt_lib.OptimizerConfig
    source: str                      # citation tag from the assignment
    accum_steps: int = 1             # gradient accumulation (train shapes)

    @property
    def shapes(self) -> dict[str, dict]:
        return {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                "recsys": RECSYS_SHAPES}[self.family]


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_id: str
    kind: str
    fn: Callable
    args: tuple
    in_specs: tuple
    out_specs: Any
    donate: tuple[int, ...]
    meta: dict


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _batch_axes(global_batch: int) -> Optional[str]:
    """Logical batch axis, or None when batch can't shard (batch==1)."""
    return "batch" if global_batch > 1 else None


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_state_specs(cfg: LMConfig, opt_cfg: opt_lib.OptimizerConfig):
    params = tfm.param_spec(cfg)
    state = jax.eval_shape(lambda p: train_loop.init_train_state(p, opt_cfg), params)
    p_pspecs = shd.tree_pspecs(params)
    state_pspecs = {
        "params": p_pspecs,
        "opt": opt_lib.state_pspecs(params, p_pspecs, opt_cfg),
        "step": P(),
    }
    return params, state, p_pspecs, state_pspecs


def build_lm_cell(arch: ArchSpec, shape_id: str) -> Cell:
    cfg: LMConfig = arch.config
    sh = LM_SHAPES[shape_id]
    b, s = sh["global_batch"], sh["seq_len"]
    batch_ax = _batch_axes(b)

    if sh["kind"] == "train":
        params, state, _, state_pspecs = _lm_state_specs(cfg, arch.optimizer)
        step = train_loop.make_train_step(
            functools.partial(_lm_loss, cfg=cfg), arch.optimizer,
            accum_steps=arch.accum_steps)
        batch = {"tokens": _sds((b, s), jnp.int32),
                 "labels": _sds((b, s), jnp.int32)}
        batch_specs = {"tokens": shd.spec_for(batch_ax, None),
                       "labels": shd.spec_for(batch_ax, None)}
        return Cell(arch.arch_id, shape_id, "train", step, (state, batch),
                    (state_pspecs, batch_specs), None, donate=(0,),
                    meta=dict(model_flops=6 * cfg.n_active_params * b * s,
                              tokens=b * s))

    params = tfm.param_spec(cfg)
    p_pspecs = shd.tree_pspecs(params)

    if sh["kind"] == "prefill":
        fn = functools.partial(_lm_prefill, cfg=cfg)
        tokens = _sds((b, s), jnp.int32)
        return Cell(arch.arch_id, shape_id, "prefill", fn, (params, tokens),
                    (p_pspecs, shd.spec_for(batch_ax, None)), None, donate=(),
                    meta=dict(model_flops=2 * cfg.n_active_params * b * s,
                              tokens=b * s))

    # decode: one new token against a seq-long cache
    cache = tfm.cache_spec(cfg, b, s)
    cache_spec_leaf = _decode_cache_pspec(b)
    cache_specs = {"k": cache_spec_leaf, "v": cache_spec_leaf}
    fn = functools.partial(_lm_decode, cfg=cfg)
    tokens = _sds((b, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    return Cell(arch.arch_id, shape_id, "decode", fn,
                (params, cache, tokens, pos),
                (p_pspecs, cache_specs, shd.spec_for(batch_ax, None), P()),
                None, donate=(1,),
                meta=dict(model_flops=2 * cfg.n_active_params * b
                          + 2 * cfg.n_layers * cfg.kv_dim * 2 * s * b,
                          tokens=b))


def _decode_cache_pspec(batch: int) -> P:
    """Cache [L, B, S, KVD]: batch over data axes + seq over model (split-KV
    flash-decode); for batch==1 spread seq across EVERY mesh axis."""
    mesh = shd.active_mesh()
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names)
    model = ("model",) if "model" in names else ()
    if batch > 1:
        return P(None, dp or None, model or None, None)
    seq = dp + model
    return P(None, None, seq or None, None)


def _lm_loss(params, batch, cfg: LMConfig):
    return tfm.train_loss(params, batch, cfg)


def _lm_prefill(params, tokens, cfg: LMConfig):
    return tfm.prefill(params, tokens, cfg)


def _lm_decode(params, cache, tokens, pos, cfg: LMConfig):
    return tfm.decode_step(params, cache, tokens, pos, cfg)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def build_gnn_cell(arch: ArchSpec, shape_id: str) -> Cell:
    cfg: GNNConfig = arch.config
    sh = GNN_SHAPES[shape_id]

    if sh["loss"] == "graph":
        n_nodes = sh["n_graphs"] * sh["nodes_per_graph"]
        n_edges = sh["n_graphs"] * sh["edges_per_graph"]
        batch = {
            "feats": _sds((n_nodes, sh["d_feat"]), jnp.float32),
            "src": _sds((n_edges,), jnp.int32),
            "dst": _sds((n_edges,), jnp.int32),
            "graph_ids": _sds((n_nodes,), jnp.int32),
            "labels": _sds((sh["n_graphs"],), jnp.int32),
        }
        def loss(params, b, cfg=cfg, sh=sh):
            return gnn_lib.graph_loss(params, cfg, b, sh["d_feat"], sh["n_classes"])
    else:
        batch = {
            "feats": _sds((sh["n_nodes"], sh["d_feat"]), jnp.float32),
            "src": _sds((sh["n_edges"],), jnp.int32),
            "dst": _sds((sh["n_edges"],), jnp.int32),
            "labels": _sds((sh["n_nodes"],), jnp.int32),
            "label_mask": _sds((sh["n_nodes"],), jnp.bool_),
        }
        def loss(params, b, cfg=cfg, sh=sh):
            return gnn_lib.node_loss(params, cfg, b, sh["d_feat"], sh["n_classes"])

    params = jax.eval_shape(
        lambda k: gnn_lib.init_params(k, cfg, sh["d_feat"], sh["n_classes"]),
        jax.random.key(0))
    state = jax.eval_shape(
        lambda p: train_loop.init_train_state(p, arch.optimizer), params)
    p_pspecs = shd.tree_pspecs(params)
    state_pspecs = {"params": p_pspecs,
                    "opt": opt_lib.state_pspecs(params, p_pspecs, arch.optimizer),
                    "step": P()}
    # Edge-parallel GNN: edge arrays shard over (pod, data); node arrays
    # (features, labels, masks, graph ids) are replicated in the baseline.
    edge_spec = shd.spec_for("edge")
    batch_specs = {k: (edge_spec if k in ("src", "dst")
                       else P(*([None] * v.ndim)))
                   for k, v in batch.items()}

    step = train_loop.make_train_step(loss, arch.optimizer)
    n_edges = batch["src"].shape[0]
    d_msg = cfg.n_heads * cfg.d_hidden
    return Cell(arch.arch_id, shape_id, "train", step, (state, batch),
                (state_pspecs, batch_specs), None, donate=(0,),
                meta=dict(model_flops=6 * n_edges * d_msg
                          + 6 * batch["feats"].shape[0] * sh["d_feat"] * d_msg,
                          tokens=batch["feats"].shape[0]))


# ---------------------------------------------------------------------------
# Recsys cells
# ---------------------------------------------------------------------------


def _recsys_batch_specs(cfg: RecsysConfig, batch: dict, batch_ax):
    specs = {}
    for k, v in batch.items():
        specs[k] = shd.spec_for(*([batch_ax] + [None] * (v.ndim - 1)))
    return specs


def recsys_batch_spec(cfg: RecsysConfig, b: int, with_labels: bool) -> dict:
    batch = {}
    if cfg.model == "dien":
        batch.update(
            user_id=_sds((b,), jnp.int32),
            target_item=_sds((b,), jnp.int32),
            target_cat=_sds((b,), jnp.int32),
            hist_items=_sds((b, cfg.seq_len), jnp.int32),
            hist_cats=_sds((b, cfg.seq_len), jnp.int32),
            hist_mask=_sds((b, cfg.seq_len), jnp.bool_),
        )
    else:
        batch["sparse"] = _sds((b, cfg.n_sparse), jnp.int32)
        if cfg.n_dense:
            batch["dense"] = _sds((b, cfg.n_dense), jnp.float32)
    if with_labels:
        batch["labels"] = _sds((b,), jnp.float32)
    return batch


def build_recsys_cell(arch: ArchSpec, shape_id: str) -> Cell:
    cfg: RecsysConfig = arch.config
    sh = RECSYS_SHAPES[shape_id]
    b = sh["batch"]
    batch_ax = _batch_axes(b)

    params = jax.eval_shape(lambda k: rec_lib.init_params(k, cfg),
                            jax.random.key(0))
    p_pspecs = shd.tree_pspecs(params)
    # dense-FLOPs proxy: MLP/cross/interaction work per example
    mlp_dims = ((cfg.n_dense,) + cfg.bot_mlp, cfg.top_mlp, cfg.deep_mlp)
    dense_flops = sum(2 * a * bb for stack in mlp_dims
                      for a, bb in zip(stack[:-1], stack[1:]))
    dense_flops += cfg.n_cross_layers * 2 * (cfg.n_dense + cfg.n_sparse * cfg.embed_dim) ** 2
    if cfg.model == "dien":
        dense_flops += cfg.seq_len * 2 * (2 * cfg.embed_dim + cfg.gru_dim) * 3 * cfg.gru_dim * 2

    if sh["kind"] == "train":
        state = jax.eval_shape(
            lambda p: train_loop.init_train_state(p, arch.optimizer), params)
        state_pspecs = {"params": p_pspecs,
                        "opt": opt_lib.state_pspecs(params, p_pspecs, arch.optimizer),
                        "step": P()}
        batch = recsys_batch_spec(cfg, b, with_labels=True)
        step = train_loop.make_train_step(
            lambda p, bt: rec_lib.loss(p, cfg, bt), arch.optimizer)
        return Cell(arch.arch_id, shape_id, "train", step, (state, batch),
                    (state_pspecs, _recsys_batch_specs(cfg, batch, batch_ax)),
                    None, donate=(0,),
                    meta=dict(model_flops=6 * dense_flops * b, tokens=b))

    if sh["kind"] == "serve":
        batch = recsys_batch_spec(cfg, b, with_labels=False)
        fn = lambda p, bt: rec_lib.forward(p, cfg, bt)
        return Cell(arch.arch_id, shape_id, "serve", fn, (params, batch),
                    (p_pspecs, _recsys_batch_specs(cfg, batch, batch_ax)),
                    None, donate=(),
                    meta=dict(model_flops=2 * dense_flops * b, tokens=b))

    # retrieval: 1 user x 1M candidates
    batch = recsys_batch_spec(cfg, b, with_labels=False)
    cand = _sds((sh["n_candidates"],), jnp.int32)
    fn = lambda p, bt, c: rec_lib.retrieval_scores(p, cfg, bt, c)
    return Cell(arch.arch_id, shape_id, "retrieval", fn, (params, batch, cand),
                (p_pspecs, _recsys_batch_specs(cfg, batch, batch_ax),
                 shd.spec_for("candidate")), None, donate=(),
                meta=dict(model_flops=2 * sh["n_candidates"] * cfg.embed_dim * b,
                          tokens=b))


# ---------------------------------------------------------------------------
# Entry
# ---------------------------------------------------------------------------

_BUILDERS = {"lm": build_lm_cell, "gnn": build_gnn_cell,
             "recsys": build_recsys_cell}


def build_cell(arch: ArchSpec, shape_id: str) -> Cell:
    if shape_id not in arch.shapes:
        raise KeyError(f"{arch.arch_id} has no shape {shape_id!r}; "
                       f"valid: {sorted(arch.shapes)}")
    return _BUILDERS[arch.family](arch, shape_id)


def input_specs(arch: ArchSpec, shape_id: str) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of a cell —
    weak-type-correct, shardable, no device allocation (the dry-run
    contract). Returns the full argument tuple the cell's step takes
    (state/params included; the trailing entries are the data batch)."""
    return build_cell(arch, shape_id).args
