"""deepfm: 39 sparse fields, embed 10, deep MLP 400-400-400, FM
interaction [arXiv:1703.04247; paper]. 1M-bucket hashed vocab/field."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.recsys import RecsysConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = RecsysConfig(
    name="deepfm", model="deepfm", n_dense=0, n_sparse=39, embed_dim=10,
    vocab_sizes=(1_000_000,) * 39, deep_mlp=(400, 400, 400),
    interaction="fm")

ARCH = ArchSpec(arch_id="deepfm", family="recsys", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=1e-3),
                source="arXiv:1703.04247; paper")
