"""arctic-480b: 35L, d_model 7168, 56 heads (GQA kv=8), expert d_ff 4864,
vocab 32000, MoE 128 experts top-2 + dense residual branch
[hf:Snowflake/snowflake-arctic-base; hf]. XL serving tier.

Dense residual: Arctic runs a small dense FFN in parallel with the routed
experts -> MoEConfig.shared_expert=True with the dense d_ff. Adafactor w/
bf16 momentum: Adam fp32 states for ~467B params (3.7 TB) cannot fit
256 x 16 GB (DESIGN §4)."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.layers import LMConfig, MoEConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = LMConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56,
    n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32000,
    activation="swiglu",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, capacity_factor=1.25,
                  shared_expert=True),
    rope_theta=10000.0, tie_embeddings=False, dtype=jnp.bfloat16)

# accum 8: per-microbatch activation/dispatch temporaries are the peak-
# memory driver at 480B scale (dry-run: 33.5 GiB/dev without accumulation).
ARCH = ArchSpec(arch_id="arctic-480b", family="lm", config=CONFIG,
                optimizer=OptimizerConfig(name="adafactor", lr=1e-4,
                                          momentum_dtype=jnp.bfloat16),
                source="hf:Snowflake/snowflake-arctic-base; hf",
                accum_steps=8)
