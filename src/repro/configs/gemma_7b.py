"""gemma-7b: 28L, d_model 3072, 16 heads (kv=16 -> MHA), head_dim 256,
d_ff 24576, GeGLU, vocab 256000, tied embeddings w/ sqrt(d) scaling
[arXiv:2403.08295; hf]. Medium / cross-family routing tier."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.layers import LMConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = LMConfig(
    name="gemma-7b", n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    head_dim=256, d_ff=24576, vocab=256000, activation="geglu",
    rope_theta=10000.0, tie_embeddings=True, scale_embed=True,
    dtype=jnp.bfloat16)

ARCH = ArchSpec(arch_id="gemma-7b", family="lm", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=3e-4),
                source="arXiv:2403.08295; hf")
