"""dlrm-mlperf: 13 dense + 26 sparse, embed 128, bottom MLP 13-512-256-128,
top MLP 1024-1024-512-256-1, dot interaction — MLPerf DLRM benchmark
config on Criteo 1TB [arXiv:1906.00091; paper]. 187.7M embedding rows."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.recsys import CRITEO_TB_VOCABS, RecsysConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = RecsysConfig(
    name="dlrm-mlperf", model="dlrm", n_dense=13, n_sparse=26,
    embed_dim=128, vocab_sizes=CRITEO_TB_VOCABS,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
    interaction="dot")

ARCH = ArchSpec(arch_id="dlrm-mlperf", family="recsys", config=CONFIG,
                optimizer=OptimizerConfig(name="adagrad", lr=1e-2),
                source="arXiv:1906.00091; paper")
