"""internlm2-20b: 48L, d_model 6144, 48 heads (GQA kv=8), d_ff 16384,
vocab 92544 [arXiv:2403.17297; hf]. Large-LM serving tier in SkewRoute."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.layers import LMConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = LMConfig(
    name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
    n_kv_heads=8, head_dim=128, d_ff=16384, vocab=92544,
    activation="swiglu", rope_theta=1_000_000.0, tie_embeddings=False,
    dtype=jnp.bfloat16)

ARCH = ArchSpec(arch_id="internlm2-20b", family="lm", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=3e-4),
                source="arXiv:2403.17297; hf")
