"""Per-architecture configs (assigned pool) + SkewRoute deployment config."""
