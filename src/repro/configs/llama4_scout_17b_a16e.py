"""llama4-scout-17b-a16e: 48L, d_model 5120, 40 heads (GQA kv=8), expert
d_ff 8192, vocab 202048, MoE 16 experts top-1 + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. MoE serving tier.

Adafactor: ~109B total params; Adam fp32 m+v would be ~0.9 TB."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.layers import LMConfig, MoEConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = LMConfig(
    name="llama4-scout-17b-a16e", n_layers=48, d_model=5120, n_heads=40,
    n_kv_heads=8, head_dim=128, d_ff=8192, vocab=202048,
    activation="swiglu",
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192, capacity_factor=1.25,
                  shared_expert=True),
    rope_theta=500_000.0, tie_embeddings=False, dtype=jnp.bfloat16)

# accum 2: 17.96 GiB/dev at accum=1 on the single-pod mesh (dry-run).
ARCH = ArchSpec(arch_id="llama4-scout-17b-a16e", family="lm", config=CONFIG,
                optimizer=OptimizerConfig(name="adafactor", lr=1e-4,
                                          momentum_dtype=jnp.bfloat16),
                source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
                accum_steps=2)
