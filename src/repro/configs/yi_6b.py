"""yi-6b: 32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000
[arXiv:2403.04652; hf]. llama-arch GQA; small-LM serving tier."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.layers import LMConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = LMConfig(
    name="yi-6b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    head_dim=128, d_ff=11008, vocab=64000, activation="swiglu",
    rope_theta=5_000_000.0, tie_embeddings=False, dtype=jnp.bfloat16)

ARCH = ArchSpec(arch_id="yi-6b", family="lm", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=3e-4),
                source="arXiv:2403.04652; hf")
