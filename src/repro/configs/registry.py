"""All assigned architectures, importable by id (``--arch <id>``)."""

from repro.configs import (arctic_480b, dcn_v2, deepfm, dien, dlrm_mlperf,
                           gat_cora, gemma_7b, internlm2_20b,
                           llama4_scout_17b_a16e, yi_6b)
from repro.configs.base import ArchSpec, build_cell  # noqa: F401

ARCHS: dict[str, ArchSpec] = {
    a.arch_id: a for a in [
        internlm2_20b.ARCH, yi_6b.ARCH, gemma_7b.ARCH,
        llama4_scout_17b_a16e.ARCH, arctic_480b.ARCH,
        gat_cora.ARCH,
        dien.ARCH, dcn_v2.ARCH, dlrm_mlperf.ARCH, deepfm.ARCH,
    ]
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """All 40 (arch, shape) pairs in a stable order."""
    out = []
    for arch_id, arch in ARCHS.items():
        for shape_id in arch.shapes:
            out.append((arch_id, shape_id))
    return out
