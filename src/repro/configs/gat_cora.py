"""gat-cora: 2 layers, 8 hidden x 8 heads, attention aggregator
[arXiv:1710.10903; paper]. Doubles as the GAT retrieval scorer for
SkewRoute (DESIGN §5): its edge-attention scores feed the router."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.gnn import GNNConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = GNNConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                   aggregator="attn", dtype=jnp.float32)

ARCH = ArchSpec(arch_id="gat-cora", family="gnn", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=5e-3,
                                          weight_decay=5e-4),
                source="arXiv:1710.10903; paper")
