"""dcn-v2: 13 dense + 26 sparse, embed 16, 3 full-rank cross layers,
deep MLP 1024-1024-512, parallel structure [arXiv:2008.13535; paper].
Criteo-Kaggle vocabulary."""

import jax.numpy as jnp
from repro.configs.base import ArchSpec
from repro.models.recsys import CRITEO_KAGGLE_VOCABS, RecsysConfig
from repro.training.optimizer import OptimizerConfig

CONFIG = RecsysConfig(
    name="dcn-v2", model="dcn_v2", n_dense=13, n_sparse=26, embed_dim=16,
    vocab_sizes=CRITEO_KAGGLE_VOCABS, deep_mlp=(1024, 1024, 512),
    n_cross_layers=3, interaction="cross")

ARCH = ArchSpec(arch_id="dcn-v2", family="recsys", config=CONFIG,
                optimizer=OptimizerConfig(name="adamw", lr=1e-3),
                source="arXiv:2008.13535; paper")
