"""Data pipeline substrate: sharded synthetic streams with prefetch."""
