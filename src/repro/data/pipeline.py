"""Deterministic sharded data pipelines with background prefetch.

Every stream is parameterized by (seed, shard_id, num_shards): each data-
parallel host pulls only its shard, reproducibly — restart-after-failure
resumes from (step, shard) without coordination, which is what makes the
checkpoint/restart path exact (tests/test_fault_tolerance.py round-trips
it). A daemon thread keeps ``prefetch`` batches ahead so host-side
generation overlaps device compute.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class ShardedStream:
    """Deterministic per-shard batch stream."""

    def __init__(self, make_batch: Callable[[np.random.Generator], dict],
                 seed: int, shard_id: int = 0, num_shards: int = 1,
                 start_step: int = 0):
        self.make_batch = make_batch
        self.seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.step = start_step

    def _rng_for(self, step: int) -> np.random.Generator:
        # independent stream per (seed, shard, step) — restartable anywhere
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, self.shard_id, step]))

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch = self.make_batch(self._rng_for(self.step))
        self.step += 1
        return batch


class Prefetcher:
    """Background-thread prefetch of a batch iterator."""

    _DONE = object()

    def __init__(self, it: Iterator, prefetch: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._err: Optional[BaseException] = None

        def run():
            try:
                for item in it:
                    self.q.put(item)
            except BaseException as e:  # noqa: BLE001 — surfaced on get
                self._err = e
            finally:
                self.q.put(self._DONE)

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._DONE:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


# ---------------------------------------------------------------------------
# Batch factories for the assigned families
# ---------------------------------------------------------------------------


def lm_batch_factory(batch: int, seq: int, vocab: int):
    """Synthetic next-token LM batches (Zipf-distributed token ids)."""
    def make(rng: np.random.Generator) -> dict:
        toks = np.minimum(rng.zipf(1.3, (batch, seq + 1)), vocab - 1)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return make


def recsys_batch_factory(cfg, batch: int, with_labels: bool = True):
    """Synthetic CTR batches matching `repro.models.recsys` inputs."""
    def make(rng: np.random.Generator) -> dict:
        out = {}
        if cfg.model == "dien":
            out.update(
                user_id=rng.integers(0, cfg.vocab_sizes[0], batch, dtype=np.int32),
                target_item=rng.integers(0, cfg.vocab_sizes[1], batch, dtype=np.int32),
                target_cat=rng.integers(0, cfg.vocab_sizes[2], batch, dtype=np.int32),
                hist_items=rng.integers(0, cfg.vocab_sizes[1],
                                        (batch, cfg.seq_len), dtype=np.int32),
                hist_cats=rng.integers(0, cfg.vocab_sizes[2],
                                       (batch, cfg.seq_len), dtype=np.int32),
                hist_mask=rng.random((batch, cfg.seq_len)) < 0.9,
            )
        else:
            out["sparse"] = np.stack(
                [rng.integers(0, v, batch) for v in cfg.vocab_sizes[:cfg.n_sparse]],
                axis=1).astype(np.int32)
            if cfg.n_dense:
                out["dense"] = rng.normal(0, 1, (batch, cfg.n_dense)).astype(np.float32)
        if with_labels:
            out["labels"] = (rng.random(batch) < 0.25).astype(np.float32)
        return out
    return make
