"""`repro.obs` — the unified observability plane.

One :class:`Observability` object bundles the three measurement
surfaces the serving stack threads through every component:

* :class:`~repro.obs.registry.MetricsRegistry` — counters, gauges and
  fixed-bucket histograms. Components cache instrument handles at
  construction; the disabled registry hands out shared no-op
  instruments so the fused fast path pays one attribute load + one
  no-op call per record — near-zero, gated in
  ``benchmarks/routing_fastpath_bench.py`` (obs-on within 5% of
  obs-off at B=1024/K=100).
* :class:`~repro.obs.trace.Tracer` — request-scoped spans + events.
  The serving stack records at BATCH granularity (one event carries
  the request-id range it covers) so tracing stays O(batches) on the
  hot path; :func:`~repro.obs.export.request_timelines` re-expands the
  batch events into one ordered per-request timeline (dispatch →
  policy → admission spill → tier execute → complete).
* exporters — :func:`~repro.obs.export.to_jsonl` event log and
  :func:`~repro.obs.export.prometheus_text` metrics snapshot, both
  byte-deterministic under a :class:`~repro.obs.clock.ManualClock`
  (golden-tested).

Profiling hooks for jitted device programs
(:func:`~repro.obs.profile.profile_program`: ``block_until_ready``
wall timing + HLO cost stats) live in :mod:`repro.obs.profile` and
feed ``benchmarks/roofline_report.py`` measured — not just modeled —
numbers.

Observability is RUNTIME configuration, like ``runners=``: it is
passed to ``repro.api.build(spec, obs=...)``, never serialized into
the ``RouteSpec``. Metric VALUES ride the snapshot envelope's state
half (``state["obs"]``) when enabled; trace event history is local
measurement and never serializes (documented in api/session.py).
"""

from repro.obs.clock import Clock, ManualClock, MonotonicClock  # noqa: F401
from repro.obs.keys import int_keyed, str_keyed  # noqa: F401
from repro.obs.registry import (  # noqa: F401
    DEFAULT_TIME_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullMetricsRegistry,
)
from repro.obs.trace import NullTracer, Span, Tracer  # noqa: F401
from repro.obs.plane import NULL_OBS, Observability  # noqa: F401
from repro.obs.export import (  # noqa: F401
    prometheus_text,
    request_timelines,
    span_tree,
    to_jsonl,
)
from repro.obs.profile import DeviceProgramProfile, profile_program  # noqa: F401

__all__ = [
    "Observability", "NULL_OBS",
    "MetricsRegistry", "NullMetricsRegistry",
    "Counter", "Gauge", "Histogram", "DEFAULT_TIME_BUCKETS",
    "Tracer", "NullTracer", "Span",
    "Clock", "ManualClock", "MonotonicClock",
    "to_jsonl", "prometheus_text", "request_timelines", "span_tree",
    "profile_program", "DeviceProgramProfile",
    "str_keyed", "int_keyed",
]
