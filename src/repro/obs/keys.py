"""JSON key round-trips, centralized.

JSON objects only have string keys, but the serving stack keys its
per-tier dicts by int tier id (``PipelineTelemetry.tier_counts``,
``DispatcherStats.tier_counts``, admission's per-tier pressure/spill
maps). Every ``state_dict``/``load_state_dict`` pair therefore needs
the same str-on-the-way-out / int-on-the-way-in coercion; before this
helper each site hand-rolled it (and ``PipelineTelemetry.snapshot``
re-coerced ad hoc). One pair of functions, shared with the
:mod:`repro.obs.export` exporters.
"""

from __future__ import annotations

from typing import Callable, Mapping

__all__ = ["str_keyed", "int_keyed"]


def str_keyed(d: Mapping) -> dict:
    """JSON-safe copy of ``d`` with every key coerced to ``str``
    (values passed through). Use on the way INTO a JSON payload."""
    return {str(k): v for k, v in d.items()}


def int_keyed(d: Mapping, value: Callable = int) -> dict:
    """Copy of ``d`` with keys coerced back to ``int`` and values
    passed through ``value`` (default ``int`` — counter dicts). Use on
    the way OUT of a JSON payload."""
    return {int(k): value(v) for k, v in d.items()}
