"""Clocks for the observability plane.

Everything in `repro.obs` that timestamps (span start/end, event
times, wall timings) reads time through a ``Clock`` so golden tests
can pin it: :class:`MonotonicClock` is ``time.perf_counter`` for real
measurement, :class:`ManualClock` advances a fixed step per read so
two identical runs produce byte-identical JSONL exports.
"""

from __future__ import annotations

import time

__all__ = ["Clock", "MonotonicClock", "ManualClock", "NullClock"]


class Clock:
    """Protocol: ``now() -> float`` seconds, monotone non-decreasing."""

    def now(self) -> float:  # pragma: no cover - interface
        raise NotImplementedError


class MonotonicClock(Clock):
    """Real wall time (``time.perf_counter``)."""

    def now(self) -> float:
        return time.perf_counter()


class ManualClock(Clock):
    """Deterministic clock for golden tests: starts at ``start`` and
    advances exactly ``step`` seconds every ``now()`` read (step=0
    freezes it). ``advance()`` jumps it explicitly."""

    def __init__(self, start: float = 0.0, step: float = 0.001):
        self.t = float(start)
        self.step = float(step)

    def now(self) -> float:
        t = self.t
        self.t += self.step
        return t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


class NullClock(Clock):
    """The disabled plane's clock: constant 0.0, no syscall."""

    def now(self) -> float:
        return 0.0
