"""Device-program profiling hooks: MEASURED wall time around jitted
programs, next to the HLO-derived cost model.

:func:`profile_program` compiles a function via
``jax.jit(fn).lower(*args).compile()``, pulls the static cost story
(FLOPs / bytes accessed / collectives via
`repro.launch.hlo_cost.analyze`) and then times the compiled program
with ``block_until_ready`` best-of-N — so a roofline row can report
what the program DID next to what the model says it SHOULD do
(``benchmarks/roofline_report.py --routing`` consumes this; ROADMAP's
"modeled-only numbers" gap).

The profile optionally records into a :class:`MetricsRegistry`
(gauge ``program_wall_seconds{program=,shape=}`` + achieved-throughput
gauges) so a serving process exposes its device-program timings
through the same Prometheus snapshot as everything else.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

__all__ = ["DeviceProgramProfile", "profile_program"]


@dataclasses.dataclass
class DeviceProgramProfile:
    """One compiled program's measured + modeled numbers."""

    name: str
    shape: str
    compile_s: float
    wall_s: float            # best-of-N blocked wall time per call
    iters: int
    flops: float             # HLO-derived (loop-aware re-derivation)
    bytes_accessed: float
    achieved_gflops: float   # flops / wall_s / 1e9
    achieved_gbps: float     # bytes_accessed / wall_s / 1e9

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def profile_program(fn, args: Sequence, *, name: str = "program",
                    shape: str = "", iters: int = 10, warmup: int = 2,
                    registry=None, timer=None,
                    compiled=None) -> DeviceProgramProfile:
    """Compile ``fn`` at ``args``'s shapes, then block-until-ready
    best-of-``iters`` time it. ``timer`` defaults to
    ``time.perf_counter`` (an obs ``clock.now`` works too — but note
    a ManualClock makes the *measured* numbers synthetic; goldens
    should pin the export format, not wall time). Pass ``compiled=``
    (a ``jax.jit(fn).lower(args).compile()`` result) to profile a
    program the caller already compiled — ``fn`` is ignored and
    ``compile_s`` reports 0."""
    import jax

    from repro.launch import hlo_cost

    timer = timer or time.perf_counter
    if compiled is None:
        t0 = timer()
        compiled = jax.jit(fn).lower(*args).compile()
        compile_s = timer() - t0
    else:
        compile_s = 0.0
    lc = hlo_cost.analyze(compiled.as_text())

    def once() -> float:
        t = timer()
        out = compiled(*args)
        jax.block_until_ready(out)
        return timer() - t

    for _ in range(max(0, warmup)):
        once()
    wall = min(once() for _ in range(max(1, iters)))
    wall = max(wall, 1e-12)

    prof = DeviceProgramProfile(
        name=name, shape=shape or "x".join(
            str(getattr(a, "shape", "?")) for a in args),
        compile_s=round(compile_s, 4), wall_s=wall, iters=iters,
        flops=float(lc["flops"]), bytes_accessed=float(lc["bytes_accessed"]),
        achieved_gflops=float(lc["flops"]) / wall / 1e9,
        achieved_gbps=float(lc["bytes_accessed"]) / wall / 1e9)
    if registry is not None:
        labels = {"program": prof.name, "shape": prof.shape}
        registry.gauge("program_wall_seconds", **labels).set(prof.wall_s)
        registry.gauge("program_compile_seconds", **labels).set(
            prof.compile_s)
        registry.gauge("program_achieved_gbps", **labels).set(
            prof.achieved_gbps)
        registry.gauge("program_achieved_gflops", **labels).set(
            prof.achieved_gflops)
    return prof
