"""Request-scoped tracing: a bounded event log of spans + events.

A :class:`Span` is one timed stage (``submit``, ``dispatch``,
``sync_round``); spans nest via a thread-local stack so a
``dispatch`` span opened inside a ``submit`` span records the parent
id — the export is a forest of span trees, one tree per root span
(= one ``trace`` id).

The serving stack records at BATCH granularity: a ``dispatch`` event
carries ``first_id`` + the per-row tier list rather than opening one
span per request — that keeps tracing O(batches) on the fused fast
path while :func:`repro.obs.export.request_timelines` still
reconstructs a complete per-request timeline from the id ranges.

Ids are sequential ints (no RNG, no wall-clock) so seeded runs are
byte-deterministic. The event buffer is bounded (``max_events``,
default 200k); overflow drops NEW events and counts them in
``n_dropped`` — a trace with holes is reported, never silently grown
without bound.
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from repro.obs.clock import Clock, MonotonicClock

__all__ = ["Span", "Tracer", "NullTracer", "NULL_SPAN"]

DEFAULT_MAX_EVENTS = 200_000


def _jsonable(v):
    """Cheap JSON coercion for event attributes."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return repr(v)


class Span:
    """One timed stage. Use as a context manager:

        with tracer.span("submit", batch=64) as sp:
            sp.event("spill", request_ids=[...])
    """

    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name")

    def __init__(self, tracer: "Tracer", trace_id: int, span_id: int,
                 parent_id: Optional[int], name: str):
        self.tracer = tracer
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name

    def event(self, name: str, **attrs) -> None:
        self.tracer._record("event", self.trace_id, self.span_id, name, attrs)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.tracer._end(self)


class _NullSpan:
    """Shared no-op span handed out by the disabled tracer."""

    __slots__ = ()
    trace_id = span_id = parent_id = 0
    name = ""

    def event(self, name: str, **attrs) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass


NULL_SPAN = _NullSpan()


class Tracer:

    enabled = True

    def __init__(self, clock: Optional[Clock] = None,
                 max_events: int = DEFAULT_MAX_EVENTS):
        self.clock = clock or MonotonicClock()
        self.max_events = int(max_events)
        self._events: list[dict] = []
        self.n_dropped = 0
        self._lock = threading.Lock()
        self._next_trace = 1
        self._next_span = 1
        self._tls = threading.local()

    # -- recording ------------------------------------------------------------

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _record(self, kind: str, trace_id: int, span_id: Optional[int],
                name: str, attrs: Optional[dict],
                parent_id: Optional[int] = None) -> None:
        rec = {"ts": round(self.clock.now(), 9), "kind": kind,
               "trace": trace_id, "span": span_id, "name": name}
        if kind == "span_start":
            rec["parent"] = parent_id
        if attrs:
            rec["attrs"] = {str(k): _jsonable(v) for k, v in attrs.items()}
        with self._lock:
            if len(self._events) >= self.max_events:
                self.n_dropped += 1
                return
            self._events.append(rec)

    def span(self, name: str, **attrs) -> Span:
        stack = self._stack()
        with self._lock:
            span_id = self._next_span
            self._next_span += 1
            if stack:
                parent = stack[-1]
                trace_id, parent_id = parent.trace_id, parent.span_id
            else:
                trace_id, parent_id = self._next_trace, None
                self._next_trace += 1
        sp = Span(self, trace_id, span_id, parent_id, name)
        self._record("span_start", trace_id, span_id, name, attrs,
                     parent_id=parent_id)
        stack.append(sp)
        return sp

    def _end(self, sp: Span) -> None:
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        elif sp in stack:  # exited out of order — drop through to it
            while stack and stack[-1] is not sp:
                stack.pop()
            if stack:
                stack.pop()
        self._record("span_end", sp.trace_id, sp.span_id, sp.name, None)

    def current_span(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    def event(self, name: str, **attrs) -> None:
        """Standalone event, attached to the current span if one is
        open (else trace/span 0 — a global event)."""
        cur = self.current_span()
        if cur is not None:
            self._record("event", cur.trace_id, cur.span_id, name, attrs)
        else:
            self._record("event", 0, None, name, attrs)

    # -- reading --------------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.n_dropped = 0


class NullTracer:
    """Disabled tracer: spans are the shared no-op span, events vanish."""

    enabled = False
    n_dropped = 0

    def span(self, name: str, **attrs) -> _NullSpan:
        return NULL_SPAN

    def event(self, name: str, **attrs) -> None:
        pass

    def current_span(self) -> None:
        return None

    def events(self) -> list:
        return []

    def __len__(self) -> int:
        return 0

    def clear(self) -> None:
        pass
