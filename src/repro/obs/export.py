"""Exporters: JSONL event log, Prometheus text snapshot, and the
per-request timeline walker.

Both exporters are byte-deterministic given deterministic inputs
(seeded clock, seeded workload): JSON is dumped with sorted keys and
fixed separators; Prometheus samples come out in the registry's
sorted collect() order.

The serving stack traces at BATCH granularity — each event carries
the request ids it covers (``first_id`` + row order, or an explicit
``request_ids`` list). :func:`request_timelines` re-expands those
batch events into one ordered stage list per request id; tests (and
humans) read a request's life as::

    dispatch(tier=2) -> policy(kind=cascade, tier=2) -> spill(2->1)
      -> execute(tier=1) -> complete(latency=0.41)
"""

from __future__ import annotations

import json
from typing import Iterable, Mapping

__all__ = ["to_jsonl", "prometheus_text", "request_timelines", "span_tree"]


# -- JSONL --------------------------------------------------------------------

def to_jsonl(events: Iterable[Mapping]) -> str:
    """One compact JSON object per line, keys sorted — byte-stable."""
    return "\n".join(
        json.dumps(e, sort_keys=True, separators=(",", ":"))
        for e in events)


# -- Prometheus text ----------------------------------------------------------

def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int):
        return str(v)
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _labels_str(labels: Mapping[str, str], extra=()) -> str:
    parts = [f'{k}="{v}"' for k, v in sorted(labels.items())]
    parts += [f'{k}="{v}"' for k, v in extra]
    return "{" + ",".join(parts) + "}" if parts else ""


def prometheus_text(registry) -> str:
    """Prometheus exposition text for every instrument in the
    registry, grouped by metric name, samples in sorted label order."""
    lines: list[str] = []
    last_name = None
    for name, labels, inst in registry.collect():
        if name != last_name:
            lines.append(f"# TYPE {name} {inst.kind}")
            last_name = name
        if inst.kind == "histogram":
            cum = 0
            for ub, c in zip(inst.buckets, inst.counts):
                cum += c
                lines.append(f"{name}_bucket"
                             f"{_labels_str(labels, [('le', _fmt(ub))])}"
                             f" {cum}")
            lines.append(f"{name}_bucket"
                         f"{_labels_str(labels, [('le', '+Inf')])} {inst.n}")
            lines.append(f"{name}_sum{_labels_str(labels)} {_fmt(inst.total)}")
            lines.append(f"{name}_count{_labels_str(labels)} {inst.n}")
        else:
            lines.append(f"{name}{_labels_str(labels)} {_fmt(inst.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


# -- timeline reconstruction --------------------------------------------------

def _ids_of(attrs: Mapping) -> list:
    """Request ids an event covers: explicit list, or first_id + row
    order of its per-row ``tiers`` array."""
    if "request_ids" in attrs:
        return list(attrs["request_ids"])
    if "first_id" in attrs and "tiers" in attrs:
        first = int(attrs["first_id"])
        return list(range(first, first + len(attrs["tiers"])))
    if "first_id" in attrs and "n" in attrs:
        first = int(attrs["first_id"])
        return list(range(first, first + int(attrs["n"])))
    return []


def request_timelines(events: Iterable[Mapping]) -> dict:
    """{request_id: [stage dicts, in event order]} from a JSONL-parsed
    (or live ``tracer.events()``) event stream.

    Stages carried through: ``dispatch`` (tier = the difficulty
    backend's threshold decision), ``policy`` (tier = the routing
    policy's final decision; ``tier_in`` when it differs), ``spill``
    (admission demotion, from/to), ``execute`` (the micro-batch run on
    a tier runner), ``complete`` (pool completion, when recorded).
    Every stage dict has ``stage``, ``ts``, ``trace``, ``span``.
    """
    timelines: dict[int, list[dict]] = {}

    def add(rid, stage, ev, **extra):
        entry = {"stage": stage, "ts": ev.get("ts"),
                 "trace": ev.get("trace"), "span": ev.get("span")}
        entry.update(extra)
        timelines.setdefault(int(rid), []).append(entry)

    for ev in events:
        if ev.get("kind") != "event":
            continue
        name = ev.get("name")
        attrs = ev.get("attrs", {})
        if name == "dispatch":
            tiers = attrs.get("tiers", [])
            for rid, t in zip(_ids_of(attrs), tiers):
                add(rid, "dispatch", ev, tier=int(t))
        elif name == "policy":
            tiers = attrs.get("tiers", [])
            tiers_in = attrs.get("tiers_in")
            for i, (rid, t) in enumerate(zip(_ids_of(attrs), tiers)):
                extra = {"tier": int(t), "kind": attrs.get("kind")}
                if tiers_in is not None and int(tiers_in[i]) != int(t):
                    extra["tier_in"] = int(tiers_in[i])
                add(rid, "policy", ev, **extra)
        elif name == "spill":
            frm, to = attrs.get("from", []), attrs.get("to", [])
            for i, rid in enumerate(_ids_of(attrs)):
                add(rid, "spill", ev,
                    tier=int(to[i]) if i < len(to) else None,
                    tier_in=int(frm[i]) if i < len(frm) else None)
        elif name == "execute":
            for rid in _ids_of(attrs):
                add(rid, "execute", ev, tier=int(attrs.get("tier", -1)))
        elif name == "complete":
            lat = attrs.get("latencies")
            for i, rid in enumerate(_ids_of(attrs)):
                extra = {"tier": int(attrs.get("tier", -1))}
                if lat is not None and i < len(lat):
                    extra["latency"] = float(lat[i])
                add(rid, "complete", ev, **extra)
    return timelines


def span_tree(events: Iterable[Mapping]) -> dict:
    """{span_id: node} with ``children`` links; roots have
    ``parent is None``. Raises on an end without a start."""
    nodes: dict[int, dict] = {}
    for ev in events:
        kind = ev.get("kind")
        if kind == "span_start":
            nodes[ev["span"]] = {
                "span": ev["span"], "trace": ev["trace"],
                "name": ev["name"], "parent": ev.get("parent"),
                "start": ev.get("ts"), "end": None,
                "n_events": 0, "children": [],
            }
        elif kind == "span_end":
            if ev["span"] not in nodes:
                raise ValueError(f"span_end for unknown span {ev['span']}")
            nodes[ev["span"]]["end"] = ev.get("ts")
        elif kind == "event" and ev.get("span") in nodes:
            nodes[ev["span"]]["n_events"] += 1
    for node in nodes.values():
        parent = node["parent"]
        if parent is not None:
            if parent not in nodes:
                raise ValueError(f"span {node['span']} has unknown parent "
                                 f"{parent}")
            nodes[parent]["children"].append(node["span"])
    return nodes
