"""MetricsRegistry: counters, gauges, fixed-bucket histograms.

Design constraints, in order:

1. **Hot-path cost.** The fused dispatch path records a handful of
   metrics per BATCH (not per request). Components look instruments up
   ONCE at construction (``self._m_requests = registry.counter(...)``)
   and the record call is a plain attribute bump — no dict lookup, no
   label hashing per record. The :class:`NullMetricsRegistry` hands
   every lookup the same shared no-op instrument, so the disabled
   plane costs one no-op method call per record site (gated within 5%
   of obs-off in ``routing_fastpath_bench``).
2. **Determinism.** ``collect()`` orders samples by (name, sorted
   labels); histogram buckets are fixed at creation. Two identical
   runs export byte-identical Prometheus text.
3. **Serialization.** ``state_dict()`` is pure JSON (label maps via
   :mod:`repro.obs.keys`); metric values ride the snapshot envelope's
   state half. Restoring is ``load_state_dict`` — instruments already
   handed out stay LIVE (the registry updates them in place rather
   than replacing them), so components keep their cached handles
   across a restore.
"""

from __future__ import annotations

import bisect
from typing import Iterator, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "NullInstrument", "NULL_INSTRUMENT",
    "MetricsRegistry", "NullMetricsRegistry", "DEFAULT_TIME_BUCKETS",
]

#: Default latency buckets (seconds) — spans micro-benchmark kernel
#: calls (~50us interpret) through engine-step walls (~seconds).
DEFAULT_TIME_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0, 3.0, 10.0,
)

LabelKey = Tuple[Tuple[str, str], ...]


class Counter:
    """Monotone counter (ints or dollars). ``value`` is directly
    assignable — restore/resync paths set it from serialized state."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (queue depth, $/query EWMA, pressure)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram. ``buckets`` are upper bounds (le);
    observations above the last bound land in the +Inf bucket."""

    __slots__ = ("buckets", "counts", "total", "n")
    kind = "histogram"

    def __init__(self, buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        self.buckets = tuple(float(b) for b in buckets)
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram buckets must be strictly "
                             f"increasing, got {buckets}")
        self.counts = [0] * (len(self.buckets) + 1)  # +Inf tail
        self.total = 0.0
        self.n = 0

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.total += v
        self.n += 1


class NullInstrument:
    """The disabled plane's instrument: every record is a no-op. One
    shared instance backs every lookup on a NullMetricsRegistry."""

    __slots__ = ()
    kind = "null"

    def inc(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass


NULL_INSTRUMENT = NullInstrument()


def _label_key(labels: Mapping[str, str]) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class MetricsRegistry:
    """Get-or-create instrument store keyed by (name, labels)."""

    enabled = True

    def __init__(self) -> None:
        self._metrics: dict[Tuple[str, LabelKey], object] = {}

    # -- instrument lookup (construction-time, not hot path) ------------------

    def _get(self, name: str, labels: Mapping[str, str], cls, *args):
        key = (str(name), _label_key(labels))
        inst = self._metrics.get(key)
        if inst is None:
            inst = cls(*args)
            self._metrics[key] = inst
        elif not isinstance(inst, cls):
            raise ValueError(
                f"metric {name!r}{dict(labels)} already registered as "
                f"{inst.kind}, requested {cls.kind}")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, labels, Counter)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, labels, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  **labels) -> Histogram:
        h = self._get(name, labels, Histogram, buckets)
        if h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"histogram {name!r}{dict(labels)} already "
                             f"registered with buckets {h.buckets}")
        return h

    # -- reading --------------------------------------------------------------

    def collect(self) -> Iterator[Tuple[str, dict, object]]:
        """(name, labels, instrument) sorted by (name, labels) — the
        deterministic export order."""
        for (name, lkey) in sorted(self._metrics):
            yield name, dict(lkey), self._metrics[(name, lkey)]

    def value(self, name: str, **labels):
        """Convenience read for tests/views; None when absent."""
        inst = self._metrics.get((str(name), _label_key(labels)))
        if inst is None:
            return None
        return inst.value if not isinstance(inst, Histogram) else inst.n

    # -- serialization (pure JSON) --------------------------------------------

    def state_dict(self) -> dict:
        samples = []
        for name, labels, inst in self.collect():
            rec = {"name": name, "labels": labels, "kind": inst.kind}
            if isinstance(inst, Histogram):
                rec.update(buckets=list(inst.buckets),
                           counts=list(inst.counts),
                           total=inst.total, n=inst.n)
            else:
                rec["value"] = inst.value
            samples.append(rec)
        return {"samples": samples}

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        """Restore values IN PLACE: instruments already handed out to
        components keep recording into the restored totals; metrics
        present here but absent from ``state`` reset to zero."""
        samples = (state or {}).get("samples", ())
        seen = set()
        for rec in samples:
            name, labels, kind = rec["name"], rec.get("labels", {}), rec["kind"]
            if kind == "counter":
                inst = self.counter(name, **labels)
                inst.value = rec["value"]
            elif kind == "gauge":
                inst = self.gauge(name, **labels)
                inst.value = rec["value"]
            elif kind == "histogram":
                inst = self.histogram(name, buckets=rec["buckets"], **labels)
                inst.counts = [int(c) for c in rec["counts"]]
                inst.total = float(rec["total"])
                inst.n = int(rec["n"])
            else:
                raise ValueError(f"unknown metric kind {kind!r} in state")
            seen.add((str(name), _label_key(labels)))
        for key, inst in self._metrics.items():
            if key in seen:
                continue
            if isinstance(inst, Histogram):
                inst.counts = [0] * (len(inst.buckets) + 1)
                inst.total, inst.n = 0.0, 0
            else:
                inst.value = 0 if isinstance(inst, Counter) else 0.0


class NullMetricsRegistry(MetricsRegistry):
    """Disabled plane: every lookup returns the shared no-op
    instrument; state is empty; loads are ignored."""

    enabled = False

    def counter(self, name: str, **labels) -> NullInstrument:
        return NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> NullInstrument:
        return NULL_INSTRUMENT

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS,
                  **labels) -> NullInstrument:
        return NULL_INSTRUMENT

    def collect(self):
        return iter(())

    def state_dict(self) -> dict:
        return {"samples": []}

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        pass
