"""The `Observability` facade: one object bundling clock + metrics +
tracer, threaded through the serving stack as RUNTIME configuration
(never serialized into a RouteSpec).

``NULL_OBS`` is the disabled plane every component defaults to: its
registry hands out shared no-op instruments, its tracer no-op spans,
its clock a constant — the fast path's per-batch overhead is a few
no-op calls (bench-gated within 5% at B=1024/K=100).
"""

from __future__ import annotations

import io
from typing import Mapping, Optional

from repro.obs.clock import Clock, MonotonicClock, NullClock
from repro.obs.export import prometheus_text, to_jsonl
from repro.obs.registry import MetricsRegistry, NullMetricsRegistry
from repro.obs.trace import DEFAULT_MAX_EVENTS, NullTracer, Tracer

__all__ = ["Observability", "NULL_OBS"]


class Observability:
    """clock + MetricsRegistry + Tracer, with exporter conveniences."""

    enabled = True

    def __init__(self, clock: Optional[Clock] = None,
                 max_events: int = DEFAULT_MAX_EVENTS):
        self.clock = clock or MonotonicClock()
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(self.clock, max_events=max_events)

    # -- exporters ------------------------------------------------------------

    def jsonl(self) -> str:
        """The trace event log as JSONL text (one event per line,
        byte-deterministic under a ManualClock)."""
        return to_jsonl(self.tracer.events())

    def export_jsonl(self, path) -> int:
        """Write the event log to ``path``; returns the line count."""
        events = self.tracer.events()
        text = to_jsonl(events)
        if isinstance(path, io.IOBase):
            path.write(text + ("\n" if text else ""))
        else:
            with open(path, "w") as fh:
                fh.write(text + ("\n" if text else ""))
        return len(events)

    def prometheus(self) -> str:
        """Prometheus text-format snapshot of the metrics registry."""
        return prometheus_text(self.metrics)

    # -- serialization (metrics only; see api/session.py) ---------------------

    def state_dict(self) -> dict:
        """Metric values only. Trace events are local measurement
        history and deliberately do NOT ride snapshots (a restored
        replica starts a fresh timeline; counters/histograms carry
        the cumulative story)."""
        return self.metrics.state_dict()

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        self.metrics.load_state_dict(state)

    def telemetry(self) -> dict:
        return {
            "enabled": self.enabled,
            "n_events": len(self.tracer),
            "n_dropped": self.tracer.n_dropped,
            "n_metrics": sum(1 for _ in self.metrics.collect()),
        }


class _NullObservability(Observability):
    """Disabled plane. Singleton (``NULL_OBS``); constructing more is
    harmless but pointless."""

    enabled = False

    def __init__(self):
        self.clock = NullClock()
        self.metrics = NullMetricsRegistry()
        self.tracer = NullTracer()

    def telemetry(self) -> dict:
        return {"enabled": False}


NULL_OBS = _NullObservability()
