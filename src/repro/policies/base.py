"""The `RoutingPolicy` protocol and registry: what to DO with skew metrics.

SkewRoute's published router is one rule — compare a skew-derived
difficulty score against ascending thresholds. Everything upstream of
that rule (scoring, top-k, the fused metric kernels, calibration
windows) is policy-agnostic machinery; this package lifts the rule
itself into a registered strategy so a :class:`~repro.api.RouteSpec` can
express cascade routing, per-query retrieval depth, or retrieval-mode
selection without new user-facing surface.

The contract, in dispatch order:

1. the difficulty backend produces the batch's threshold-tier ids,
   difficulty scores, and the raw metric matrix (unchanged — backends
   stay policy-agnostic and the fused device programs stay compiled
   once);
2. the dispatcher hands those arrays to ``policy.decide(...)``, which
   returns a :class:`PolicyDecision`: final tier ids, an optional
   per-request $ cost override (cascades pay every stage they ran;
   depth/mode policies price per-request token counts), an optional
   per-request retrieval depth, and telemetry;
3. counters, the $ ledger, admission's budget EWMA, and the micro-batch
   queues all consume the DECISION, so per-stage accounting flows
   end-to-end.

Calibration: a policy with data-dependent cutoffs implements
:meth:`RoutingPolicy.refit`, which receives a *quantile source* — a
callable mapping quantile levels to values over whatever sample set is
authoritative right now (the local streaming window on a drift swap, the
weighted fleet merge in a sync round). Every threshold hot-swap goes
through ``dispatcher.apply_config``; the policy refit rides the same
path, so replicas that merged identical windows land on identical policy
cutoffs — the fabric's replicas-agree-exactly property extends to
policies for free.

Serialization: specs are frozen dataclasses with a ``kind``
discriminator (JSON dict ``{"kind": ..., <fields>}``); mutable policy
state (live cutoffs, escalation counters) rides the snapshot envelope's
state half next to the calibrator window. A stateless policy serializes
its state as ``None``, which keeps pre-policy (PR 8) envelopes loading
unchanged under the default threshold policy.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Optional, Sequence

import numpy as np

from repro.core.cost import CostModel

__all__ = [
    "PolicyDecision",
    "PolicySpec",
    "QuantileSource",
    "RoutingPolicy",
    "available_policies",
    "build_policy",
    "policy_spec_from_dict",
    "register_policy",
]

#: Maps ascending quantile levels in [0, 1] -> sample values. The
#: streaming calibrator provides one over its window; the replica-sync
#: merge provides one over the weighted fleet union.
QuantileSource = Callable[[Sequence[float]], np.ndarray]


def bucketize(values: np.ndarray, cutoffs: Sequence[float]) -> np.ndarray:
    """Host-side twin of `core.router.route_from_difficulty`: bucket id =
    number of ascending cutoffs strictly below the value. The SAME
    compare (strict ``>``) as the device program, so a policy cutoff and
    a router threshold at the same value bucket identically."""
    v = np.asarray(values)
    cuts = np.asarray(tuple(cutoffs), dtype=v.dtype if v.dtype.kind == "f"
                      else np.float32)
    return np.sum(v[:, None] > cuts[None, :], axis=1).astype(np.int32)


def ascending(values: Sequence[float]) -> tuple[float, ...]:
    """Clamp a cutoff sequence ascending (quantile ties can collapse) —
    the same rule `StreamingCalibrator.fit_config` applies."""
    out = [float(v) for v in values]
    for i in range(1, len(out)):
        out[i] = max(out[i], out[i - 1])
    return tuple(out)


@dataclasses.dataclass
class PolicyDecision:
    """What a policy decided for one dispatched batch.

    ``tiers`` are the FINAL tier ids the batch executes on (and what
    every downstream counter records). ``request_cost`` — when not None —
    overrides the dispatcher's default price-by-final-tier accounting
    with per-request $ (a cascade pays every stage it attempted; a depth
    policy pays per-request prompt tokens). ``depths`` — when not None —
    is the per-request retrieval depth the retrieval output is truncated
    to. ``info`` is policy-specific batch telemetry.
    """

    tiers: np.ndarray
    request_cost: Optional[np.ndarray] = None
    depths: Optional[np.ndarray] = None
    info: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class PolicySpec:
    """Base of the frozen, JSON-round-trippable policy spec family.

    Subclasses set the class attribute ``kind`` (the registry key and
    JSON discriminator) and may override :meth:`validate`, which runs
    inside ``RouteSpec.__post_init__`` with the enclosing spec — the one
    place cross-field invariants (tier counts, top_k bounds) live.
    """

    kind = "?"  # class attribute, not a field — overridden per subclass

    def validate(self, route_spec) -> None:  # noqa: ARG002 (interface)
        """Check this policy against the enclosing RouteSpec."""

    def to_dict(self) -> dict:
        d: dict[str, Any] = {"kind": type(self).kind}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, tuple):
                v = list(v)
            d[f.name] = v
        return d


class RoutingPolicy:
    """Runtime half of a policy: spec + live cutoffs + counters.

    Built by :func:`build_policy` with the routing context a decision
    needs (tier count, cost-model pricing per tier). Subclasses override
    :meth:`decide`; stateful ones also ``refit``/``state_dict``/
    ``load_state_dict``.
    """

    #: True when the policy owns data-dependent cutoffs that should be
    #: re-fit from the quantile source on every threshold hot-swap.
    needs_refit = False

    def __init__(self, spec: PolicySpec, *, n_tiers: int,
                 tier_models: Sequence[str], cost_model: CostModel):
        if len(tier_models) != n_tiers:
            raise ValueError(f"{n_tiers} tiers but {len(tier_models)} "
                             f"tier models")
        self.spec = spec
        self.n_tiers = int(n_tiers)
        self.tier_models = tuple(str(m) for m in tier_models)
        self.cost_model = cost_model
        # $/request by final tier — 0.0 for models the pricing table
        # doesn't know, matching the dispatcher's default ledger
        self.tier_cost = np.asarray(
            [cost_model.request_cost(m) if m in cost_model.cost_per_mtok
             else 0.0 for m in self.tier_models])

    @property
    def kind(self) -> str:
        return type(self.spec).kind

    # -- the decision ---------------------------------------------------------

    def decide(self, tiers: np.ndarray, difficulty: np.ndarray,
               metrics: np.ndarray,
               self_scores: Optional[np.ndarray] = None) -> PolicyDecision:
        raise NotImplementedError

    # -- calibration (no-op for cutoff-free policies) -------------------------

    def refit(self, quantile_source: QuantileSource) -> None:
        """Re-fit live cutoffs from the given quantile source. Called on
        every threshold hot-swap (drift refit, admission tighten/relax,
        fleet merge) with the source that produced the new thresholds."""

    # -- serializable state ---------------------------------------------------

    def state_dict(self) -> Optional[dict]:
        """Mutable policy state for the snapshot envelope; ``None`` for a
        stateless policy (which keeps pre-policy envelopes bit-stable)."""
        return None

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        if state is not None:
            raise ValueError(
                f"policy {self.kind!r} is stateless but the snapshot "
                f"carries policy state {sorted(state)}; the snapshot was "
                f"minted under a different policy")

    def telemetry(self) -> dict:
        return {"kind": self.kind}


# -- registry -----------------------------------------------------------------

_REGISTRY: dict[str, tuple[type[PolicySpec], type[RoutingPolicy]]] = {}


def register_policy(spec_cls: type[PolicySpec],
                    policy_cls: type[RoutingPolicy]) -> None:
    """Register a (spec, runtime) pair under ``spec_cls.kind`` — the name
    a RouteSpec selects and the JSON discriminator."""
    kind = spec_cls.kind
    if not kind or kind == "?":
        raise ValueError(f"policy spec {spec_cls.__name__} must define a "
                         f"kind class attribute")
    _REGISTRY[kind] = (spec_cls, policy_cls)


def available_policies() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def policy_spec_from_dict(d: Mapping[str, Any]) -> PolicySpec:
    """JSON dict (``{"kind": ..., <fields>}``) -> the concrete spec,
    with the same strict unknown-field rejection as RouteSpec."""
    d = dict(d)
    kind = d.pop("kind", None)
    if kind not in _REGISTRY:
        raise ValueError(f"unknown routing policy {kind!r}; choose from "
                         f"{available_policies()}")
    spec_cls, _ = _REGISTRY[kind]
    known = {f.name for f in dataclasses.fields(spec_cls)}
    unknown = set(d) - known
    if unknown:
        raise ValueError(f"unknown {spec_cls.__name__} fields "
                         f"{sorted(unknown)}; known: {sorted(known)}")
    for key, value in d.items():
        if isinstance(value, list):
            d[key] = tuple(value)
    return spec_cls(**d)


def build_policy(spec: Optional[PolicySpec], *, n_tiers: int,
                 tier_models: Sequence[str],
                 cost_model: CostModel) -> RoutingPolicy:
    """Spec -> runtime policy. ``None`` builds the default threshold
    policy — exactly today's compare, bit-for-bit."""
    if spec is None:
        from repro.policies.threshold import ThresholdPolicySpec
        spec = ThresholdPolicySpec()
    _, policy_cls = _REGISTRY[type(spec).kind]
    return policy_cls(spec, n_tiers=n_tiers, tier_models=tier_models,
                      cost_model=cost_model)
