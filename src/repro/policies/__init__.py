"""Pluggable routing policies: what to DO with the skew metrics.

Importing this package registers the built-in strategies:

* ``threshold`` (default) — SkewRoute's published compare, bit-for-bit;
* ``cascade`` — cheap-tier-first with calibrated escalation cutoffs and
  per-stage cost accounting;
* ``adaptive_depth`` — per-query top-k retrieval depth as a second
  routed axis;
* ``mode_select`` — KG-RAG / no-RAG / long-context execution modes as
  tier-topology metadata.

See :mod:`repro.policies.base` for the protocol and registry.
"""

from repro.policies.adaptive_depth import (AdaptiveDepthPolicy,
                                           AdaptiveDepthPolicySpec)
from repro.policies.base import (PolicyDecision, PolicySpec, QuantileSource,
                                 RoutingPolicy, available_policies,
                                 build_policy, policy_spec_from_dict,
                                 register_policy)
from repro.policies.cascade import CascadePolicy, CascadePolicySpec
from repro.policies.mode_select import (KNOWN_MODES, ModeSelectPolicy,
                                        ModeSelectPolicySpec)
from repro.policies.threshold import ThresholdPolicy, ThresholdPolicySpec

__all__ = [
    "AdaptiveDepthPolicy",
    "AdaptiveDepthPolicySpec",
    "CascadePolicy",
    "CascadePolicySpec",
    "KNOWN_MODES",
    "ModeSelectPolicy",
    "ModeSelectPolicySpec",
    "PolicyDecision",
    "PolicySpec",
    "QuantileSource",
    "RoutingPolicy",
    "ThresholdPolicy",
    "ThresholdPolicySpec",
    "available_policies",
    "build_policy",
    "policy_spec_from_dict",
    "register_policy",
]
