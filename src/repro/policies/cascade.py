"""Cascade routing: answer cheap first, escalate on low confidence.

RouteLLM-style win-rate-vs-cutoff, adapted to SkewRoute's training-free
setting: every request is dispatched to tier 0 (the cheapest model)
first, and escalates stage-by-stage while a confidence signal says the
current tier will likely lose. Two signals feed escalation:

* the skew-derived **difficulty** score vs. a per-stage *escalation
  cutoff* — calibrated as window quantiles at ``escalation_quantiles``
  (the target fraction of traffic that STOPS at or below each stage),
  re-fit through the same ``apply_config`` hot-swap path as the router
  thresholds, so the fleet's merged windows converge cascade cutoffs
  exactly like thresholds;
* an optional **engine self-score** (higher = less confident) vs. the
  fixed ``self_score_cutoff`` — a post-hoc observation the pre-hoc skew
  signal can't see. When provided and above cutoff, the request
  escalates at least one stage regardless of skew.

Cost accounting is cumulative: a request that ends on tier *t* paid for
every stage ``0..t`` it attempted, so ``PolicyDecision.request_cost``
is ``cumsum(tier_cost)[final_tier]`` per request. That per-stage bill
is what flows into the dispatcher ledger and admission's budget EWMA —
a cascade only wins the cost-quality frontier when its escalation rate
is low enough to beat paying the big model's share directly, and the
accounting makes that visible instead of assuming it.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.policies.base import (PolicyDecision, PolicySpec, QuantileSource,
                                 RoutingPolicy, ascending, bucketize,
                                 register_policy)

__all__ = ["CascadePolicySpec", "CascadePolicy"]


@dataclasses.dataclass(frozen=True)
class CascadePolicySpec(PolicySpec):
    """Spec for cascade escalation over the RouteSpec's tier ladder.

    ``escalation_cutoffs`` — initial per-stage difficulty cutoffs
    (stage *i* escalates past tier *i* when difficulty > cutoff[i]);
    length must be ``n_tiers - 1``, ascending. ``escalation_quantiles``
    — when set, the live cutoffs are re-calibrated to these window
    quantiles on every threshold hot-swap (same cadence, same sample
    source as the router thresholds). ``self_score_cutoff`` — when set,
    a request whose engine self-score exceeds it escalates at least one
    stage even if skew called it easy.
    """

    kind = "cascade"

    escalation_cutoffs: tuple = ()
    escalation_quantiles: Optional[tuple] = None
    self_score_cutoff: Optional[float] = None

    def validate(self, route_spec) -> None:
        n_stages = len(route_spec.tier_names) - 1
        if len(self.escalation_cutoffs) != n_stages:
            raise ValueError(
                f"cascade over {len(route_spec.tier_names)} tiers needs "
                f"{n_stages} escalation cutoffs, got "
                f"{len(self.escalation_cutoffs)}")
        if list(self.escalation_cutoffs) != sorted(self.escalation_cutoffs):
            raise ValueError("escalation_cutoffs must be ascending, got "
                             f"{self.escalation_cutoffs}")
        if self.escalation_quantiles is not None:
            if len(self.escalation_quantiles) != n_stages:
                raise ValueError(
                    f"need {n_stages} escalation quantiles, got "
                    f"{len(self.escalation_quantiles)}")
            qs = [float(q) for q in self.escalation_quantiles]
            if qs != sorted(qs) or not all(0.0 < q < 1.0 for q in qs):
                raise ValueError("escalation_quantiles must be ascending "
                                 f"in (0, 1), got {self.escalation_quantiles}")


class CascadePolicy(RoutingPolicy):

    def __init__(self, spec, **kwargs):
        super().__init__(spec, **kwargs)
        # Live cutoffs start at the spec values and drift with refits;
        # they are the mutable state the snapshot envelope carries.
        self.cutoffs = tuple(float(c) for c in spec.escalation_cutoffs)
        # Cumulative $ by final tier: a request ending on tier t paid
        # for stages 0..t.
        self._cum_cost = np.cumsum(self.tier_cost)
        self.n_escalated = 0  # requests that went past tier 0
        self.n_self_score_bumps = 0  # escalations forced by self-score
        self.n_decided = 0

    @property
    def needs_refit(self) -> bool:
        return self.spec.escalation_quantiles is not None

    def decide(self, tiers: np.ndarray, difficulty: np.ndarray,
               metrics: np.ndarray,
               self_scores: Optional[np.ndarray] = None) -> PolicyDecision:
        diff = np.asarray(difficulty)
        # The backend's threshold tiers are ignored: a cascade always
        # starts at tier 0 and the final tier is how many stage cutoffs
        # the difficulty clears — same strict-> compare as the router.
        final = bucketize(diff, self.cutoffs)
        bumps = 0
        if self_scores is not None and self.spec.self_score_cutoff is not None:
            scores = np.asarray(self_scores, dtype=np.float64)
            unsure = scores > float(self.spec.self_score_cutoff)
            bumps = int(np.sum(unsure & (final == 0)))
            final = np.where(unsure, np.maximum(final, 1), final)
        final = final.astype(np.int32)
        cost = self._cum_cost[final]
        self.n_decided += int(final.shape[0])
        self.n_escalated += int(np.sum(final > 0))
        self.n_self_score_bumps += bumps
        return PolicyDecision(
            tiers=final, request_cost=cost,
            info={"escalated": int(np.sum(final > 0)),
                  "self_score_bumps": bumps})

    def refit(self, quantile_source: QuantileSource) -> None:
        if self.spec.escalation_quantiles is None:
            return
        fitted = np.asarray(
            quantile_source(tuple(self.spec.escalation_quantiles)))
        self.cutoffs = ascending(fitted.tolist())

    def state_dict(self) -> Optional[dict]:
        return {
            "kind": self.kind,
            "cutoffs": list(self.cutoffs),
            "n_decided": self.n_decided,
            "n_escalated": self.n_escalated,
            "n_self_score_bumps": self.n_self_score_bumps,
        }

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        if state is None:
            # Pre-policy snapshot half: reset to spec-initial cutoffs.
            self.cutoffs = tuple(float(c)
                                 for c in self.spec.escalation_cutoffs)
            return
        if state.get("kind") != self.kind:
            raise ValueError(
                f"snapshot policy state is {state.get('kind')!r}, this "
                f"session runs {self.kind!r}; refusing cross-policy restore")
        self.cutoffs = tuple(float(c) for c in state["cutoffs"])
        self.n_decided = int(state.get("n_decided", 0))
        self.n_escalated = int(state.get("n_escalated", 0))
        self.n_self_score_bumps = int(state.get("n_self_score_bumps", 0))

    def telemetry(self) -> dict:
        rate = (self.n_escalated / self.n_decided) if self.n_decided else 0.0
        return {
            "kind": self.kind,
            "cutoffs": list(self.cutoffs),
            "n_decided": self.n_decided,
            "n_escalated": self.n_escalated,
            "escalation_rate": rate,
            "self_score_bumps": self.n_self_score_bumps,
        }


register_policy(CascadePolicySpec, CascadePolicy)
