"""Adaptive retrieval depth: route top-k per query, not just the model.

Per "Cost-Aware Query Routing in RAG: Empirical Analysis of Retrieval
Depth Tradeoffs": a high-skew score distribution means the evidence the
query needs concentrates in the first few triples — shipping the full
top-k pads the prompt with noise and tokens. This policy keeps the
model-tier decision exactly as the thresholds made it and adds a SECOND
routed axis: each request gets a retrieval depth from
``depth_options`` (ascending), picked by bucketing difficulty against
``depth_cutoffs`` — easy (high-skew, low difficulty) queries take the
shallow option, flat distributions take the deep one.

The depth decision reuses the router's compare, so on the fused
retrieve-to-decision path it stays inside the one device program
(`core.router.select_depths` is jitted alongside the decision); the
host side then truncates the retrieved candidate set to the routed
depth before it reaches the engine. Per-request cost is re-priced at
the routed depth via ``CostModel.request_cost(model,
n_triples=depth)`` — the token-linear prompt pricing the cost model
already exposes — so the $ ledger and admission budget see the depth
savings, not the flat full-k price.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from repro.policies.base import (PolicyDecision, PolicySpec, QuantileSource,
                                 RoutingPolicy, ascending, register_policy)

__all__ = ["AdaptiveDepthPolicySpec", "AdaptiveDepthPolicy"]


@dataclasses.dataclass(frozen=True)
class AdaptiveDepthPolicySpec(PolicySpec):
    """``depth_options`` — ascending candidate depths (e.g. ``(25, 50,
    100)``); the deepest must not exceed ``RouteSpec.top_k`` since the
    device program only retrieves that many. ``depth_cutoffs`` — initial
    difficulty cutoffs between consecutive options (``len(options) -
    1``, ascending). ``depth_quantiles`` — when set, the live cutoffs
    re-fit to these window quantiles on every threshold hot-swap.
    """

    kind = "adaptive_depth"

    depth_options: tuple = ()
    depth_cutoffs: tuple = ()
    depth_quantiles: Optional[tuple] = None

    def validate(self, route_spec) -> None:
        opts = [int(k) for k in self.depth_options]
        if len(opts) < 2:
            raise ValueError("adaptive_depth needs >= 2 depth_options, got "
                             f"{self.depth_options}")
        if opts != sorted(opts) or min(opts) < 1:
            raise ValueError("depth_options must be ascending positive ints, "
                             f"got {self.depth_options}")
        if max(opts) > route_spec.top_k:
            raise ValueError(
                f"max depth option {max(opts)} exceeds RouteSpec.top_k="
                f"{route_spec.top_k}; the device program only retrieves "
                f"top_k candidates")
        if len(self.depth_cutoffs) != len(opts) - 1:
            raise ValueError(
                f"{len(opts)} depth options need {len(opts) - 1} cutoffs, "
                f"got {len(self.depth_cutoffs)}")
        if list(self.depth_cutoffs) != sorted(self.depth_cutoffs):
            raise ValueError("depth_cutoffs must be ascending, got "
                             f"{self.depth_cutoffs}")
        if self.depth_quantiles is not None:
            if len(self.depth_quantiles) != len(opts) - 1:
                raise ValueError(
                    f"need {len(opts) - 1} depth quantiles, got "
                    f"{len(self.depth_quantiles)}")
            qs = [float(q) for q in self.depth_quantiles]
            if qs != sorted(qs) or not all(0.0 < q < 1.0 for q in qs):
                raise ValueError("depth_quantiles must be ascending in "
                                 f"(0, 1), got {self.depth_quantiles}")


class AdaptiveDepthPolicy(RoutingPolicy):

    def __init__(self, spec, **kwargs):
        super().__init__(spec, **kwargs)
        self.depth_options = tuple(int(k) for k in spec.depth_options)
        self.cutoffs = tuple(float(c) for c in spec.depth_cutoffs)
        # $ matrix [tier, depth-option]: the tier's model re-priced at
        # each candidate depth's prompt length.
        self._depth_cost = np.asarray(
            [[self.cost_model.request_cost(m, n_triples=k)
              if m in self.cost_model.cost_per_mtok else 0.0
              for k in self.depth_options] for m in self.tier_models])
        self.n_decided = 0
        self.depth_counts = np.zeros(len(self.depth_options), dtype=np.int64)

    @property
    def needs_refit(self) -> bool:
        return self.spec.depth_quantiles is not None

    def decide(self, tiers: np.ndarray, difficulty: np.ndarray,
               metrics: np.ndarray,
               self_scores: Optional[np.ndarray] = None) -> PolicyDecision:
        tiers = np.asarray(tiers)
        # The depth pick itself runs as the jitted device primitive
        # (`core.router.select_depths` — cutoffs/options are runtime
        # arrays, so refits never recompile); it shares the router's
        # strict-> compare, and the host only sees the [B] int32 depths.
        from repro.core.router import select_depths
        depths = np.asarray(select_depths(
            np.asarray(difficulty, np.float32),
            np.asarray(self.cutoffs, np.float32),
            np.asarray(self.depth_options, np.int32)))
        # Option index back from the depth value (options are ascending),
        # for the cost matrix and the share counters.
        bucket = np.searchsorted(self.depth_options, depths).astype(np.int64)
        cost = self._depth_cost[tiers, bucket]
        self.n_decided += int(tiers.shape[0])
        self.depth_counts += np.bincount(bucket,
                                         minlength=len(self.depth_options))
        return PolicyDecision(
            tiers=tiers, request_cost=cost, depths=depths,
            info={"mean_depth": float(depths.mean()) if depths.size else 0.0})

    def refit(self, quantile_source: QuantileSource) -> None:
        if self.spec.depth_quantiles is None:
            return
        fitted = np.asarray(quantile_source(tuple(self.spec.depth_quantiles)))
        self.cutoffs = ascending(fitted.tolist())

    def state_dict(self) -> Optional[dict]:
        return {
            "kind": self.kind,
            "cutoffs": list(self.cutoffs),
            "n_decided": self.n_decided,
            "depth_counts": [int(c) for c in self.depth_counts],
        }

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        if state is None:
            self.cutoffs = tuple(float(c) for c in self.spec.depth_cutoffs)
            return
        if state.get("kind") != self.kind:
            raise ValueError(
                f"snapshot policy state is {state.get('kind')!r}, this "
                f"session runs {self.kind!r}; refusing cross-policy restore")
        self.cutoffs = tuple(float(c) for c in state["cutoffs"])
        self.n_decided = int(state.get("n_decided", 0))
        counts = state.get("depth_counts")
        if counts is not None:
            if len(counts) != len(self.depth_options):
                raise ValueError(
                    f"snapshot has {len(counts)} depth counters for "
                    f"{len(self.depth_options)} depth options")
            self.depth_counts = np.asarray(counts, dtype=np.int64)

    def telemetry(self) -> dict:
        total = int(self.depth_counts.sum())
        mean_depth = (float(np.dot(self.depth_counts, self.depth_options))
                      / total if total else 0.0)
        return {
            "kind": self.kind,
            "cutoffs": list(self.cutoffs),
            "depth_options": list(self.depth_options),
            "depth_shares": [(int(c) / total if total else 0.0)
                             for c in self.depth_counts],
            "mean_depth": mean_depth,
            "n_decided": self.n_decided,
        }


register_policy(AdaptiveDepthPolicySpec, AdaptiveDepthPolicy)
