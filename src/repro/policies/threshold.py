"""The default policy: SkewRoute's published threshold compare, verbatim.

The difficulty backend already bucketed every request against
``RouteSpec.thresholds`` inside the device program; this policy passes
those tier ids through untouched and leaves ``request_cost`` unset so
the dispatcher's pre-policy per-tier cost loop runs — a spec with no
``policy=`` field routes and accounts bit-for-bit as before the policy
layer existed.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.policies.base import (PolicyDecision, PolicySpec, RoutingPolicy,
                                 register_policy)

__all__ = ["ThresholdPolicySpec", "ThresholdPolicy"]


@dataclasses.dataclass(frozen=True)
class ThresholdPolicySpec(PolicySpec):
    """No knobs: the thresholds live on the RouteSpec itself."""

    kind = "threshold"


class ThresholdPolicy(RoutingPolicy):
    """Identity over the backend's threshold decision. Stateless —
    ``state_dict()`` is None, so snapshots minted under the default
    policy are indistinguishable from pre-policy envelopes."""

    def decide(self, tiers: np.ndarray, difficulty: np.ndarray,
               metrics: np.ndarray,
               self_scores: Optional[np.ndarray] = None) -> PolicyDecision:
        return PolicyDecision(tiers=np.asarray(tiers))


register_policy(ThresholdPolicySpec, ThresholdPolicy)
