"""Mode selection: route ACROSS retrieval modes, not just model sizes.

Per "Route Before Retrieve" / "RAGRouter" (PAPERS.md): the third routed
axis is HOW the query is answered, not just by which model. Skew already
tells us which regime a query is in — a sharply-skewed score
distribution means the top few triples carry the answer (a no-RAG or
shallow KG prompt may suffice); a flat distribution means retrieval
found nothing decisive and the engine should see long context instead
of a noisy subgraph.

This policy keeps the backend's threshold tiers (the RouteSpec ladder
is the mode ladder: ``tier_names[i]`` is the MODEL serving tier *i*,
``modes[i]`` is the RETRIEVAL MODE it runs under) and contributes the
per-mode economics and topology metadata:

* each mode re-prices its tier's model at the mode's true prompt
  length — ``no_rag`` pays for the bare question (62 tokens on CWQ),
  ``kg_rag`` pays the cost model's default retrieval prompt, and
  ``long_context`` pays ``long_context_tokens`` of stuffed document
  context — so the $ ledger and admission budget reflect mode choice;
* ``no_rag`` tiers route retrieval depth 0 (the scheduler still
  retrieves for SCORING — skew is the routing signal — but ships no
  triples in the prompt), so `PolicyDecision.depths` truncates the
  candidate set to nothing for those rows;
* :meth:`tier_topology` exposes ``{tier: mode}`` metadata the
  TierScheduler pools and loadgen summaries label themselves with.

Modes come from a closed vocabulary so topology consumers can rely on
the names; the same mode may back several tiers (e.g. a 3-tier ladder
``no_rag → kg_rag → long_context`` over two model sizes).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

from repro.core.cost import TOKENS_BARE_QUESTION
from repro.policies.base import (PolicyDecision, PolicySpec, RoutingPolicy,
                                 register_policy)

__all__ = ["ModeSelectPolicySpec", "ModeSelectPolicy", "KNOWN_MODES"]

#: The closed mode vocabulary the TierScheduler/loadgen understand.
KNOWN_MODES = ("no_rag", "kg_rag", "long_context")


@dataclasses.dataclass(frozen=True)
class ModeSelectPolicySpec(PolicySpec):
    """``modes`` — one mode per RouteSpec tier, drawn from
    :data:`KNOWN_MODES`. ``long_context_tokens`` — prompt length a
    ``long_context`` tier is billed at (stuffed-document context instead
    of retrieved triples)."""

    kind = "mode_select"

    modes: tuple = ()
    long_context_tokens: int = 8192

    def validate(self, route_spec) -> None:
        if len(self.modes) != len(route_spec.tier_names):
            raise ValueError(
                f"mode_select needs one mode per tier "
                f"({len(route_spec.tier_names)}), got {len(self.modes)}")
        unknown = [m for m in self.modes if m not in KNOWN_MODES]
        if unknown:
            raise ValueError(f"unknown retrieval modes {unknown}; known: "
                             f"{list(KNOWN_MODES)}")
        if self.long_context_tokens < 1:
            raise ValueError("long_context_tokens must be positive, got "
                             f"{self.long_context_tokens}")


class ModeSelectPolicy(RoutingPolicy):

    def __init__(self, spec, **kwargs):
        super().__init__(spec, **kwargs)
        self.modes = tuple(spec.modes)
        # $ per tier at the tier's MODE prompt length.
        self._mode_cost = np.asarray(
            [self._price(m, mode)
             for m, mode in zip(self.tier_models, self.modes)])
        # Depth per tier: no_rag ships zero triples; retrieval modes keep
        # the full routed candidate set (depths stay in int32 like the
        # device program's k).
        self._mode_depth = np.asarray(
            [0 if mode == "no_rag" else -1 for mode in self.modes],
            dtype=np.int32)
        self.n_decided = 0
        self.mode_counts = np.zeros(len(self.modes), dtype=np.int64)

    def _price(self, model: str, mode: str) -> float:
        if model not in self.cost_model.cost_per_mtok:
            return 0.0
        if mode == "no_rag":
            toks = TOKENS_BARE_QUESTION + self.cost_model.output_tokens
            return self.cost_model.cost_per_mtok[model] * toks / 1e6
        if mode == "long_context":
            toks = (self.spec.long_context_tokens
                    + self.cost_model.output_tokens)
            return self.cost_model.cost_per_mtok[model] * toks / 1e6
        return self.cost_model.request_cost(model)

    def decide(self, tiers: np.ndarray, difficulty: np.ndarray,
               metrics: np.ndarray,
               self_scores: Optional[np.ndarray] = None) -> PolicyDecision:
        tiers = np.asarray(tiers)
        cost = self._mode_cost[tiers]
        tier_depth = self._mode_depth[tiers]
        # -1 marks "full depth" — only surface a depths array when some
        # row actually truncates, so pure-retrieval topologies keep the
        # no-depth fast path.
        depths = None
        if np.any(tier_depth >= 0):
            depths = np.where(tier_depth >= 0, tier_depth,
                              np.iinfo(np.int32).max).astype(np.int32)
        self.n_decided += int(tiers.shape[0])
        self.mode_counts += np.bincount(tiers, minlength=len(self.modes))
        return PolicyDecision(
            tiers=tiers, request_cost=cost, depths=depths,
            info={"modes": list(self.modes)})

    def tier_topology(self) -> dict:
        """Tier -> execution-mode metadata for schedulers and loadgen."""
        return {
            "modes": list(self.modes),
            "tier_models": list(self.tier_models),
            "prompt_cost_per_request": [float(c) for c in self._mode_cost],
        }

    def state_dict(self) -> Optional[dict]:
        return {
            "kind": self.kind,
            "n_decided": self.n_decided,
            "mode_counts": [int(c) for c in self.mode_counts],
        }

    def load_state_dict(self, state: Optional[Mapping]) -> None:
        if state is None:
            self.n_decided = 0
            self.mode_counts = np.zeros(len(self.modes), dtype=np.int64)
            return
        if state.get("kind") != self.kind:
            raise ValueError(
                f"snapshot policy state is {state.get('kind')!r}, this "
                f"session runs {self.kind!r}; refusing cross-policy restore")
        self.n_decided = int(state.get("n_decided", 0))
        counts = state.get("mode_counts")
        if counts is not None:
            if len(counts) != len(self.modes):
                raise ValueError(
                    f"snapshot has {len(counts)} mode counters for "
                    f"{len(self.modes)} tier modes")
            self.mode_counts = np.asarray(counts, dtype=np.int64)

    def telemetry(self) -> dict:
        total = int(self.mode_counts.sum())
        return {
            "kind": self.kind,
            "modes": list(self.modes),
            "mode_shares": [(int(c) / total if total else 0.0)
                            for c in self.mode_counts],
            "n_decided": self.n_decided,
        }


register_policy(ModeSelectPolicySpec, ModeSelectPolicy)
