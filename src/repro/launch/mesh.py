"""Production mesh construction (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the "pod" axis carries pure data parallelism whose
all-reduce crosses the inter-pod DCN (gradient compression hooks live in
`repro.distributed.compression`).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before its first import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1-device mesh for CPU smoke tests of sharded code paths."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
