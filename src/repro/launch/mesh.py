"""Production mesh construction (TPU v5e pods).

Single pod: (data=16, model=16) = 256 chips. Multi-pod: (pod=2, data=16,
model=16) = 512 chips; the "pod" axis carries pure data parallelism whose
all-reduce crosses the inter-pod DCN (gradient compression hooks live in
`repro.distributed.compression`).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before its first import).
"""

from __future__ import annotations

import jax


def make_auto_mesh(shape, axes) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types across jax versions.

    Newer jax exposes ``jax.sharding.AxisType`` and ``make_mesh`` takes
    ``axis_types``; older versions (<= 0.4.x) have neither — but Auto is
    their only behavior, so plain ``make_mesh`` is equivalent.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_auto_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate 1-device mesh for CPU smoke tests of sharded code paths."""
    return make_auto_mesh((1, 1), ("data", "model"))


# v5e hardware constants for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
