"""Production serving driver: SkewRoute-fronted multi-tier LM fleet.

  PYTHONPATH=src python -m repro.launch.serve --requests 40 [--budget 0.4]

Runs the paper's deployment shape end to end on small-config tiers:
retrieval scoring -> declarative `repro.api.RouteSpec` -> one
`SkewRouteSession` (fused skew metrics, calibrated threshold routing,
drift-aware streaming recalibration, per-tier micro-batch queues) ->
engines generating real tokens, with cost/latency telemetry. The policy
is pure data: the driver prints the spec JSON a replica would need to
run the identical router.
On TPU the tier configs switch to the assigned archs (yi-6b small /
gemma-7b medium / internlm2-20b large) on the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--budget", type=float, default=0.4,
                    help="target large-tier call ratio")
    ap.add_argument("--metric", default="gini",
                    choices=["area", "cumulative", "entropy", "gini"])
    args = ap.parse_args()

    from repro.api import CalibrationSpec, RouteSpec, build
    from repro.core import calibrate_threshold
    from repro.models.layers import LMConfig
    from repro.retrieval import scorer as sc
    from repro.retrieval import synthetic
    from repro.serving.engine import EngineBank, make_engine

    print("== retrieval stack ==")
    data = synthetic.make_dataset("cwq", n_queries=args.requests + 100,
                                  n_entities=4000)
    cfg = sc.ScorerConfig(lr=2e-3)
    params = sc.train_scorer(data, cfg, n_steps=150)

    calib, calib_nv = [], []
    for q in data.queries[: 100]:
        _, probs = sc.retrieve(params, data.kg, data.entity_emb,
                               data.relation_emb, q, cfg)
        calib.append(np.pad(probs[:100], (0, max(0, 100 - len(probs)))))
        calib_nv.append(min(len(probs), 100))
    calib_nv = np.asarray(calib_nv, np.int32)
    # ragged retrieval: calibrate on the same masked metrics dispatch uses
    calib_mask = np.arange(100)[None, :] < calib_nv[:, None]
    theta = calibrate_threshold(jnp.asarray(np.stack(calib)), args.budget,
                                args.metric, mask=jnp.asarray(calib_mask))

    # the WHOLE policy, declaratively — ship spec.to_json() to replicas
    spec = RouteSpec(
        metric=args.metric, thresholds=(theta,),
        tier_names=("qwen7b", "qwen72b"),
        backend="auto", micro_batch=8,
        calibration=CalibrationSpec(
            policy="streaming",
            target_shares=(1.0 - args.budget, args.budget),
            window=1024, min_samples=64))
    print(f"{args.metric} threshold {theta:.4f} for {args.budget:.0%} budget")
    print(f"policy: {spec.to_json()}")

    print("== tier engines ==")
    bank = EngineBank({
        0: make_engine(LMConfig(name="small-tier", n_layers=2, d_model=64,
                                n_heads=4, n_kv_heads=2, head_dim=16,
                                d_ff=128, vocab=512, dtype=jnp.float32)),
        1: make_engine(LMConfig(name="large-tier", n_layers=4, d_model=128,
                                n_heads=8, n_kv_heads=4, head_dim=16,
                                d_ff=256, vocab=512, dtype=jnp.float32)),
    }, max_new=8)
    session = build(spec, runners=bank)

    t0 = time.monotonic()
    batch_scores, batch_nv, batch_prompts = [], [], []
    for q in data.queries[100: 100 + args.requests]:
        _, probs = sc.retrieve(params, data.kg, data.entity_emb,
                               data.relation_emb, q, cfg)
        batch_scores.append(np.pad(probs[:100], (0, max(0, 100 - len(probs)))))
        batch_nv.append(min(len(probs), 100))  # ragged: pad is NOT data
        batch_prompts.append(
            np.abs(np.frombuffer(q.query_emb.tobytes(), np.uint8)[:16])
            .astype(np.int32) % 512)
        if len(batch_scores) == 16:  # request-batch granularity of dispatch
            session.submit(np.stack(batch_scores), batch_prompts,
                           n_valid=np.asarray(batch_nv, np.int32))
            batch_scores, batch_nv, batch_prompts = [], [], []
    if batch_scores:
        session.submit(np.stack(batch_scores), batch_prompts,
                       n_valid=np.asarray(batch_nv, np.int32))
    session.flush()
    wall = time.monotonic() - t0

    generated = sum(b.result.generated_tokens for b in session.executed)
    s = session.stats
    from repro.core.cost import CostModel
    cm = CostModel()
    all_large = cm.request_cost("qwen72b") * s.n_requests
    n_micro = session.telemetry()["pipeline"]["n_microbatches"]
    print(f"\nserved {s.n_requests} requests / {generated} tokens in "
          f"{wall:.1f}s over {n_micro} micro-batches; "
          f"tier mix {s.tier_counts} (large ratio {s.large_call_ratio:.2f}); "
          f"{s.n_recalibrations} drift recalibrations")
    print(f"est. cost ${s.total_cost:.4f} vs all-large ${all_large:.4f} "
          f"({100 * (1 - s.total_cost / all_large):.0f}% saved)")
    # hand-off artifact: this session's live state, as a policy/state
    # envelope (the state half alone is what replica sync ships)
    snap = session.snapshot()
    state = snap["state"]
    cal_state = state["calibrator"] or {"window": {"buffer": []}}
    print(f"snapshot envelope v{snap['envelope_version']}: "
          f"thresholds={state['thresholds']}, "
          f"{len(cal_state['window']['buffer'])} window samples — "
          f"restorable via SkewRouteSession.from_snapshot")


if __name__ == "__main__":
    main()
