"""Roofline terms from a dry-run record (TPU v5e constants).

    compute_s    = per-device HLO FLOPs / 197 TF/s bf16
    memory_s     = per-device HLO bytes accessed / 819 GB/s HBM
    collective_s = per-device collective operand bytes / 50 GB/s ICI

(`cost_analysis()` and the HLO parse are both post-SPMD per-device
quantities, so no division by chip count is needed here; multiplying both
sides of the assignment's formulas by `chips` gives the same ratios.)

Extras recorded per cell: MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference)
with N = active params, and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs ×
chips) which exposes remat/dispatch/padding waste.
"""

from __future__ import annotations

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def roofline_terms(rec: dict) -> dict:
    cost = rec.get("cost", {})
    coll = rec.get("collectives", {})
    n_dev = rec.get("n_devices", 1)
    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes_accessed", 0.0)
    coll_dev = float(coll.get("total_bytes", 0))

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]

    out = dict(terms)
    out["dominant"] = dominant.replace("_s", "")
    out["step_lower_bound_s"] = bound_s
    model_flops = rec.get("meta", {}).get("model_flops", 0.0)
    hlo_flops_total = flops_dev * n_dev
    if hlo_flops_total > 0:
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / hlo_flops_total
    if bound_s > 0:
        # fraction of peak achievable if nothing overlaps = compute/bound
        out["roofline_fraction"] = compute_s / bound_s
        # MFU upper bound: useful model FLOPs over peak for the bound time
        if model_flops > 0:
            out["mfu_upper_bound"] = (model_flops / n_dev / bound_s
                                      / PEAK_FLOPS_BF16)
    return out
