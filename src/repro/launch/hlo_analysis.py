"""Parse collective traffic out of post-SPMD HLO text.

`compiled.cost_analysis()` has no collective-bytes entry, so the roofline's
third term comes from scanning the optimized HLO for all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops and
summing their operand sizes (per-device shard bytes, matching the
per-device FLOPs/bytes from cost_analysis).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. ``f32[16,128]{1,0}`` or ``bf16[4096]`` (layout braces optional)
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# ``%name = <shape or tuple> <op>(`` — op token just before the paren
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+([a-z\-]+)(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _operand_bytes(line: str) -> int:
    """Sum shape sizes appearing in the operand list of the op call."""
    lparen = line.index("(")
    operands = line[lparen:]
    return sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands))


def collective_stats(hlo_text: str) -> dict:
    """Per-kind collective op counts + operand bytes (per device)."""
    counts: dict[str, int] = defaultdict(int)
    bytes_: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if "(" not in line or "=" not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        # normalize -start/-done fusions; count traffic once (at -start or
        # the plain op; -done carries the same operands, skip it)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in _COLLECTIVES:
            continue
        counts[base] += 1
        bytes_[base] += _operand_bytes(line)
    total = sum(bytes_.values())
    return {
        "counts": dict(counts),
        "bytes": dict(bytes_),
        "total_bytes": total,
        "n_ops": sum(counts.values()),
    }


def hbm_traffic_upper_bound(hlo_text: str) -> int:
    """Sum of output-buffer sizes of all non-fusion root ops — a crude
    upper bound on HBM traffic used for sanity checks only (cost_analysis
    'bytes accessed' is the number the roofline uses)."""
    total = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if not stripped.startswith("%") or "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1].lstrip()
        m = _SHAPE_RE.match(rhs)
        if m:
            total += _shape_bytes(m.group(1), m.group(2))
    return total
