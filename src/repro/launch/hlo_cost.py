"""Loop-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
scan-over-layers transformer reports ~1/L of its real FLOPs, and anything
inside the flash-attention KV scan or the CE chunk scan is similarly
undercounted. This module re-derives the roofline inputs directly from the
HLO with while-loop trip multipliers:

  flops            2·M·N·K per ``dot`` (K from the lhs operand's shape +
                   lhs_contracting_dims; operand shapes resolved through a
                   per-computation symbol table since CPU HLO prints bare
                   ``%var`` references)
  bytes            operand + output bytes of every top-level op at fusion
                   boundaries (an HBM-traffic estimate: fusion internals
                   stay in registers/VMEM)
  collective bytes operand bytes of all-gather/all-reduce/reduce-scatter/
                   all-to-all/collective-permute

Trip counts come from the loop condition: scans compare the induction
variable against a constant; we take the largest s32/u32 constant in the
condition computation. Multipliers compose through nested loops.

Validated in tests/test_hlo_cost.py against analytically-known programs
(matmul, scan-of-matmuls, nested scans) and against unrolled probe
lowerings of the real models.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "s2": 1, "u2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1, "f4e2m1fn": 1,
    "f8e8m0fnu": 1, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|\S+))\s+([\w\-]+)\(")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))")
_VAR_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"\b[su](?:32|64)\[\]\s+constant\((\d+)\)")
_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "add-dependency", "domain",
    "opt-barrier", "call", "while", "conditional", "iota",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

#: callee references whose bodies are measured at the call boundary
_BOUNDARY_CALL_KINDS = {
    "fusion", "reduce", "sort", "scatter", "map", "reduce-window",
    "select-and-scatter", "all-reduce", "reduce-scatter", "custom-call",
    "select-and-scatter-done", "all-reduce-start",
}


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _sig_bytes(sig: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 0)
               for dt, dims in _SHAPE_RE.findall(sig))


def _sig_shapes(sig: str) -> list[tuple[str, list[int]]]:
    return [(dt, [int(d) for d in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(sig)]


@dataclass
class _Op:
    var: str
    kind: str
    out_sig: str
    operand_vars: list
    line: str
    is_root: bool = False


@dataclass
class _Comp:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)       # var -> out_sig
    params: list = field(default_factory=list)        # positional param names
    while_bodies: list = field(default_factory=list)  # (body, cond)
    calls: list = field(default_factory=list)         # (callee, kind)


_ATTR_CUT_RE = re.compile(
    r",\s*(?:metadata=|backend_config=|sharding=|frontend_attributes=)")


def _split_call(line: str, kind: str) -> tuple[str, str]:
    """Return (operand_region, attr_region) of the op call."""
    start = line.find(kind + "(")
    lparen = start + len(kind)
    depth = 0
    for i in range(lparen, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                return line[lparen + 1:i], line[i + 1:]
    return line[lparen + 1:], ""


def _parse(hlo: str) -> tuple[dict[str, _Comp], str]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    entry = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in hlo.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if not line or line.startswith(("HloModule", "//", "}")):
            if line.startswith("}"):
                cur = None
            continue
        # computation header, e.g. `%region_0.1 (arg: f32[2]) -> f32[2] {`
        if line.endswith("{") and "->" in line and "=" not in line.split("->")[0]:
            hdr = line.strip()
            is_entry = hdr.startswith("ENTRY")
            name_part = hdr[len("ENTRY"):].strip() if is_entry else hdr
            name = name_part.split("(")[0].strip().lstrip("%").strip()
            cur = _Comp(name)
            comps[name] = cur
            if is_entry:
                entry = name
            # header params populate the symbol table
            paren = name_part[name_part.find("("):name_part.rfind("->")]
            for pname, psig in _PARAM_RE.findall(paren):
                cur.symbols[pname] = psig
                cur.params.append(pname)
            continue
        if cur is None or "=" not in line:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        var, out_sig, kind = m.group(1), m.group(2), m.group(3)
        cur.symbols[var] = out_sig
        operands, attrs = _split_call(line, kind)
        operand_vars = _VAR_RE.findall(operands)
        cur.ops.append(_Op(var, kind, out_sig, operand_vars, line,
                           is_root=line.lstrip().startswith("ROOT")))
        if kind == "while":
            mb = re.search(r"body=%?([\w.\-]+)", attrs)
            mc = re.search(r"condition=%?([\w.\-]+)", attrs)
            cur.while_bodies.append(
                (mb.group(1) if mb else None, mc.group(1) if mc else None))
        else:
            for key in ("calls", "to_apply", "true_computation",
                        "false_computation"):
                mm = re.search(key + r"=%?([\w.\-]+)", attrs)
                if mm:
                    cur.calls.append((mm.group(1), kind))
            mm = re.search(r"branch_computations=\{([^}]*)\}", attrs)
            if mm:
                for c in mm.group(1).split(","):
                    cur.calls.append((c.strip().lstrip("%"), kind))
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO")
    return comps, entry


def _trip_count(comps: dict[str, _Comp], cond_name: str | None) -> int:
    if cond_name is None or cond_name not in comps:
        return 1
    best = 1
    for op in comps[cond_name].ops:
        for c in _CONST_RE.findall(op.line):
            best = max(best, int(c))
    return best


def _dot_flops(comp: _Comp, op: _Op) -> float:
    m = _DOT_CONTRACT_RE.search(op.line)
    if not m or not op.operand_vars:
        return 0.0
    lhs_sig = comp.symbols.get(op.operand_vars[0], "")
    lhs_shapes = _sig_shapes(lhs_sig)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(lhs_dims):
            k *= lhs_dims[idx]
    out_elems = sum(_shape_elems(dims) for _, dims in
                    _SHAPE_RE.findall(op.out_sig))
    return 2.0 * out_elems * k


def _fusion_bytes(comps: dict[str, _Comp], comp: _Comp, op: _Op) -> tuple[int, int]:
    """(operand_bytes, out_bytes) for a fusion call, slice-aware.

    XLA's convention (and our naive one) charges a fusion's FULL operand
    arrays, but a fusion whose body only dynamic-slices a big operand (the
    scan pattern: slice layer i of a stacked [L, ...] carry) physically
    reads just the slice. Decode_32k measured 67x inflated HBM traffic
    under the naive rule. For each fusion parameter used exclusively by
    dynamic-slice/gather ops we charge the slices' out-bytes; a root
    dynamic-update-slice into a parameter charges 2x the update size.
    """
    callee_name = next((c for c, k in comp.calls
                        if k == "fusion" and c in comps), None)
    # fall back to naive accounting when the body isn't resolvable
    m = re.search(r"calls=%?([\w.\-]+)", op.line)
    if m:
        callee_name = m.group(1)
    callee = comps.get(callee_name)
    out_bytes = _sig_bytes(op.out_sig)
    if callee is None:
        return (sum(_sig_bytes(comp.symbols.get(v, ""))
                    for v in op.operand_vars), out_bytes)
    params = callee.params[: len(op.operand_vars)]
    # Forward-propagate each param through the fusion graph: fusions are
    # lazy, so a param consumed only via (elementwise ops ->)
    # dynamic-slice physically reads just the slice. Any consumption by a
    # non-elementwise, non-slicing op counts as a full read.
    passthrough = {
        "convert", "copy", "bitcast", "transpose", "reshape", "negate",
        "add", "subtract", "multiply", "divide", "maximum", "minimum",
        "select", "compare", "and", "or", "not", "exponential", "tanh",
        "rsqrt", "sqrt", "abs", "clamp", "sign", "floor", "power",
    }
    consumers: dict[str, list] = defaultdict(list)
    for cop in callee.ops:
        for j, v in enumerate(cop.operand_vars):
            consumers[v].append((cop, j))
    root_op = next((o for o in callee.ops if o.is_root),
                   callee.ops[-1] if callee.ops else None)
    # follow elementwise chains backward from the root to find a DUS root
    # (fusions like convert(dynamic-update-slice(...)) are still in-place)
    _seen = set()
    while (root_op is not None and root_op.kind in
           ("convert", "copy", "bitcast") and root_op.operand_vars
           and root_op.var not in _seen):
        _seen.add(root_op.var)
        prev = next((o for o in callee.ops
                     if o.var == root_op.operand_vars[0]), None)
        if prev is None:
            break
        root_op = prev

    def accessed_bytes(pname: str) -> int | None:
        """Slice-bounded read bytes for a param, or None if fully read."""
        total = 0
        frontier = [pname]
        seen = {pname}
        while frontier:
            v = frontier.pop()
            for cop, j in consumers.get(v, ()):
                if cop.kind in ("dynamic-slice", "gather") and j == 0:
                    total += _sig_bytes(cop.out_sig)
                elif cop.kind == "dynamic-update-slice" and j == 0:
                    upd = (cop.operand_vars[1]
                           if len(cop.operand_vars) > 1 else None)
                    total += _sig_bytes(callee.symbols.get(upd, ""))
                elif cop.kind in passthrough:
                    if cop.var not in seen:
                        seen.add(cop.var)
                        frontier.append(cop.var)
                elif cop.kind == "parameter":
                    continue
                else:
                    return None  # full use
        return total if total else None

    operand_bytes = 0
    for pos, v in enumerate(op.operand_vars):
        pname = params[pos] if pos < len(params) else None
        sig = comp.symbols.get(v, "")
        sliced = accessed_bytes(pname) if pname else None
        full = _sig_bytes(sig)
        operand_bytes += min(sliced, full) if sliced is not None else full
    if (root_op is not None and root_op.kind == "dynamic-update-slice"
            and root_op.operand_vars):
        # in-place DUS root: the fusion writes only the update region
        upd = (root_op.operand_vars[1]
               if len(root_op.operand_vars) > 1 else None)
        upd_bytes = _sig_bytes(callee.symbols.get(upd, ""))
        if upd_bytes:
            out_bytes = upd_bytes
    return operand_bytes, out_bytes


def analyze(hlo: str) -> dict:
    """Loop-aware flops / bytes / collective traffic (per device)."""
    comps, entry = _parse(hlo)

    # Multipliers through the while-loop call graph.
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order, seen = [entry], {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for body, cond in comp.while_bodies:
            trips = _trip_count(comps, cond)
            for sub, mul in ((body, m * trips), (cond, m * (trips + 1))):
                if sub:
                    mult[sub] += mul
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
        for callee, kind in comp.calls:
            if kind in _BOUNDARY_CALL_KINDS:
                continue
            mult[callee] += m
            if callee not in seen:
                seen.add(callee)
                order.append(callee)

    fusion_targets = {callee for comp in comps.values()
                      for callee, kind in comp.calls
                      if kind in _BOUNDARY_CALL_KINDS}

    flops = 0.0
    bytes_ = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)
    for name, comp in comps.items():
        if name in fusion_targets:
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        for op in comp.ops:
            if op.kind in _SKIP_OPS or op.kind.endswith("-done"):
                continue
            base = (op.kind[:-6] if op.kind.endswith("-start") else op.kind)
            out_bytes = _sig_bytes(op.out_sig)
            if base in ("dynamic-slice", "slice", "gather"):
                # reads only the sliced/gathered region, not the operand
                operand_bytes = out_bytes
            elif base == "dynamic-update-slice":
                # in-place DUS: read+write of the update region only
                upd = op.operand_vars[1] if len(op.operand_vars) > 1 else None
                operand_bytes = _sig_bytes(comp.symbols.get(upd, ""))
                out_bytes = operand_bytes
            elif base == "scatter":
                upd = op.operand_vars[2] if len(op.operand_vars) > 2 else None
                operand_bytes = 2 * _sig_bytes(comp.symbols.get(upd, ""))
                out_bytes = operand_bytes
            elif base == "fusion":
                operand_bytes, out_bytes = _fusion_bytes(comps, comp, op)
            else:
                operand_bytes = sum(_sig_bytes(comp.symbols.get(v, ""))
                                    for v in op.operand_vars)
            if base in _COLLECTIVES:
                coll_bytes[base] += m * operand_bytes
                coll_counts[base] += m
            bytes_ += m * (out_bytes + operand_bytes)
            if op.kind == "dot":
                flops += m * _dot_flops(comp, op)
    return {
        "flops": flops,
        "bytes_accessed": bytes_,
        "collective_bytes": dict(coll_bytes),
        "collective_counts": {k: int(v) for k, v in coll_counts.items()},
        "collective_total_bytes": sum(coll_bytes.values()),
        "collective_n_ops": int(sum(coll_counts.values())),
    }
