"""Attribution tool for hillclimbing: rank loop-aware byte / collective
contributions per op, grouped by the jaxpr op_name metadata, so the
dominant roofline term can be traced to a specific model component.

  PYTHONPATH=src python -m repro.launch.hlo_breakdown --arch X --shape Y \\
      [--mesh single] [--top 15] [--collectives]
"""

from __future__ import annotations

import re
from collections import defaultdict

from repro.launch import hlo_cost

_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _tag(line: str) -> str:
    m = _OPNAME_RE.search(line)
    if not m:
        return "(no metadata)"
    name = m.group(1)
    # keep the trailing ~3 semantic segments; drop jit/transpose wrappers
    parts = [p for p in name.split("/")
             if p and not p.startswith(("jit(", "jvp(", "transpose("))]
    return "/".join(parts[-3:]) if parts else name[:60]


def breakdown(hlo: str, top: int = 15, collectives_only: bool = False):
    comps, entry = hlo_cost._parse(hlo)
    mult = defaultdict(float)
    mult[entry] = 1.0
    order, seen = [entry], {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = comps.get(name)
        if comp is None:
            continue
        m = mult[name]
        for body, cond in comp.while_bodies:
            trips = hlo_cost._trip_count(comps, cond)
            for sub, mul in ((body, m * trips), (cond, m * (trips + 1))):
                if sub:
                    mult[sub] += mul
                    if sub not in seen:
                        seen.add(sub)
                        order.append(sub)
        for callee, kind in comp.calls:
            if kind in hlo_cost._BOUNDARY_CALL_KINDS:
                continue
            mult[callee] += m
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    fusion_targets = {c for comp in comps.values()
                      for c, kind in comp.calls
                      if kind in hlo_cost._BOUNDARY_CALL_KINDS}
    rows = defaultdict(float)
    for name, comp in comps.items():
        if name in fusion_targets or mult.get(name, 0) == 0:
            continue
        m = mult[name]
        for op in comp.ops:
            if op.kind in hlo_cost._SKIP_OPS or op.kind.endswith("-done"):
                continue
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            is_coll = base in hlo_cost._COLLECTIVES
            if collectives_only and not is_coll:
                continue
            out_b = hlo_cost._sig_bytes(op.out_sig)
            if base in ("dynamic-slice", "slice", "gather"):
                opd = out_b
            elif base == "dynamic-update-slice":
                u = op.operand_vars[1] if len(op.operand_vars) > 1 else None
                opd = hlo_cost._sig_bytes(comp.symbols.get(u, ""))
                out_b = opd
            elif base == "fusion":
                opd, out_b = hlo_cost._fusion_bytes(comps, comp, op)
            else:
                opd = sum(hlo_cost._sig_bytes(comp.symbols.get(v, ""))
                          for v in op.operand_vars)
            rows[(base, _tag(op.line))] += m * (out_b + opd)
    ranked = sorted(rows.items(), key=lambda kv: -kv[1])[:top]
    total = sum(rows.values())
    return ranked, total


def main() -> None:
    import argparse
    import os
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_force_host_platform_device_count=512 "
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--collectives", action="store_true")
    args = ap.parse_args()

    import jax
    from jax.sharding import NamedSharding
    from repro.configs.registry import build_cell, get_arch
    from repro.distributed import sharding as shd
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_production_mesh(multi_pod=(args.mesh == "multi"))
    rules = (shd.TRAIN_RULES if args.shape.startswith(
        ("train", "full_graph", "minibatch", "ogb", "molecule"))
        else shd.DEFAULT_RULES)
    with shd.use_mesh(mesh, rules):
        cell = build_cell(get_arch(args.arch), args.shape)
        in_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cell.in_specs,
                             is_leaf=lambda x: isinstance(
                                 x, jax.sharding.PartitionSpec))
        compiled = jax.jit(cell.fn, in_shardings=in_sh,
                           donate_argnums=cell.donate).lower(
            *cell.args).compile()
    ranked, total = breakdown(compiled.as_text(), args.top, args.collectives)
    kind = "collective" if args.collectives else "hbm"
    print(f"total {kind} bytes/device: {total:.3e}")
    for (op, tag), b in ranked:
        print(f"{b:10.3e}  {100 * b / total:5.1f}%  {op:22s} {tag}")


if __name__ == "__main__":
    main()
