import os
# 512 placeholder devices for the production meshes; WLICM disabled because
# XLA hoists bf16->f32 converts of remat-saved activation stacks out of the
# backward loop, materializing a full-precision copy of every saved
# residual (dry-run finding; +13 GiB/device on arctic-480b train_4k).
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Per cell this records (to JSON):
  * compile success + wall time
  * ``memory_analysis()``  — per-device bytes (args/output/temp/code)
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes accessed
  * collective traffic     — parsed from the post-SPMD HLO text, operand
                             bytes summed per collective kind
  * roofline terms (seconds) + dominant bottleneck (launch/roofline.py)

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 1]
  python -m repro.launch.dryrun --all --subprocess   # crash isolation

Results append to benchmarks/dryrun_results/<arch>__<shape>__<mesh>.json;
`benchmarks/roofline_report.py` renders the EXPERIMENTS.md tables.
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time
import traceback

import jax

from repro.launch import hlo_analysis, hlo_cost, mesh as mesh_lib
from repro.launch.roofline import roofline_terms

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


def run_cell(arch_id: str, shape_id: str, mesh_kind: str,
             overrides: dict | None = None, probe: bool = False) -> dict:
    """Lower + compile one cell on one mesh; return the record dict."""
    from jax.sharding import NamedSharding
    from repro.configs.registry import get_arch, build_cell
    from repro.distributed import sharding as shd

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
                 "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
                 "n_devices": mesh.devices.size}
    rules = (shd.TRAIN_RULES if shape_id.startswith(("train", "full_graph",
                                                     "minibatch", "ogb",
                                                     "molecule"))
             else shd.DEFAULT_RULES)
    t0 = time.monotonic()
    try:
        with shd.use_mesh(mesh, rules):
            arch = get_arch(arch_id)
            if overrides:
                import dataclasses
                arch = dataclasses.replace(
                    arch, config=dataclasses.replace(arch.config, **overrides))
            cell = build_cell(arch, shape_id)
            to_ns = lambda spec: NamedSharding(mesh, spec)
            in_shardings = jax.tree.map(
                to_ns, cell.in_specs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            jitted = jax.jit(cell.fn, in_shardings=in_shardings,
                             donate_argnums=cell.donate)
            lowered = jitted.lower(*cell.args)
            t_lower = time.monotonic()
            compiled = lowered.compile()
            t_compile = time.monotonic()

        rec["ok"] = True
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_device_bytes": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
            }
        if os.environ.get("DRYRUN_VERBOSE") == "1":
            print(compiled.memory_analysis())   # proves it fits
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if not k.endswith("}")})     # FLOPs/bytes for §Roofline
        ca = compiled.cost_analysis() or {}
        # XLA's numbers count while-loop bodies once — kept for reference.
        rec["cost_xla_raw"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}
        hlo = compiled.as_text()
        # Loop-aware re-derivation (launch/hlo_cost.py) is the roofline input.
        lc = hlo_cost.analyze(hlo)
        rec["cost"] = {"flops": lc["flops"],
                       "bytes_accessed": lc["bytes_accessed"],
                       "transcendentals": float(ca.get("transcendentals", 0.0))}
        rec["collectives"] = {
            "counts": lc["collective_counts"],
            "bytes": lc["collective_bytes"],
            "total_bytes": lc["collective_total_bytes"],
            "n_ops": lc["collective_n_ops"],
        }
        rec["meta"] = {k: float(v) for k, v in cell.meta.items()}
        if not probe:
            rec["roofline"] = roofline_terms(rec)
            if os.environ.get("DRYRUN_PROBES") == "1":
                probe_crosscheck(rec, arch_id, shape_id, mesh_kind)
    except Exception as e:  # noqa: BLE001 — record, don't die
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["compile_s"] = round(time.monotonic() - t0, 2)
    return rec


def _probe_costs(arch_id: str, shape_id: str, mesh_kind: str,
                 overrides: dict) -> dict | None:
    """Compile a small unrolled probe and return its per-device costs.

    cost_analysis() counts a while-loop body ONCE regardless of trip count,
    so scan-over-layers models undercount FLOPs ~L-fold. Probes rebuild the
    cell with n layers unrolled inside a trip-1 loop (scan(unroll=n)) so
    every layer is counted; linear extrapolation recovers the full model.
    """
    rec = run_cell(arch_id, shape_id, mesh_kind, overrides=overrides,
                   probe=True)
    if not rec.get("ok"):
        return None
    return {"flops": rec["cost_xla_raw"]["flops"]}


def probe_crosscheck(rec: dict, arch_id: str, shape_id: str,
                     mesh_kind: str) -> None:
    """Optional validation: compare hlo_cost FLOPs with probe-linearized.

    LM: C(L) = C(1) + (L-1)·(C(2)-C(1)) with layers unrolled and the flash
    KV-block scan collapsed to a single block (identical FLOPs — every
    (q,k) pair is computed exactly once either way).
    DIEN: same linearization over GRU seq_len.
    Other families have no data-dependent loops; costs are already exact.
    """
    from repro.configs.registry import get_arch

    arch = get_arch(arch_id)
    sh = arch.shapes[shape_id]
    if arch.family == "lm":
        seq = sh["seq_len"]
        base = dict(scan_unroll=1, flash_block=seq, loss_chunk=seq)
        c1 = _probe_costs(arch_id, shape_id, mesh_kind, {**base, "n_layers": 1})
        c2 = _probe_costs(arch_id, shape_id, mesh_kind,
                          {**base, "n_layers": 2, "scan_unroll": 2})
        n_steps = arch.config.n_layers
    elif arch.family == "recsys" and arch.config.model == "dien":
        c1 = _probe_costs(arch_id, shape_id, mesh_kind,
                          {"seq_len": 1, "scan_unroll": 1})
        c2 = _probe_costs(arch_id, shape_id, mesh_kind,
                          {"seq_len": 2, "scan_unroll": 2})
        n_steps = arch.config.seq_len
    else:
        return
    if c1 is None or c2 is None:
        rec["probe_crosscheck"] = {"error": "probe compile failed"}
        return
    lin_flops = c1["flops"] + (n_steps - 1) * max(c2["flops"] - c1["flops"], 0.0)
    rec["probe_crosscheck"] = {
        "probe_linearized_flops": lin_flops,
        "hlo_cost_flops": rec["cost"]["flops"],
        "agreement": (rec["cost"]["flops"] / lin_flops) if lin_flops else None,
        "n_steps": n_steps,
    }


def save(rec: dict) -> pathlib.Path:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    path = RESULTS_DIR / f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    path.write_text(json.dumps(rec, indent=1))
    return path


def _summary(rec: dict) -> str:
    if not rec["ok"]:
        return f"FAIL {rec['arch']}/{rec['shape']}/{rec['mesh']}: {rec['error']}"
    r = rec.get("roofline", {})
    mem = rec.get("memory", {}).get("peak_device_bytes", 0) / 2**30
    return (f"ok   {rec['arch']}/{rec['shape']}/{rec['mesh']}: "
            f"compile {rec['compile_s']}s  peak {mem:.2f} GiB/dev  "
            f"bound={r.get('dominant', '?')}  "
            f"t_comp={r.get('compute_s', 0):.2e}s t_mem={r.get('memory_s', 0):.2e}s "
            f"t_coll={r.get('collective_s', 0):.2e}s")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="one subprocess per cell (crash isolation)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        from repro.configs.registry import all_cells
        cells = all_cells()
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch_id, shape_id in cells:
        for mesh_kind in meshes:
            out = RESULTS_DIR / f"{arch_id}__{shape_id}__{mesh_kind}.json"
            if args.skip_existing and out.exists():
                rec = json.loads(out.read_text())
                if rec.get("ok"):
                    print(f"skip {arch_id}/{shape_id}/{mesh_kind} (done)")
                    continue
            if not args.all:
                # single-cell mode: print the raw analyses (spec: the
                # dry-run must print memory_analysis / cost_analysis)
                os.environ["DRYRUN_VERBOSE"] = "1"
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch_id, "--shape", shape_id,
                       "--mesh", mesh_kind]
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      env={**os.environ,
                                           "PYTHONPATH": os.environ.get("PYTHONPATH", "src")})
                if proc.returncode != 0 and not out.exists():
                    rec = {"arch": arch_id, "shape": shape_id,
                           "mesh": mesh_kind, "ok": False,
                           "error": f"subprocess rc={proc.returncode}",
                           "traceback": proc.stderr[-4000:]}
                    save(rec)
                rec = json.loads(out.read_text()) if out.exists() else rec
            else:
                rec = run_cell(arch_id, shape_id, mesh_kind)
                save(rec)
            print(_summary(rec), flush=True)
            failures += 0 if rec.get("ok") else 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
