"""Production training driver: mesh + pipeline + checkpoints + heartbeats.

  PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 100 \\
      [--smoke] [--ckpt-dir /tmp/ckpt] [--restore]

``--smoke`` shrinks the arch to a CPU-runnable config on a 1x1 mesh but
exercises the identical code path the dry-run lowers at full scale:
rules-based sharding, grad-accumulated train step, sharded data pipeline
with prefetch, async checkpoints with atomic commit, heartbeat-driven
fault detection. On the production mesh the same script runs per-host
with jax.distributed initialization (not available in this container).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import get_arch
    from repro.data.pipeline import Prefetcher, ShardedStream, lm_batch_factory
    from repro.distributed import sharding as shd
    from repro.distributed.fault_tolerance import FaultToleranceManager
    from repro.launch import mesh as mesh_lib
    from repro.models import transformer as tfm
    from repro.training import train_loop
    from repro.training.checkpoint import CheckpointManager

    arch = get_arch(args.arch)
    if arch.family != "lm":
        raise SystemExit("train.py drives LM archs; GNN/recsys train via "
                         "their smoke tests / benchmarks")
    cfg = arch.config
    if args.smoke:
        cfg = dataclasses.replace(
            cfg, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
            head_dim=32, d_ff=256, vocab=2048,
            moe=dataclasses.replace(cfg.moe, n_experts=4, d_ff=128)
            if cfg.moe else None,
            dtype=jax.numpy.float32, loss_chunk=64)
        mesh = mesh_lib.make_host_mesh()
    else:
        mesh = mesh_lib.make_production_mesh()

    opt_cfg = arch.optimizer
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    ftm = FaultToleranceManager(n_workers=1, data_parallel=1, model_parallel=1)

    with shd.use_mesh(mesh, shd.TRAIN_RULES):
        params = tfm.init_params(jax.random.key(0), cfg)
        state = train_loop.init_train_state(params, opt_cfg)
        step_fn = train_loop.make_train_step(
            lambda p, b: tfm.train_loss(p, b, cfg), opt_cfg)
        p_pspecs = shd.tree_pspecs(params)
        from repro.training import optimizer as opt_lib
        state_specs = {"params": p_pspecs,
                       "opt": opt_lib.state_pspecs(params, p_pspecs, opt_cfg),
                       "step": P()}
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            state, state_specs,
            is_leaf=lambda x: isinstance(x, jax.Array))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

        start = 0
        if args.restore and ckpt.latest_step() is not None:
            state = ckpt.restore(state)
            start = int(np.asarray(state["step"]))
            print(f"restored from step {start}")

        stream = ShardedStream(
            lm_batch_factory(args.batch, args.seq, cfg.vocab),
            seed=0, shard_id=0, num_shards=1, start_step=start)
        batches = Prefetcher(iter(stream), prefetch=2)

        for i in range(start, start + args.steps):
            t0 = time.monotonic()
            batch = {k: jax.numpy.asarray(v) for k, v in next(batches).items()}
            state, metrics = jit_step(state, batch)
            dt = time.monotonic() - t0
            ftm.heartbeat(0, i, latency_s=dt)
            if i % 5 == 0 or i == start + args.steps - 1:
                print(f"step {i}: loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.2f}s)")
            if (i + 1) % args.ckpt_every == 0:
                ckpt.save(i + 1, state, blocking=False)
        ckpt.wait()
        ckpt.save(start + args.steps, state)
        print(f"done; checkpoints: {ckpt.all_steps()}; "
              f"dead workers: {ftm.dead_workers()}")


if __name__ == "__main__":
    main()
