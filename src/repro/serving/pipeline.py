"""The pipelined serving flow: dispatch → micro-batch queues → engines,
with streaming recalibration folded in.

One :class:`ServingPipeline` owns

  * a :class:`~repro.serving.router_service.SkewRouteDispatcher` running
    the fused skew-metrics kernel over whole request batches (with an
    optional drift-aware :class:`~repro.core.streaming_calibrate.\
StreamingCalibrator` hot-swapping thresholds inline);
  * one :class:`~repro.serving.scheduler.MicroBatchQueue` per tier, so
    tier engines always execute full, shape-stable micro-batches;
  * per-tier runner callables (an :class:`~repro.serving.engine.\
EngineBank`'s ``runners()`` in production, fakes in tests);
  * telemetry: queue depths, executed batches, recalibration count,
    tier mix.

The flow is synchronous by design — the parallelism lives inside the
jitted kernels and engine steps; the host-side control plane stays a
deterministic, testable state machine (same philosophy as TierScheduler's
simulated clocks).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving import _deprecation
from repro.serving.router_service import (BatchDispatchResult,
                                          SkewRouteDispatcher)
from repro.serving.scheduler import MicroBatchQueue


@dataclasses.dataclass
class ExecutedBatch:
    """One micro-batch run on a tier engine (telemetry + test hook)."""

    tier: int
    size: int
    result: object  # whatever the tier runner returned


@dataclasses.dataclass
class PipelineTelemetry:
    n_submitted: int = 0
    n_executed: int = 0
    n_microbatches: int = 0
    n_recalibrations: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)

    def snapshot(self, queues: dict[int, MicroBatchQueue]) -> dict:
        state = self.state_dict()
        state["tier_counts"] = {int(t): c
                                for t, c in state["tier_counts"].items()}
        state["queue_depths"] = {t: len(q) for t, q in queues.items()}
        return state

    # -- serializable state (the single source of the counter list) ----------

    def state_dict(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "n_executed": self.n_executed,
            "n_microbatches": self.n_microbatches,
            "n_recalibrations": self.n_recalibrations,
            "tier_counts": {str(t): c for t, c in self.tier_counts.items()},
        }

    def load_state_dict(self, state: dict) -> None:
        self.n_submitted = int(state["n_submitted"])
        self.n_executed = int(state["n_executed"])
        self.n_microbatches = int(state["n_microbatches"])
        self.n_recalibrations = int(state["n_recalibrations"])
        self.tier_counts = {int(t): int(c)
                            for t, c in state["tier_counts"].items()}


class ServingPipeline:
    """Batched dispatch through per-tier micro-batch queues to runners."""

    def __init__(self, dispatcher: SkewRouteDispatcher,
                 runners: dict[int, Callable[[list], object]],
                 micro_batch: int = 8):
        _deprecation.warn_once(
            "ServingPipeline",
            "hand-wiring ServingPipeline is deprecated; declare the policy "
            "as a repro.api.RouteSpec and call repro.api.build(spec, "
            "runners=...) (see README 'Routing fast path')")
        n_tiers = dispatcher.router.n_tiers
        missing = set(range(n_tiers)) - set(runners)
        if missing:
            raise ValueError(f"runners missing for tiers {sorted(missing)}")
        self.dispatcher = dispatcher
        self.runners = dict(runners)
        self.queues = {t: MicroBatchQueue(t, micro_batch)
                       for t in range(n_tiers)}
        self.telemetry = PipelineTelemetry(
            tier_counts={t: 0 for t in range(n_tiers)})
        self.executed: list[ExecutedBatch] = []

    # -- internals ------------------------------------------------------------

    def _run(self, tier: int, batch: list) -> None:
        result = self.runners[tier](batch)
        self.executed.append(ExecutedBatch(tier=tier, size=len(batch),
                                           result=result))
        self.telemetry.n_microbatches += 1
        self.telemetry.n_executed += len(batch)

    # -- the flow -------------------------------------------------------------

    def submit(self, scores_desc: np.ndarray,
               payloads: Optional[Sequence] = None,
               n_valid: Optional[np.ndarray] = None) -> BatchDispatchResult:
        """Dispatch a request batch and pump full micro-batches.

        ``scores_desc``: [B, K] descending top-K retrieval scores.
        ``payloads``: per-request items handed to the tier runner (prompt
        token arrays in production); defaults to the dispatch records.
        Returns the dispatch result (tiers, difficulty, all four metrics,
        whether a drift hot-swap fired).
        """
        scores = np.asarray(scores_desc)
        if payloads is not None and len(payloads) != scores.shape[0]:
            raise ValueError(f"{scores.shape[0]} score rows but "
                             f"{len(payloads)} payloads")
        res: BatchDispatchResult = self.dispatcher.dispatch_batch(
            scores, n_valid=n_valid, return_details=True)
        # per-request records are lazy; only build them when they ARE the
        # payloads — with explicit payloads the tier array is all we need
        items = payloads if payloads is not None else res.records
        self.telemetry.n_submitted += len(items)
        if res.recalibrated:
            self.telemetry.n_recalibrations += 1
        for tier, item in zip(res.tiers.tolist(), items):
            self.telemetry.tier_counts[tier] += 1
            for full in self.queues[tier].push(item):
                self._run(tier, full)
        return res

    def flush(self) -> int:
        """Drain partial micro-batches (burst tail / shutdown); returns
        the number of requests executed."""
        drained = 0
        for tier, q in self.queues.items():
            tail = q.flush()
            if tail:
                self._run(tier, tail)
                drained += len(tail)
        return drained

    def stats(self) -> dict:
        return self.telemetry.snapshot(self.queues)
