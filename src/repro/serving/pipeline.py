"""The pipelined serving flow: dispatch → micro-batch queues → engines,
with streaming recalibration and (optionally) load-aware admission
control folded in.

One :class:`ServingPipeline` owns

  * a :class:`~repro.serving.router_service.SkewRouteDispatcher` running
    the fused skew-metrics kernel over whole request batches (with an
    optional drift-aware :class:`~repro.core.streaming_calibrate.\
StreamingCalibrator` hot-swapping thresholds inline);
  * one :class:`~repro.serving.scheduler.MicroBatchQueue` per tier, so
    tier engines always execute full, shape-stable micro-batches;
  * per-tier runner callables (an :class:`~repro.serving.engine.\
EngineBank`'s ``runners()`` in production, fakes in tests);
  * optionally an :class:`~repro.serving.admission.AdmissionController`
    (``admission=``): each submit runs one feedback tick (pressure /
    budget → threshold hot-swap) and, while spill is engaged, demotes
    marginal top-tier requests one tier before they queue. With
    ``admission=None`` the flow is exactly the pre-admission pipeline —
    bit-for-bit identical routing decisions;
  * telemetry: queue depths, executed batches, recalibration count,
    spill count, tier mix.

The flow is synchronous by design — the parallelism lives inside the
jitted kernels and engine steps; the host-side control plane stays a
deterministic, testable state machine (same philosophy as TierScheduler's
simulated clocks).

Tier accounting with admission enabled: ``dispatcher.stats.tier_counts``
records the routing *decisions* (pre-spill) while
``pipeline.telemetry.tier_counts`` records the *executed* mix
(post-spill) — the gap between them is exactly the spilled traffic, and
realized spend follows the executed mix (the admission controller's
$/query EWMA; ``dispatcher.stats.total_cost`` stays decision-priced).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.serving import _deprecation
from repro.serving.admission import AdmissionController
from repro.serving.router_service import (BatchDispatchResult,
                                          SkewRouteDispatcher)
from repro.serving.scheduler import MicroBatchQueue


@dataclasses.dataclass
class ExecutedBatch:
    """One micro-batch run on a tier engine (telemetry + test hook)."""

    tier: int
    size: int
    result: object  # whatever the tier runner returned


@dataclasses.dataclass
class PipelineTelemetry:
    """Pipeline counters. Serialization contract (state_dict): counters
    ONLY — pending micro-batch queue payloads are arbitrary Python
    objects and are NOT part of telemetry state. The invariant
    ``n_submitted == n_executed + pending queue depth`` therefore only
    survives a state round-trip on DRAINED queues: flush() before
    saving, and restore through :meth:`ServingPipeline.load_telemetry`
    (which refuses non-empty queues) so pending items are never double-
    nor zero-executed."""

    n_submitted: int = 0
    n_executed: int = 0
    n_microbatches: int = 0
    n_recalibrations: int = 0
    n_spilled: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)

    def snapshot(self, queues: dict[int, MicroBatchQueue]) -> dict:
        state = self.state_dict()
        state["tier_counts"] = {int(t): c
                                for t, c in state["tier_counts"].items()}
        state["queue_depths"] = {t: len(q) for t, q in queues.items()}
        return state

    # -- serializable state (the single source of the counter list) ----------

    def state_dict(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "n_executed": self.n_executed,
            "n_microbatches": self.n_microbatches,
            "n_recalibrations": self.n_recalibrations,
            "n_spilled": self.n_spilled,
            "tier_counts": {str(t): c for t, c in self.tier_counts.items()},
        }

    def load_state_dict(self, state: dict) -> None:
        self.n_submitted = int(state["n_submitted"])
        self.n_executed = int(state["n_executed"])
        self.n_microbatches = int(state["n_microbatches"])
        self.n_recalibrations = int(state["n_recalibrations"])
        # absent in pre-admission snapshots; those never spilled
        self.n_spilled = int(state.get("n_spilled", 0))
        self.tier_counts = {int(t): int(c)
                            for t, c in state["tier_counts"].items()}


class ServingPipeline:
    """Batched dispatch through per-tier micro-batch queues to runners."""

    def __init__(self, dispatcher: SkewRouteDispatcher,
                 runners: dict[int, Callable[[list], object]],
                 micro_batch: int = 8,
                 admission: Optional[AdmissionController] = None):
        _deprecation.warn_once(
            "ServingPipeline",
            "hand-wiring ServingPipeline is deprecated; declare the policy "
            "as a repro.api.RouteSpec and call repro.api.build(spec, "
            "runners=...) (see README 'Routing fast path')")
        n_tiers = dispatcher.router.n_tiers
        missing = set(range(n_tiers)) - set(runners)
        if missing:
            raise ValueError(f"runners missing for tiers {sorted(missing)}")
        if admission is not None and dispatcher.calibrator is None:
            raise ValueError("admission control requires a dispatcher with "
                             "an attached streaming calibrator")
        self.dispatcher = dispatcher
        self.runners = dict(runners)
        self.admission = admission
        self.queues = {t: MicroBatchQueue(t, micro_batch)
                       for t in range(n_tiers)}
        self.telemetry = PipelineTelemetry(
            tier_counts={t: 0 for t in range(n_tiers)})
        self.executed: list[ExecutedBatch] = []

    # -- internals ------------------------------------------------------------

    def _run(self, tier: int, batch: list) -> None:
        result = self.runners[tier](batch)
        self.executed.append(ExecutedBatch(tier=tier, size=len(batch),
                                           result=result))
        self.telemetry.n_microbatches += 1
        self.telemetry.n_executed += len(batch)

    # -- the flow -------------------------------------------------------------

    def submit(self, scores_desc: np.ndarray,
               payloads: Optional[Sequence] = None,
               n_valid: Optional[np.ndarray] = None,
               self_scores: Optional[np.ndarray] = None
               ) -> BatchDispatchResult:
        """Dispatch a request batch and pump full micro-batches.

        ``scores_desc``: [B, K] descending top-K retrieval scores.
        ``payloads``: per-request items handed to the tier runner (prompt
        token arrays in production); defaults to the dispatch records.
        ``self_scores``: optional [B] engine self-uncertainty feeding
        confidence-aware routing policies (cascade).
        Returns the dispatch result (tiers, difficulty, all four metrics,
        whether a drift hot-swap fired). With an admission controller
        attached, requests execute on ``admission.apply``'s possibly
        down-spilled tiers; the returned result still reports the
        dispatcher's decisions.
        """
        scores = np.asarray(scores_desc)
        if payloads is not None and len(payloads) != scores.shape[0]:
            raise ValueError(f"{scores.shape[0]} score rows but "
                             f"{len(payloads)} payloads")
        res: BatchDispatchResult = self.dispatcher.dispatch_batch(
            scores, n_valid=n_valid, return_details=True,
            self_scores=self_scores)
        exec_tiers = res.tiers
        if self.admission is not None:
            new_config = self.admission.control_step()
            if new_config is not None:
                self.dispatcher.apply_config(new_config)
                self.telemetry.n_recalibrations += 1
            # request_cost (when the policy priced per request — cascade
            # stage bills, depth-priced prompts) flows into the budget
            # EWMA so admission reacts to what the decision actually
            # costs, not the flat per-tier price.
            exec_tiers, n_spilled = self.admission.apply(
                res.tiers, res.difficulty, request_cost=res.request_cost)
            self.telemetry.n_spilled += n_spilled
        # per-request records are lazy; only build them when they ARE the
        # payloads — with explicit payloads the tier array is all we need
        items = payloads if payloads is not None else res.records
        self.telemetry.n_submitted += len(items)
        if res.recalibrated:
            self.telemetry.n_recalibrations += 1
        for tier, item in zip(exec_tiers.tolist(), items):
            self.telemetry.tier_counts[tier] += 1
            for full in self.queues[tier].push(item):
                self._run(tier, full)
        return res

    def flush(self) -> int:
        """Drain partial micro-batches (burst tail / shutdown); returns
        the number of requests executed."""
        drained = 0
        for tier, q in self.queues.items():
            tail = q.flush()
            if tail:
                self._run(tier, tail)
                drained += len(tail)
        return drained

    def pending(self) -> int:
        """Requests sitting in partial micro-batches (not yet executed)."""
        return sum(len(q) for q in self.queues.values())

    def load_telemetry(self, state: dict) -> None:
        """Restore telemetry counters (see the PipelineTelemetry
        contract). Queue contents do not round-trip through telemetry
        state, so restoring over pending payloads would desync
        ``n_submitted`` from what later flushes execute — refuse it."""
        depths = {t: len(q) for t, q in self.queues.items() if len(q)}
        if depths:
            raise RuntimeError(
                f"cannot restore telemetry over pending micro-batch "
                f"payloads (queue depths {depths}); flush() first")
        self.telemetry.load_state_dict(state)
        # executed-batch history must match the restored counters
        self.executed.clear()

    def stats(self) -> dict:
        out = self.telemetry.snapshot(self.queues)
        if self.admission is not None:
            out["admission"] = self.admission.telemetry()
        return out
