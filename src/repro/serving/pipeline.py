"""The pipelined serving flow: dispatch → micro-batch queues → engines,
with streaming recalibration and (optionally) load-aware admission
control folded in.

One :class:`ServingPipeline` owns

  * a :class:`~repro.serving.router_service.SkewRouteDispatcher` running
    the fused skew-metrics kernel over whole request batches (with an
    optional drift-aware :class:`~repro.core.streaming_calibrate.\
StreamingCalibrator` hot-swapping thresholds inline);
  * one :class:`~repro.serving.scheduler.MicroBatchQueue` per tier, so
    tier engines always execute full, shape-stable micro-batches;
  * per-tier runner callables (an :class:`~repro.serving.engine.\
EngineBank`'s ``runners()`` in production, fakes in tests);
  * optionally an :class:`~repro.serving.admission.AdmissionController`
    (``admission=``): each submit runs one feedback tick (pressure /
    budget → threshold hot-swap) and, while spill is engaged, demotes
    marginal top-tier requests one tier before they queue. With
    ``admission=None`` the flow is exactly the pre-admission pipeline —
    bit-for-bit identical routing decisions;
  * telemetry: queue depths, executed batches, recalibration count,
    spill count, tier mix.

The flow is synchronous by design — the parallelism lives inside the
jitted kernels and engine steps; the host-side control plane stays a
deterministic, testable state machine (same philosophy as TierScheduler's
simulated clocks).

Tier accounting with admission enabled: ``dispatcher.stats.tier_counts``
records the routing *decisions* (pre-spill) while
``pipeline.telemetry.tier_counts`` records the *executed* mix
(post-spill) — the gap between them is exactly the spilled traffic, and
realized spend follows the executed mix (the admission controller's
$/query EWMA; ``dispatcher.stats.total_cost`` stays decision-priced).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs import NULL_OBS, int_keyed, str_keyed
from repro.serving import _deprecation
from repro.serving.admission import AdmissionController
from repro.serving.router_service import (BatchDispatchResult,
                                          SkewRouteDispatcher)
from repro.serving.scheduler import MicroBatchQueue


@dataclasses.dataclass
class ExecutedBatch:
    """One micro-batch run on a tier engine (telemetry + test hook)."""

    tier: int
    size: int
    result: object  # whatever the tier runner returned


@dataclasses.dataclass
class PipelineTelemetry:
    """Pipeline counters. Serialization contract (state_dict): counters
    ONLY — pending micro-batch queue payloads are arbitrary Python
    objects and are NOT part of telemetry state. The invariant
    ``n_submitted == n_executed + pending queue depth`` therefore only
    survives a state round-trip on DRAINED queues: flush() before
    saving, and restore through :meth:`ServingPipeline.load_telemetry`
    (which refuses non-empty queues) so pending items are never double-
    nor zero-executed."""

    n_submitted: int = 0
    n_executed: int = 0
    n_microbatches: int = 0
    n_recalibrations: int = 0
    n_spilled: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)

    def snapshot(self, queues: dict[int, MicroBatchQueue]) -> dict:
        state = self.state_dict()
        state["tier_counts"] = int_keyed(state["tier_counts"])
        state["queue_depths"] = {t: len(q) for t, q in queues.items()}
        return state

    # -- serializable state (the single source of the counter list) ----------

    def state_dict(self) -> dict:
        return {
            "n_submitted": self.n_submitted,
            "n_executed": self.n_executed,
            "n_microbatches": self.n_microbatches,
            "n_recalibrations": self.n_recalibrations,
            "n_spilled": self.n_spilled,
            "tier_counts": str_keyed(self.tier_counts),
        }

    def load_state_dict(self, state: dict) -> None:
        self.n_submitted = int(state["n_submitted"])
        self.n_executed = int(state["n_executed"])
        self.n_microbatches = int(state["n_microbatches"])
        self.n_recalibrations = int(state["n_recalibrations"])
        # absent in pre-admission snapshots; those never spilled
        self.n_spilled = int(state.get("n_spilled", 0))
        self.tier_counts = int_keyed(state["tier_counts"])


class ServingPipeline:
    """Batched dispatch through per-tier micro-batch queues to runners."""

    def __init__(self, dispatcher: SkewRouteDispatcher,
                 runners: dict[int, Callable[[list], object]],
                 micro_batch: int = 8,
                 admission: Optional[AdmissionController] = None,
                 obs=None):
        _deprecation.warn_once(
            "ServingPipeline",
            "hand-wiring ServingPipeline is deprecated; declare the policy "
            "as a repro.api.RouteSpec and call repro.api.build(spec, "
            "runners=...) (see README 'Routing fast path')")
        n_tiers = dispatcher.router.n_tiers
        missing = set(range(n_tiers)) - set(runners)
        if missing:
            raise ValueError(f"runners missing for tiers {sorted(missing)}")
        if admission is not None and dispatcher.calibrator is None:
            raise ValueError("admission control requires a dispatcher with "
                             "an attached streaming calibrator")
        self.dispatcher = dispatcher
        self.runners = dict(runners)
        self.admission = admission
        self.queues = {t: MicroBatchQueue(t, micro_batch)
                       for t in range(n_tiers)}
        self.telemetry = PipelineTelemetry(
            tier_counts={t: 0 for t in range(n_tiers)})
        self.executed: list[ExecutedBatch] = []
        # Observability mirrors. The per-tier `_queued_ids` shadow queues
        # (obs-enabled only) track WHICH request ids sit in each
        # MicroBatchQueue — both are strict FIFO, so the ids popped in
        # `_run` name exactly the payloads in that micro-batch without
        # touching the runner payload contract.
        self.obs = obs if obs is not None else getattr(
            dispatcher, "obs", NULL_OBS)
        m = self.obs.metrics
        self._m_submitted = m.counter("pipeline_submitted_total")
        self._m_executed = m.counter("pipeline_executed_total")
        self._m_microbatches = m.counter("pipeline_microbatches_total")
        self._m_recal = m.counter("pipeline_recalibrations_total")
        self._m_spilled = m.counter("pipeline_spilled_total")
        self._m_tiers = [m.counter("pipeline_tier_executed_total",
                                   tier=str(t)) for t in range(n_tiers)]
        self._g_pending = [m.gauge("pipeline_queue_depth", tier=str(t))
                           for t in range(n_tiers)]
        self._h_run_s = m.histogram("pipeline_run_seconds")
        self._queued_ids: dict[int, list] = {t: [] for t in range(n_tiers)}

    def _obs_resync(self) -> None:
        """Re-point the registry's pipeline mirrors at the (restored)
        telemetry counters; called by the session after restore."""
        if not self.obs.enabled:
            return
        t = self.telemetry
        self._m_submitted.value = t.n_submitted
        self._m_executed.value = t.n_executed
        self._m_microbatches.value = t.n_microbatches
        self._m_recal.value = t.n_recalibrations
        self._m_spilled.value = t.n_spilled
        for tier, mt in enumerate(self._m_tiers):
            mt.value = t.tier_counts.get(tier, 0)
        for tier, g in enumerate(self._g_pending):
            g.set(len(self.queues[tier]))

    # -- internals ------------------------------------------------------------

    def _run(self, tier: int, batch: list) -> None:
        obs_on = self.obs.enabled
        rids = None
        if obs_on:
            q = self._queued_ids[tier]
            rids, self._queued_ids[tier] = q[:len(batch)], q[len(batch):]
            t0 = self.obs.clock.now()
        result = self.runners[tier](batch)
        self.executed.append(ExecutedBatch(tier=tier, size=len(batch),
                                           result=result))
        self.telemetry.n_microbatches += 1
        self.telemetry.n_executed += len(batch)
        self._m_microbatches.inc()
        self._m_executed.inc(len(batch))
        if obs_on:
            self._h_run_s.observe(self.obs.clock.now() - t0)
            self._g_pending[tier].set(len(self.queues[tier]))
            self.obs.tracer.event("execute", tier=tier, request_ids=rids,
                                  n=len(batch))

    # -- the flow -------------------------------------------------------------

    def submit(self, scores_desc: np.ndarray,
               payloads: Optional[Sequence] = None,
               n_valid: Optional[np.ndarray] = None,
               self_scores: Optional[np.ndarray] = None
               ) -> BatchDispatchResult:
        """Dispatch a request batch and pump full micro-batches.

        ``scores_desc``: [B, K] descending top-K retrieval scores.
        ``payloads``: per-request items handed to the tier runner (prompt
        token arrays in production); defaults to the dispatch records.
        ``self_scores``: optional [B] engine self-uncertainty feeding
        confidence-aware routing policies (cascade).
        Returns the dispatch result (tiers, difficulty, all four metrics,
        whether a drift hot-swap fired). With an admission controller
        attached, requests execute on ``admission.apply``'s possibly
        down-spilled tiers; the returned result still reports the
        dispatcher's decisions.
        """
        scores = np.asarray(scores_desc)
        if payloads is not None and len(payloads) != scores.shape[0]:
            raise ValueError(f"{scores.shape[0]} score rows but "
                             f"{len(payloads)} payloads")
        obs_on = self.obs.enabled
        with self.obs.tracer.span("submit", batch=int(scores.shape[0])):
            res: BatchDispatchResult = self.dispatcher.dispatch_batch(
                scores, n_valid=n_valid, return_details=True,
                self_scores=self_scores)
            exec_tiers = res.tiers
            if self.admission is not None:
                new_config = self.admission.control_step()
                if new_config is not None:
                    self.dispatcher.apply_config(new_config)
                    self.telemetry.n_recalibrations += 1
                    self._m_recal.inc()
                # request_cost (when the policy priced per request —
                # cascade stage bills, depth-priced prompts) flows into
                # the budget EWMA so admission reacts to what the
                # decision actually costs, not the flat per-tier price.
                exec_tiers, n_spilled = self.admission.apply(
                    res.tiers, res.difficulty, request_cost=res.request_cost)
                self.telemetry.n_spilled += n_spilled
                self._m_spilled.inc(n_spilled)
                if obs_on and n_spilled:
                    moved = np.flatnonzero(exec_tiers != res.tiers)
                    self.obs.tracer.event(
                        "spill",
                        request_ids=[res.first_id + int(i) for i in moved],
                        **{"from": res.tiers[moved].tolist(),
                           "to": exec_tiers[moved].tolist()})
            # per-request records are lazy; only build them when they ARE
            # the payloads — with explicit payloads the tier array is all
            # we need
            items = payloads if payloads is not None else res.records
            self.telemetry.n_submitted += len(items)
            self._m_submitted.inc(len(items))
            if res.recalibrated:
                self.telemetry.n_recalibrations += 1
                self._m_recal.inc()
            for i, (tier, item) in enumerate(zip(exec_tiers.tolist(), items)):
                self.telemetry.tier_counts[tier] += 1
                self._m_tiers[tier].inc()
                if obs_on:
                    self._queued_ids[tier].append(res.first_id + i)
                for full in self.queues[tier].push(item):
                    self._run(tier, full)
            if obs_on:
                for tier, g in enumerate(self._g_pending):
                    g.set(len(self.queues[tier]))
        return res

    def flush(self) -> int:
        """Drain partial micro-batches (burst tail / shutdown); returns
        the number of requests executed."""
        drained = 0
        with self.obs.tracer.span("flush"):
            for tier, q in self.queues.items():
                tail = q.flush()
                if tail:
                    self._run(tier, tail)
                    drained += len(tail)
        return drained

    def pending(self) -> int:
        """Requests sitting in partial micro-batches (not yet executed)."""
        return sum(len(q) for q in self.queues.values())

    def load_telemetry(self, state: dict) -> None:
        """Restore telemetry counters (see the PipelineTelemetry
        contract). Queue contents do not round-trip through telemetry
        state, so restoring over pending payloads would desync
        ``n_submitted`` from what later flushes execute — refuse it."""
        depths = {t: len(q) for t, q in self.queues.items() if len(q)}
        if depths:
            raise RuntimeError(
                f"cannot restore telemetry over pending micro-batch "
                f"payloads (queue depths {depths}); flush() first")
        self.telemetry.load_state_dict(state)
        # executed-batch history must match the restored counters
        self.executed.clear()
        self._queued_ids = {t: [] for t in self.queues}
        self._obs_resync()

    def stats(self) -> dict:
        out = self.telemetry.snapshot(self.queues)
        if self.admission is not None:
            out["admission"] = self.admission.telemetry()
        return out
