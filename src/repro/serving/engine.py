"""LM serving engine: prefill + decode against a static KV cache.

One engine per tier (small / medium / large model pool). Jitted step
functions are cached per (batch, prompt_len) bucket; prompts right-pad to
the bucket and decode greedily. The same `repro.models.transformer` code
paths the dry-run lowers at production shapes run here at test scale —
there is no separate "toy" model.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.models.layers import LMConfig
from repro.serving.scheduler import bucket_size


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024)) -> int:
    return bucket_size(n, buckets)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # [B, max_new]
    prompt_tokens: int
    generated_tokens: int


class LMEngine:
    def __init__(self, cfg: LMConfig, params, max_len: int = 2048):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len

    @functools.lru_cache(maxsize=32)
    def _prefill_fn(self, b: int, s: int):
        cfg = self.cfg

        def run(params, tokens):
            logits, cache = tfm.prefill(params, tokens, cfg)
            return logits, cache
        return jax.jit(run)

    @functools.lru_cache(maxsize=32)
    def _decode_fn(self, b: int, s: int):
        cfg = self.cfg

        def run(params, cache, tokens, pos):
            return tfm.decode_step(params, cache, tokens, pos, cfg)
        return jax.jit(run, donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """prompts: [B, S] int32 (right-padded with 0s is fine for the
        synthetic vocab). Greedy decode ``max_new`` tokens."""
        b, s = prompts.shape
        sb = _bucket(s)
        total = _bucket(min(sb + max_new, self.max_len))
        toks = np.zeros((b, sb), np.int32)
        toks[:, :s] = prompts
        logits, cache = self._prefill_fn(b, sb)(self.params, jnp.asarray(toks))
        # re-home the prefill cache into a longer decode cache
        dk = jnp.zeros((self.cfg.n_layers, b, total, self.cfg.kv_dim),
                       cache["k"].dtype)
        dv = jnp.zeros_like(dk)
        cache = {"k": jax.lax.dynamic_update_slice(dk, cache["k"], (0, 0, 0, 0)),
                 "v": jax.lax.dynamic_update_slice(dv, cache["v"], (0, 0, 0, 0))}
        decode = self._decode_fn(b, total)
        out = np.zeros((b, max_new), np.int32)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(max_new):
            out[:, i] = np.asarray(next_tok)
            logits, cache = decode(self.params, cache, next_tok[:, None],
                                   jnp.int32(s + i))
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            if eos_id is not None and bool(np.all(out[:, i] == eos_id)):
                out = out[:, : i + 1]
                break
        return GenerationResult(tokens=out, prompt_tokens=b * s,
                                generated_tokens=out.size)


def make_engine(cfg: LMConfig, seed: int = 0, max_len: int = 2048) -> LMEngine:
    params = tfm.init_params(jax.random.key(seed), cfg)
    return LMEngine(cfg, params, max_len=max_len)


class EngineBank:
    """Tier id -> LMEngine, adapted to micro-batch execution.

    The serving pipeline hands over lists of prompt arrays (one micro-
    batch from ``MicroBatchQueue``); the bank right-pads them to a common
    length and runs the tier's engine once. ``runners()`` exports the
    per-tier callables the pipeline consumes — tests inject fakes with
    the same signature.
    """

    def __init__(self, engines: dict[int, LMEngine], max_new: int = 16):
        if not engines:
            raise ValueError("EngineBank needs at least one tier engine")
        self.engines = dict(engines)
        self.max_new = max_new

    def run_tier(self, tier: int, prompts: list[np.ndarray]) -> GenerationResult:
        longest = max(p.shape[-1] for p in prompts)
        batch = np.zeros((len(prompts), longest), np.int32)
        for i, p in enumerate(prompts):
            batch[i, :p.shape[-1]] = p
        return self.engines[tier].generate(batch, max_new=self.max_new)

    def runners(self) -> dict[int, "functools.partial"]:
        return {t: functools.partial(self.run_tier, t) for t in self.engines}
