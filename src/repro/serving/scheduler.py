"""Continuous-batching scheduler with straggler mitigation.

Per tier: a bounded queue feeds fixed-size decode batches (slots freed as
sequences finish — continuous batching a la Orca/vLLM, at slot
granularity). Straggler / failure handling: every request carries a
deadline; a request stuck on an unhealthy replica past its deadline is
re-dispatched to the fastest healthy replica of the SAME tier (quality is
tier-sticky; latency is not). Replica health comes from the fault-
tolerance heartbeats.

Upstream of the replica pools sits :class:`MicroBatchQueue`: the batched
dispatcher emits tier ids for a whole request batch at once, and each
tier accumulates its requests into fixed-size micro-batches so the tier
engines always see full, shape-bucketed batches (one compiled step per
bucket) instead of singleton calls. ``serving/pipeline.py`` wires
dispatch → micro-batch queues → engines → streaming recalibration into
one flow.

Runs in-process with simulated replica clocks for tests; the dispatch
logic is the deliverable (the engine call is injected).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

# Completions below this: latency quantiles report nan instead of a
# degenerate value (p99 over <20 samples is just the max with extra steps).
P99_MIN_SAMPLES = 20
# Latency quantiles look at the most recent completions only: a feedback
# controller needs the CURRENT tail, and a lifetime quantile never
# recovers after one burst poisons it (measured: spill stayed engaged
# forever in examples/serve_under_load.py).
P99_WINDOW = 256


@dataclasses.dataclass
class Request:
    request_id: int
    tier: int
    prompt_len: int
    max_new: int
    deadline: float
    submitted_at: float
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    replica: Optional[int] = None
    redispatched: int = 0


@dataclasses.dataclass
class Replica:
    replica_id: int
    tier: int
    healthy: bool = True
    speed: float = 1.0          # tokens/sec multiplier (1.0 = nominal)
    busy_until: float = 0.0

    def eta(self, now: float, work: float) -> float:
        return max(self.busy_until, now) + work / max(self.speed, 1e-6)


class TierScheduler:
    """Scheduler for one tier's replica pool."""

    def __init__(self, tier: int, replicas: list[Replica],
                 batch_slots: int = 8, base_token_time: float = 0.01,
                 max_redispatch: int = 1, mode: str = "kg_rag"):
        self.tier = tier
        # Execution mode this pool serves (``no_rag`` / ``kg_rag`` /
        # ``long_context``). Pure metadata to the scheduler itself; the
        # loadgen runners consult it when sizing request prompts, so a
        # ``no_rag`` tier never pays retrieval-context decode time.
        self.mode = mode
        self.replicas = {r.replica_id: r for r in replicas}
        self.batch_slots = batch_slots
        self.base_token_time = base_token_time
        self.max_redispatch = max_redispatch
        self.pending: list[tuple[float, int, Request]] = []  # (deadline, id, req)
        self.inflight: dict[int, Request] = {}
        self.done: list[Request] = []
        self.now = 0.0  # last clock seen by step(); anchors horizons

    def submit(self, req: Request) -> None:
        heapq.heappush(self.pending, (req.deadline, req.request_id, req))

    def submit_batch(self, reqs: list[Request]) -> None:
        """Admit a whole micro-batch (the batched-dispatch fast path)."""
        for req in reqs:
            self.submit(req)

    def _work(self, req: Request) -> float:
        return (req.prompt_len * 0.1 + req.max_new) * self.base_token_time

    def _pick_replica(self, now: float, work: float) -> Optional[Replica]:
        healthy = [r for r in self.replicas.values() if r.healthy]
        if not healthy:
            return None
        return min(healthy, key=lambda r: r.eta(now, work))

    def step(self, now: float) -> list[Request]:
        """Advance the scheduler clock; returns requests completed by now."""
        self.now = max(self.now, now)
        # 1. finish in-flight work
        completed = []
        for rid, req in list(self.inflight.items()):
            rep = self.replicas[req.replica]
            if rep.healthy and rep.busy_until <= now:
                req.finished_at = rep.busy_until
                completed.append(req)
                self.done.append(req)
                del self.inflight[rid]
        # 2. straggler / failure re-dispatch: dead replica always; deadline
        # overruns at most ``max_redispatch`` times — unbounded yanking
        # starves long requests forever (measured: 80/120 requests churned
        # indefinitely in examples/serve_with_routing.py)
        for rid, req in list(self.inflight.items()):
            rep = self.replicas[req.replica]
            stuck = (not rep.healthy) or (
                now > req.deadline and rep.busy_until > req.deadline
                and req.redispatched < self.max_redispatch)
            if stuck:
                del self.inflight[rid]
                req.redispatched += 1
                req.replica = None
                heapq.heappush(self.pending,
                               (now, req.request_id, req))  # front of queue
        # 3. admit pending onto replicas (slot-limited)
        while self.pending and len(self.inflight) < self.batch_slots:
            _, _, req = heapq.heappop(self.pending)
            work = self._work(req)
            rep = self._pick_replica(now, work)
            if rep is None:
                heapq.heappush(self.pending, (req.deadline, req.request_id, req))
                break
            req.replica = rep.replica_id
            req.started_at = max(now, rep.busy_until)
            rep.busy_until = rep.eta(now, work)
            self.inflight[req.request_id] = req
        return completed

    # -- health hooks ---------------------------------------------------------

    def mark_unhealthy(self, replica_id: int) -> None:
        self.replicas[replica_id].healthy = False

    def mark_healthy(self, replica_id: int, speed: float = 1.0) -> None:
        rep = self.replicas[replica_id]
        rep.healthy, rep.speed = True, speed

    # -- load probes (what the admission controller consumes) -----------------

    def queue_depth(self) -> int:
        """Requests waiting for a replica slot (excludes in-flight work)."""
        return len(self.pending)

    def latency_quantile(self, q: float,
                         min_samples: int = P99_MIN_SAMPLES,
                         window: int = P99_WINDOW,
                         horizon: Optional[float] = None) -> float:
        """Latency quantile over the last ``window`` completions (those
        that finished within ``horizon`` seconds of the current clock,
        when given), or ``nan`` below ``min_samples`` of them — a tail
        quantile over a handful of requests is one request's latency
        wearing a costume, and feeding it to a feedback controller makes
        the controller chase noise. ``horizon`` matters for the same
        reason in the other direction: a low-throughput tier keeps
        burst-era completions in a count window long after the burst, so
        a controller watching it never sees recovery. ``nan`` also means
        "tier (near-)idle over the horizon", which callers should read
        as the absence of latency pressure, not as pressure."""
        recent = self.done[-max(window, 1):]
        lats = [r.finished_at - r.submitted_at for r in recent
                if r.finished_at is not None
                and (horizon is None
                     or r.finished_at >= self.now - horizon)]
        if len(lats) < max(min_samples, 1):
            return float("nan")
        return float(np.percentile(lats, q))

    def p99_latency(self, min_samples: int = P99_MIN_SAMPLES,
                    window: int = P99_WINDOW,
                    horizon: Optional[float] = None) -> float:
        return self.latency_quantile(99, min_samples=min_samples,
                                     window=window, horizon=horizon)


def bucket_size(n: int, buckets: tuple[int, ...]) -> int:
    """Round ``n`` up to the next bucket (multiples of the last bucket
    beyond it) — shared by engine prompt-length and dispatcher batch-size
    bucketing so jitted shapes stay few."""
    for b in buckets:
        if n <= b:
            return b
    return -(-n // buckets[-1]) * buckets[-1]


# -- micro-batch accumulation (between dispatcher and tier engines) -----------


class MicroBatchQueue:
    """Per-tier accumulator turning a stream of routed requests into
    fixed-size micro-batches.

    The batched dispatcher assigns tiers for B requests in one kernel
    call; each tier then wants its requests executed together so the
    engine's jitted step is reused at a stable batch shape. ``push``
    returns completed micro-batches as they fill; ``flush`` drains the
    remainder (tail of a traffic burst / shutdown).
    """

    def __init__(self, tier: int, batch_size: int = 8):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.tier = tier
        self.batch_size = batch_size
        self._items: list = []
        self.n_pushed = 0
        self.n_batches = 0

    def __len__(self) -> int:
        return len(self._items)

    def push(self, item) -> list[list]:
        """Add one routed request; returns zero or more FULL batches."""
        self._items.append(item)
        self.n_pushed += 1
        out = []
        while len(self._items) >= self.batch_size:
            out.append(self._items[:self.batch_size])
            self._items = self._items[self.batch_size:]
            self.n_batches += 1
        return out

    def push_many(self, items) -> list[list]:
        out = []
        for it in items:
            out.extend(self.push(it))
        return out

    def flush(self) -> Optional[list]:
        """Drain the partial tail batch, if any."""
        if not self._items:
            return None
        out, self._items = self._items, []
        self.n_batches += 1
        return out
