"""SkewRoute dispatcher: retrieval scores in, tier assignment out.

This is the paper's Algorithm 1 as a serving component, running on the
FUSED fast path. Per batch:

  1. the retrieval stage hands over the top-K triple scores (descending,
     optionally ragged via per-row ``n_valid``);
  2. the attached :class:`repro.api.backends.DifficultyBackend` (fused
     Pallas pass by default — ``auto``; interpret mode off-TPU) computes
     all four difficulty metrics in one call — the configured metric is
     a column select, never a recompile;
  3. the threshold router picks tiers; telemetry (tier counts, expected
     $ cost, mean difficulty) streams to the stats sink;
  4. difficulty samples feed the attached streaming calibrator
     (``core.streaming_calibrate``), which hot-swaps the thresholds when
     live traffic drifts off the calibrated tier shares;
  5. requests join their tier's micro-batch queue
     (``serving/scheduler.MicroBatchQueue`` via ``serving/pipeline``).

Batch shapes are bucketed (pad to the next bucket, slice the pad off) so
arbitrary request-batch sizes reuse a handful of compiled kernels.

Thresholds stay *hot-swappable*: both the offline calibrator
(core/calibrate.py) and the online one can re-fit them from unlabeled
samples without touching the serving path — the training-free property
operationalized.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.calibrate import calibrate_multi_tier
from repro.core.cost import CostModel
from repro.core.router import RouteBatchResult, RouterConfig
from repro.core.streaming_calibrate import StreamingCalibrator
from repro.obs import NULL_OBS, str_keyed, int_keyed
from repro.serving import _deprecation
from repro.serving.scheduler import bucket_size

BATCH_BUCKETS = (8, 64, 256, 1024, 4096)


@dataclasses.dataclass
class DispatchRecord:
    request_id: int
    tier: int
    difficulty: float
    metric: str


@dataclasses.dataclass
class BatchDispatchResult:
    """Per-batch fast-path output plus what the control plane did with it.

    ``records`` is built lazily on first access: array-only consumers
    (telemetry, the recsys example, bulk routing) never pay the
    per-request Python object loop.
    """

    tiers: np.ndarray         # [B] int32
    difficulty: np.ndarray    # [B] float32
    metrics: np.ndarray       # [B, 4] float32 (area, cum_k, entropy, gini)
    first_id: int = 0
    metric: str = ""
    recalibrated: bool = False
    # Routing-policy extras (None under the default threshold policy):
    # per-request $ the decision actually costs (cascades bill every
    # stage attempted) and per-request retrieval depth.
    request_cost: Optional[np.ndarray] = None
    depths: Optional[np.ndarray] = None

    @functools.cached_property
    def records(self) -> list[DispatchRecord]:
        return [DispatchRecord(request_id=self.first_id + i,
                               tier=int(self.tiers[i]),
                               difficulty=float(self.difficulty[i]),
                               metric=self.metric)
                for i in range(len(self.tiers))]


@dataclasses.dataclass
class RetrievedDispatchResult:
    """End-to-end dispatch output: the routing decision plus the top-K
    retrieval the fused program produced on the way (candidate indices
    into the per-query feature rows, sigmoid scores, valid prefix)."""

    result: BatchDispatchResult
    indices: np.ndarray       # [B, K] int32
    probs: np.ndarray         # [B, K] float32, descending
    n_valid: np.ndarray       # [B] int32

    @property
    def tiers(self) -> np.ndarray:
        return self.result.tiers


@dataclasses.dataclass
class DispatcherStats:
    n_requests: int = 0
    n_batches: int = 0
    n_recalibrations: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)
    total_cost: float = 0.0
    mean_difficulty: float = 0.0  # running mean over all dispatched requests

    @property
    def large_call_ratio(self) -> float:
        if not self.n_requests:
            return 0.0
        top = max(self.tier_counts) if self.tier_counts else 0
        return self.tier_counts.get(top, 0) / self.n_requests

    # -- serializable state (the single source of the counter list) ----------

    def state_dict(self) -> dict:
        return {
            "n_requests": self.n_requests,
            "n_batches": self.n_batches,
            "n_recalibrations": self.n_recalibrations,
            "tier_counts": str_keyed(self.tier_counts),
            "total_cost": self.total_cost,
            "mean_difficulty": self.mean_difficulty,
        }

    def load_state_dict(self, state: dict) -> None:
        self.n_requests = int(state["n_requests"])
        self.n_batches = int(state["n_batches"])
        self.n_recalibrations = int(state["n_recalibrations"])
        self.tier_counts = int_keyed(state["tier_counts"])
        self.total_cost = float(state["total_cost"])
        self.mean_difficulty = float(state["mean_difficulty"])


class SkewRouteDispatcher:
    def __init__(self, router: RouterConfig, tier_names: Sequence[str],
                 cost_model: Optional[CostModel] = None,
                 calibrator: Optional[StreamingCalibrator] = None,
                 backend=None, policy=None, obs=None):
        _deprecation.warn_once(
            "SkewRouteDispatcher",
            "hand-wiring SkewRouteDispatcher is deprecated; declare the "
            "policy as a repro.api.RouteSpec and call repro.api.build(spec) "
            "(see README 'Routing fast path')")
        if len(tier_names) != router.n_tiers:
            raise ValueError(f"{router.n_tiers} tiers but "
                             f"{len(tier_names)} tier names")
        if backend is None:
            # lazy import: repro.api composes this class, not vice versa
            from repro.api.backends import make_backend
            backend = make_backend("auto")
        self.backend = backend
        self.router = router
        self.tier_names = list(tier_names)
        self.cost_model = cost_model or CostModel()
        self.calibrator = calibrator
        if policy is None:
            # lazy import for the same layering reason as the backend
            from repro.policies import build_policy
            policy = build_policy(None, n_tiers=router.n_tiers,
                                  tier_models=tier_names,
                                  cost_model=self.cost_model)
        self.policy = policy
        self.stats = DispatcherStats(tier_counts={i: 0 for i in
                                                  range(router.n_tiers)})
        self._lock = threading.Lock()
        self._next_id = 0
        # Observability mirrors: instruments looked up ONCE here; every
        # record below is a plain attribute bump (no-ops under NULL_OBS).
        # DispatcherStats stays the serialization source; the registry is
        # the live read surface (old accessors preserved as views).
        self.obs = obs or NULL_OBS
        m = self.obs.metrics
        self._m_requests = m.counter("routing_requests_total")
        self._m_batches = m.counter("routing_batches_total")
        self._m_recal = m.counter("routing_recalibrations_total")
        self._m_cost = m.counter("routing_cost_dollars_total")
        self._m_mean_diff = m.gauge("routing_mean_difficulty")
        self._m_dispatch_s = m.histogram("routing_dispatch_seconds")
        self._m_tiers = [m.counter("routing_tier_decisions_total",
                                   tier=str(t))
                         for t in range(router.n_tiers)]

    def _obs_resync(self) -> None:
        """Point the registry's dispatcher mirrors at the (restored)
        stats — called by the session after a state restore so the live
        metrics agree with the restored counters."""
        if not self.obs.enabled:
            return
        s = self.stats
        self._m_requests.value = s.n_requests
        self._m_batches.value = s.n_batches
        self._m_recal.value = s.n_recalibrations
        self._m_cost.value = s.total_cost
        self._m_mean_diff.value = s.mean_difficulty
        for t, mt in enumerate(self._m_tiers):
            mt.value = s.tier_counts.get(t, 0)

    # -- calibration ----------------------------------------------------------

    def attach_calibrator(self, target_shares: Sequence[float],
                          **knobs) -> StreamingCalibrator:
        """Wire a drift-aware streaming calibrator into the dispatch flow."""
        self.calibrator = StreamingCalibrator(self.router, target_shares,
                                              **knobs)
        return self.calibrator

    def apply_config(self, new_router: RouterConfig,
                     quantile_source=None) -> None:
        """THE threshold hot-swap path — offline recalibration, the
        streaming drift calibrator, the admission controller, and the
        replica-sync merge all land here: swap the frozen config, keep
        the calibrator's view coherent, count it — and re-fit the
        routing policy's own cutoffs from the same sample set that
        produced the thresholds (``quantile_source``; defaults to the
        attached calibrator's window, replica sync passes its merged
        fleet quantile), so threshold and policy calibration can never
        diverge."""
        with self._lock:
            self.router = new_router
            self.stats.n_recalibrations += 1
            self._m_recal.inc()
            if self.calibrator is not None:
                self.calibrator.config = new_router
            self._refit_policy_locked(quantile_source)
        if self.obs.enabled:
            self.obs.tracer.event(
                "hot_swap", thresholds=list(new_router.thresholds),
                metric=new_router.metric)

    def _refit_policy_locked(self, quantile_source=None) -> None:
        """Policy-cutoff refit half of a hot-swap; caller holds the lock."""
        if not self.policy.needs_refit:
            return
        if quantile_source is None:
            cal = self.calibrator
            if cal is None or len(cal.window) < cal.min_samples:
                return  # nothing trustworthy to fit from yet
            quantile_source = cal.quantile_source()
        self.policy.refit(quantile_source)

    def recalibrate(self, calibration_scores: np.ndarray,
                    tier_shares: Sequence[float]) -> RouterConfig:
        """Hot-swap thresholds to hit new traffic shares (training-free)."""
        new_router = calibrate_multi_tier(
            jnp.asarray(calibration_scores), tier_shares,
            metric=self.router.metric, cumulative_p=self.router.cumulative_p)
        self.apply_config(new_router)
        return new_router

    # -- dispatch -------------------------------------------------------------

    def dispatch(self, scores_desc: np.ndarray,
                 n_valid: Optional[int] = None) -> DispatchRecord:
        """Route one request — same fused kernel, batch of one (bucketed
        to the smallest batch bucket, so it shares the compiled kernel
        with every other small batch)."""
        nv = None if n_valid is None else np.asarray([n_valid])
        return self.dispatch_batch(np.asarray(scores_desc)[None], n_valid=nv,
                                   return_details=True).records[0]

    def dispatch_batch(self, scores_desc: np.ndarray,
                       n_valid: Optional[np.ndarray] = None,
                       return_details: bool = False,
                       self_scores: Optional[np.ndarray] = None):
        """[B, K] (+ optional [B] n_valid) -> [B] tier ids.

        The vectorized fast path: one fused kernel call per bucketed batch
        shape. With ``return_details=True`` returns a
        :class:`BatchDispatchResult` carrying per-request records and the
        full metric matrix (the pipeline and telemetry consumers).
        ``self_scores``: optional [B] engine self-uncertainty (higher =
        less confident) some policies (cascade) fold into the decision.
        """
        scores = np.asarray(scores_desc)
        b, k = scores.shape
        bpad = bucket_size(b, BATCH_BUCKETS)
        if bpad != b:
            scores = np.concatenate(
                [scores, np.zeros((bpad - b, k), scores.dtype)])
        # always pass a concrete n_valid so every bucket shape compiles
        # the kernel exactly once (None vs array would be two traces)
        nv = np.full(bpad, k, np.int32)
        if n_valid is not None:
            nv[:b] = np.asarray(n_valid, np.int32)
        nv[b:] = 1  # padded rows: degenerate but well-defined
        with self.obs.tracer.span("dispatch", batch=b):
            obs_on = self.obs.enabled
            t0 = self.obs.clock.now() if obs_on else 0.0
            result: RouteBatchResult = self.backend.route_batch(
                jnp.asarray(scores), self.router, n_valid=jnp.asarray(nv))
            tiers = np.asarray(result.tiers)[:b]
            diff = np.asarray(result.difficulty)[:b]
            metrics = np.asarray(result.metrics)[:b]
            if obs_on:  # np.asarray forced the device sync above
                self._m_dispatch_s.observe(self.obs.clock.now() - t0)

            decision = self.policy.decide(tiers, diff, metrics,
                                          self_scores=self_scores)
            first_id, metric_name, recalibrated = self._record_batch(
                decision.tiers, diff, decision, backend_tiers=tiers)
        if not return_details:
            return decision.tiers
        return BatchDispatchResult(tiers=decision.tiers, difficulty=diff,
                                   metrics=metrics, first_id=first_id,
                                   metric=metric_name,
                                   recalibrated=recalibrated,
                                   request_cost=decision.request_cost,
                                   depths=decision.depths)

    def dispatch_retrieved(self, feats: np.ndarray, query_emb: np.ndarray,
                           scorer_params, n_cand: Optional[np.ndarray] = None
                           ) -> "RetrievedDispatchResult":
        """End-to-end dispatch from candidate features: ONE device program
        (scoring -> top-k -> skew -> decision; see
        `repro.core.router.route_retrieved`) replaces the old
        score-on-device / top-k-on-host / re-enter-device-for-metrics
        staging. Telemetry and streaming calibration update exactly as
        for :meth:`dispatch_batch`.

        ``feats``: [B, N, Dt]; ``query_emb``: [B, Dq]; ``n_cand``:
        optional [B] real candidate counts (ragged retrieval).
        """
        feats = np.asarray(feats)
        b, k_feats, _ = feats.shape
        bpad = bucket_size(b, BATCH_BUCKETS)
        qemb = np.asarray(query_emb)
        nc = np.full(bpad, k_feats, np.int32)
        if n_cand is not None:
            nc[:b] = np.asarray(n_cand, np.int32)
        nc[b:] = 1  # padded rows: degenerate but well-defined
        if not hasattr(self.backend, "route_retrieved"):
            raise TypeError(
                f"difficulty backend {self.backend.name!r} has no "
                f"route_retrieved; end-to-end dispatch needs one of the "
                f"built-in backends (oracle | pallas | fused | auto) or a "
                f"custom backend implementing it")
        if bpad != b:
            feats = np.concatenate(
                [feats, np.zeros((bpad - b,) + feats.shape[1:], feats.dtype)])
            qemb = np.concatenate(
                [qemb, np.zeros((bpad - b, qemb.shape[1]), qemb.dtype)])
        with self.obs.tracer.span("dispatch_retrieved", batch=b):
            obs_on = self.obs.enabled
            t0 = self.obs.clock.now() if obs_on else 0.0
            res = self.backend.route_retrieved(
                jnp.asarray(feats), jnp.asarray(qemb), scorer_params,
                self.router, n_cand=jnp.asarray(nc))
            tiers = np.asarray(res.tiers)[:b]
            diff = np.asarray(res.difficulty)[:b]
            metrics = np.asarray(res.metrics)[:b]
            if obs_on:
                self._m_dispatch_s.observe(self.obs.clock.now() - t0)
            decision = self.policy.decide(tiers, diff, metrics)
            first_id, metric_name, recalibrated = self._record_batch(
                decision.tiers, diff, decision, backend_tiers=tiers)
        nv_out = np.asarray(res.n_valid)[:b]
        probs = np.asarray(res.probs)[:b]
        if decision.depths is not None:
            # Depth-routing: the candidate set each request SHIPS is the
            # routed depth — shrink the valid prefix and zero the probs
            # past it so downstream consumers can't read truncated rows.
            nv_out = np.minimum(nv_out, decision.depths).astype(np.int32)
            probs = np.where(
                np.arange(probs.shape[1])[None, :] < nv_out[:, None],
                probs, 0.0).astype(probs.dtype)
        return RetrievedDispatchResult(
            result=BatchDispatchResult(
                tiers=decision.tiers, difficulty=diff,
                metrics=metrics, first_id=first_id,
                metric=metric_name, recalibrated=recalibrated,
                request_cost=decision.request_cost,
                depths=decision.depths),
            indices=np.asarray(res.indices)[:b],
            probs=probs,
            n_valid=nv_out)

    def _record_batch(self, tiers: np.ndarray, diff: np.ndarray,
                      decision=None, backend_tiers=None
                      ) -> tuple[int, str, bool]:
        """The control-plane half shared by every dispatch entry: request
        ids, tier/cost/difficulty counters, drift-aware recalibration.
        ``backend_tiers`` is the difficulty backend's threshold decision
        (pre-policy) — the trace's ``dispatch`` event carries it so a
        request's timeline shows both halves of the decision."""
        b = len(tiers)
        recalibrated = False
        with self._lock:
            metric_name = self.router.metric
            first_id = self._next_id
            self._next_id += b
            counts = np.bincount(tiers, minlength=self.router.n_tiers)
            total = self.stats.n_requests
            self.stats.n_requests += b
            self.stats.n_batches += 1
            self.stats.mean_difficulty = (
                (self.stats.mean_difficulty * total + float(diff.sum()))
                / max(self.stats.n_requests, 1))
            cost_before = self.stats.total_cost
            if decision is not None and decision.request_cost is not None:
                # The policy priced each request itself (per-stage cascade
                # bills, per-depth prompt lengths) — the ledger takes the
                # decision's word over the flat per-tier price.
                self.stats.total_cost += float(decision.request_cost.sum())
                for t, c in enumerate(counts):
                    if c:
                        self.stats.tier_counts[t] += int(c)
            else:
                for t, c in enumerate(counts):
                    if not c:
                        continue
                    self.stats.tier_counts[t] += int(c)
                    name = self.tier_names[t]
                    if name in self.cost_model.cost_per_mtok:
                        self.stats.total_cost += (
                            self.cost_model.request_cost(name) * int(c))
            # registry mirrors (no-ops under NULL_OBS)
            self._m_requests.inc(b)
            self._m_batches.inc()
            self._m_cost.inc(self.stats.total_cost - cost_before)
            self._m_mean_diff.set(self.stats.mean_difficulty)
            for t, c in enumerate(counts):
                if c:
                    self._m_tiers[t].inc(int(c))
            if self.calibrator is not None:
                new_config = self.calibrator.observe(diff)
                if new_config is not None:
                    self.router = new_config
                    self.stats.n_recalibrations += 1
                    self._m_recal.inc()
                    recalibrated = True
                    # An inline drift swap re-fits the policy from the
                    # window that produced the new thresholds (same rule
                    # as apply_config; we already hold the lock).
                    self._refit_policy_locked()
        if self.obs.enabled:
            # Batch-granularity trace events: one "dispatch" (the
            # backend's threshold tiers) + one "policy" (the final
            # decision) carrying first_id + per-row tiers — the export
            # walker re-expands them into per-request timelines.
            # ndarrays go in raw: the tracer's _jsonable hits the
            # one-shot ndarray->tolist branch instead of walking a
            # python list per element (measured on the 5% overhead gate)
            tr = self.obs.tracer
            bt = tiers if backend_tiers is None else backend_tiers
            tr.event("dispatch", first_id=first_id,
                     tiers=np.asarray(bt), metric=metric_name)
            attrs = {"first_id": first_id, "kind": self.policy.kind,
                     "tiers": np.asarray(tiers)}
            if backend_tiers is not None and \
                    not np.array_equal(bt, tiers):
                attrs["tiers_in"] = np.asarray(bt)  # policy overrode rows
            if decision is not None and decision.info:
                attrs.update(decision.info)
            tr.event("policy", **attrs)
            if recalibrated:
                tr.event("recalibrate", first_id=first_id,
                         thresholds=list(self.router.thresholds))
        return first_id, metric_name, recalibrated
