"""SkewRoute dispatcher: retrieval scores in, tier assignment out.

This is the paper's Algorithm 1 as a serving component. Per request:

  1. the retrieval stage hands over the top-K triple scores (descending);
  2. the fused skew-metrics kernel (or its XLA oracle) computes the
     difficulty metric;
  3. the threshold router picks a tier; telemetry (tier counts, expected
     $ cost, mean difficulty) streams to the stats sink;
  4. the request joins the chosen tier's batch queue
     (serving/scheduler.py).

Thresholds are *hot-swappable*: the calibrator (core/calibrate.py) can
re-fit them to a new traffic budget from any unlabeled sample without
touching the serving path — the training-free property operationalized.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import skewness
from repro.core.calibrate import calibrate_multi_tier
from repro.core.cost import CostModel
from repro.core.router import RouterConfig, route_from_difficulty


@dataclasses.dataclass
class DispatchRecord:
    request_id: int
    tier: int
    difficulty: float
    metric: str


@dataclasses.dataclass
class DispatcherStats:
    n_requests: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)
    total_cost: float = 0.0

    @property
    def large_call_ratio(self) -> float:
        if not self.n_requests:
            return 0.0
        top = max(self.tier_counts) if self.tier_counts else 0
        return self.tier_counts.get(top, 0) / self.n_requests


class SkewRouteDispatcher:
    def __init__(self, router: RouterConfig, tier_names: Sequence[str],
                 cost_model: Optional[CostModel] = None):
        if len(tier_names) != router.n_tiers:
            raise ValueError(f"{router.n_tiers} tiers but "
                             f"{len(tier_names)} tier names")
        self.router = router
        self.tier_names = list(tier_names)
        self.cost_model = cost_model or CostModel()
        self.stats = DispatcherStats(tier_counts={i: 0 for i in
                                                  range(router.n_tiers)})
        self._lock = threading.Lock()
        self._next_id = 0

    def dispatch(self, scores_desc: np.ndarray) -> DispatchRecord:
        """Route one request from its retrieval score vector."""
        diff = float(skewness.difficulty(
            jnp.asarray(scores_desc)[None], metric=self.router.metric,
            p=self.router.cumulative_p)[0])
        tier = int(route_from_difficulty(
            jnp.asarray([diff]), jnp.asarray(self.router.thresholds))[0])
        with self._lock:
            rid = self._next_id
            self._next_id += 1
            self.stats.n_requests += 1
            self.stats.tier_counts[tier] += 1
            name = self.tier_names[tier]
            if name in self.cost_model.cost_per_mtok:
                self.stats.total_cost += self.cost_model.request_cost(name)
        return DispatchRecord(request_id=rid, tier=tier, difficulty=diff,
                              metric=self.router.metric)

    def dispatch_batch(self, scores_desc: np.ndarray) -> np.ndarray:
        """[B, K] -> [B] tier ids (vectorized fast path)."""
        diff = skewness.difficulty(jnp.asarray(scores_desc),
                                   metric=self.router.metric,
                                   p=self.router.cumulative_p)
        tiers = route_from_difficulty(diff, jnp.asarray(self.router.thresholds))
        with self._lock:
            for t in np.asarray(tiers):
                self.stats.n_requests += 1
                self.stats.tier_counts[int(t)] += 1
        return np.asarray(tiers)

    def recalibrate(self, calibration_scores: np.ndarray,
                    tier_shares: Sequence[float]) -> RouterConfig:
        """Hot-swap thresholds to hit new traffic shares (training-free)."""
        new_router = calibrate_multi_tier(
            jnp.asarray(calibration_scores), tier_shares,
            metric=self.router.metric, cumulative_p=self.router.cumulative_p)
        with self._lock:
            self.router = new_router
        return new_router
