"""Deterministic workload simulation for load-aware serving.

``workload`` — seeded trace generators (diurnal rate, Poisson/bursty
arrivals, retrieval-score-skew drift, replica-failure injection) behind
a small JSON trace spec, so the exact same stress trace replays across
PRs and machines.

``runner`` — replays a trace through a :class:`repro.api.SkewRouteSession`
and per-tier :class:`~repro.serving.scheduler.TierScheduler` replica
pools, feeding load probes to the admission controller and recording the
per-step telemetry trajectory (queue depths, thresholds, spill, budget
burn, SLO attainment).
"""

from repro.serving.loadgen.workload import (  # noqa: F401
    CANONICAL_TRACES,
    BurstSpec,
    DriftSpec,
    FailureSpec,
    TraceSpec,
    WorkloadStep,
    canonical_trace,
    generate,
)
from repro.serving.loadgen.runner import (  # noqa: F401
    LoadReport,
    LoadRunner,
    SimRequest,
    canonical_load_runner,
    canonical_policy_spec,
    make_pool_runners,
    make_pools,
)
