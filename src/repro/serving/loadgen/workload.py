"""Seeded, replayable workload traces: the standing serving stress test.

A :class:`TraceSpec` is a small frozen value (JSON-round-trippable, same
discipline as `RouteSpec`) describing a traffic scenario:

* **arrivals** — per-step Poisson draws around a base rate, modulated by
  a diurnal sinusoid and piecewise burst multipliers;
* **drift** — the synthetic retrieval-score *skew* distribution shifts
  over time: each segment draws per-request power-law decay exponents
  from its own ``[alpha_lo, alpha_hi]`` range (flat rows = hard queries,
  spiky rows = easy — the same construction the calibrator tests use),
  so thresholds calibrated on one era walk off target in the next;
* **failures** — replica down/up events at fixed steps, driven into
  ``TierScheduler.mark_unhealthy / mark_healthy`` by the runner.

Everything derives from one `numpy` Generator seeded from the spec and
consumed in a fixed order, so the same spec JSON yields bit-identical
score batches anywhere — a trace IS a regression test.

Trace spec JSON schema (all fields optional except name/steps):

    {"name": "bursty", "seed": 7, "steps": 400, "dt": 0.05,
     "top_k": 100, "base_rate": 6.0, "max_batch": 256,
     "diurnal_amplitude": 0.3, "diurnal_period": 200.0,
     "bursts":   [{"start": 120, "length": 80, "multiplier": 4.0}],
     "drift":    [{"start": 0,   "alpha_lo": 1.0, "alpha_hi": 2.5},
                  {"start": 200, "alpha_lo": 0.1, "alpha_hi": 0.9}],
     "failures": [{"tier": 1, "replica": 0, "down_at": 150,
                   "up_at": 260, "speed": 0.35}]}
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Iterator, Mapping, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class BurstSpec:
    """Arrival-rate multiplier over ``[start, start + length)`` steps."""

    start: int
    length: int
    multiplier: float

    def __post_init__(self):
        if self.start < 0 or self.length < 1:
            raise ValueError(f"burst needs start >= 0 and length >= 1, got "
                             f"start={self.start}, length={self.length}")
        if self.multiplier <= 0:
            raise ValueError(f"burst multiplier must be > 0, got "
                             f"{self.multiplier}")

    def active(self, step: int) -> bool:
        return self.start <= step < self.start + self.length


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """From ``start`` on, score rows decay with alpha ~ U[lo, hi].
    Smaller alphas = flatter score curves = harder queries."""

    start: int
    alpha_lo: float
    alpha_hi: float

    def __post_init__(self):
        if self.start < 0:
            raise ValueError(f"drift start must be >= 0, got {self.start}")
        if not 0 < self.alpha_lo <= self.alpha_hi:
            raise ValueError(f"drift needs 0 < alpha_lo <= alpha_hi, got "
                             f"[{self.alpha_lo}, {self.alpha_hi}]")


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """Replica ``replica`` of tier ``tier`` goes down at step ``down_at``
    and recovers (at ``speed``) at step ``up_at``."""

    tier: int
    replica: int
    down_at: int
    up_at: int
    speed: float = 1.0

    def __post_init__(self):
        if self.tier < 0 or self.replica < 0:
            raise ValueError("failure tier/replica must be >= 0")
        if not 0 <= self.down_at < self.up_at:
            raise ValueError(f"failure needs 0 <= down_at < up_at, got "
                             f"down_at={self.down_at}, up_at={self.up_at}")
        if self.speed <= 0:
            raise ValueError(f"recovery speed must be > 0, got {self.speed}")


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """One serving scenario as a frozen, seeded, JSON-serializable value."""

    name: str
    steps: int
    seed: int = 0
    dt: float = 0.05            # simulated seconds per step
    top_k: int = 100            # retrieval depth of the score rows
    base_rate: float = 8.0      # mean arrivals per step (Poisson)
    max_batch: int = 256        # arrivals-per-step cap (bounds memory)
    diurnal_amplitude: float = 0.0   # rate *= 1 + A sin(2π step / period)
    diurnal_period: Optional[float] = None
    bursts: tuple[BurstSpec, ...] = ()
    drift: tuple[DriftSpec, ...] = (DriftSpec(0, 0.2, 2.5),)
    failures: tuple[FailureSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "bursts", tuple(self.bursts))
        object.__setattr__(self, "drift", tuple(self.drift))
        object.__setattr__(self, "failures", tuple(self.failures))
        if self.steps < 1:
            raise ValueError(f"steps must be >= 1, got {self.steps}")
        if self.dt <= 0:
            raise ValueError(f"dt must be > 0, got {self.dt}")
        if self.top_k < 2:
            raise ValueError(f"top_k must be >= 2, got {self.top_k}")
        if self.base_rate < 0:
            raise ValueError(f"base_rate must be >= 0, got {self.base_rate}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError(f"diurnal_amplitude must be in [0, 1), got "
                             f"{self.diurnal_amplitude}")
        if self.diurnal_amplitude > 0 and (self.diurnal_period is None
                                           or self.diurnal_period <= 0):
            raise ValueError("diurnal_amplitude > 0 needs a positive "
                             "diurnal_period")
        if not self.drift:
            raise ValueError("at least one drift segment is required")
        starts = [seg.start for seg in self.drift]
        if starts != sorted(starts) or starts[0] != 0:
            raise ValueError(f"drift segments must be sorted by start and "
                             f"begin at step 0, got starts {starts}")

    # -- the deterministic schedule -------------------------------------------

    def rate(self, step: int) -> float:
        """Mean arrivals at ``step``: base x diurnal x active bursts."""
        r = self.base_rate
        if self.diurnal_amplitude > 0:
            r *= 1.0 + self.diurnal_amplitude * math.sin(
                2.0 * math.pi * step / self.diurnal_period)
        for burst in self.bursts:
            if burst.active(step):
                r *= burst.multiplier
        return r

    def drift_segment(self, step: int) -> DriftSpec:
        seg = self.drift[0]
        for candidate in self.drift:
            if candidate.start <= step:
                seg = candidate
        return seg

    # -- serialization --------------------------------------------------------

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "TraceSpec":
        d = dict(d)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown TraceSpec fields {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        for key, sub in (("bursts", BurstSpec), ("drift", DriftSpec),
                         ("failures", FailureSpec)):
            if d.get(key) is not None:
                d[key] = tuple(x if isinstance(x, sub) else sub(**dict(x))
                               for x in d[key])
        return cls(**d)

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.to_dict(), **kwargs)

    @classmethod
    def from_json(cls, payload: str) -> "TraceSpec":
        return cls.from_dict(json.loads(payload))


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """A health transition the runner must apply at this step."""

    tier: int
    replica: int
    kind: str           # "down" | "up"
    speed: float = 1.0


@dataclasses.dataclass(frozen=True)
class WorkloadStep:
    """One simulator tick: the arrivals' score rows + failure events."""

    step: int
    time: float
    scores: np.ndarray            # [n, top_k] descending float32
    events: tuple[FailureEvent, ...] = ()

    @property
    def n_arrivals(self) -> int:
        return int(self.scores.shape[0])


def _power_law_scores(rng: np.random.Generator, n: int, k: int,
                      alpha_lo: float, alpha_hi: float) -> np.ndarray:
    """Synthetic descending top-K retrieval scores: per-row power-law
    decay with alpha ~ U[lo, hi] plus 5% multiplicative noise (the
    construction shared with the calibrator tests — flat rows are
    'hard', spiky rows 'easy')."""
    if n == 0:
        return np.empty((0, k), np.float32)
    alphas = rng.uniform(alpha_lo, alpha_hi, n)
    base = 1.0 / np.arange(1, k + 1)[None, :] ** alphas[:, None]
    noise = rng.uniform(0.95, 1.05, (n, k))
    return np.sort((base * noise).astype(np.float32),
                   axis=1)[:, ::-1].copy()


def generate(spec: TraceSpec) -> Iterator[WorkloadStep]:
    """Replay ``spec`` deterministically: one Generator seeded from the
    spec, consumed in fixed (arrival-count, then scores) order per step —
    same spec, same platform-independent stream of batches."""
    rng = np.random.default_rng(spec.seed)
    events_at: dict[int, list[FailureEvent]] = {}
    for f in spec.failures:
        events_at.setdefault(f.down_at, []).append(
            FailureEvent(f.tier, f.replica, "down"))
        events_at.setdefault(f.up_at, []).append(
            FailureEvent(f.tier, f.replica, "up", speed=f.speed))
    for step in range(spec.steps):
        n = min(int(rng.poisson(spec.rate(step))), spec.max_batch)
        seg = spec.drift_segment(step)
        scores = _power_law_scores(rng, n, spec.top_k,
                                   seg.alpha_lo, seg.alpha_hi)
        yield WorkloadStep(step=step, time=step * spec.dt, scores=scores,
                           events=tuple(events_at.get(step, ())))


# -- canonical traces (the standing stress tests; referenced by name from
#    benchmarks/load_sim_bench.py, CI, tests, and the example) ----------------

CANONICAL_TRACES: dict[str, TraceSpec] = {
    # THE acceptance trace: easy-era calibration, then a 4x burst landing
    # together with a hard-shift drift AND a large-tier replica failure —
    # the expensive tier saturates unless admission reacts.
    "bursty_drift_saturation": TraceSpec(
        name="bursty_drift_saturation", seed=7, steps=400, dt=0.05,
        top_k=100, base_rate=6.0, max_batch=192,
        diurnal_amplitude=0.3, diurnal_period=200.0,
        bursts=(BurstSpec(start=120, length=120, multiplier=4.0),),
        drift=(DriftSpec(0, 1.0, 2.5), DriftSpec(140, 0.1, 0.9)),
        failures=(FailureSpec(tier=1, replica=0, down_at=150, up_at=280,
                              speed=0.35),)),
    # A day in fifty seconds: smooth diurnal swing, no shocks — the
    # "does the controller stay quiet when nothing is wrong" trace.
    "diurnal_calm": TraceSpec(
        name="diurnal_calm", seed=11, steps=300, dt=0.05, top_k=100,
        base_rate=5.0, diurnal_amplitude=0.5, diurnal_period=150.0,
        drift=(DriftSpec(0, 0.8, 2.2),)),
    # CI-sized cut of the acceptance trace: same shape, ~4x shorter.
    "smoke": TraceSpec(
        name="smoke", seed=7, steps=120, dt=0.05, top_k=50,
        base_rate=5.0, max_batch=96,
        bursts=(BurstSpec(start=30, length=50, multiplier=4.0),),
        drift=(DriftSpec(0, 1.0, 2.5), DriftSpec(40, 0.1, 0.9)),
        failures=(FailureSpec(tier=1, replica=0, down_at=40, up_at=90,
                              speed=0.35),)),
}


def canonical_trace(name: str) -> TraceSpec:
    try:
        return CANONICAL_TRACES[name]
    except KeyError:
        raise KeyError(f"unknown canonical trace {name!r}; choose from "
                       f"{sorted(CANONICAL_TRACES)}") from None
