"""Trace replay: a `TraceSpec` driven through a `SkewRouteSession` and
per-tier `TierScheduler` replica pools, end to end.

Per simulator tick the runner

1. applies the trace's failure events to the replica pools
   (``mark_unhealthy`` / ``mark_healthy``);
2. feeds each pool's load probes (waiting depth, nan-safe p99) to the
   session's admission controller, when one is attached;
3. routes the tick's arrivals through ``session.submit`` — dispatch,
   admission control-step, spill, micro-batch queues — with the tier
   runners landing requests on the pools (``make_pool_runners``), then
   flushes partial micro-batches so queueing delay stays bounded by one
   tick;
4. advances every pool's simulated clock;
5. records one telemetry row: arrivals, per-tier queue depth, live
   thresholds, spill/pressure/budget state — the trajectory the bench
   plots and the tests assert on.

After the trace the pools drain to empty and the run folds into a
:class:`LoadReport` (JSON-friendly): SLO attainment, realized $/query
over the *executed* tier mix, expensive-tier shares (decision vs
executed), a share-weighted quality proxy, spill/recalibration/failure
counters, and the full per-step trajectory.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.cost import PAPER_QUALITY, TOKENS_BARE_QUESTION
from repro.obs import NULL_OBS
from repro.serving.loadgen.workload import TraceSpec, generate
from repro.serving.scheduler import Replica, Request, TierScheduler


@dataclasses.dataclass(frozen=True)
class SimRequest:
    """The payload flowing through the micro-batch queues: identity plus
    the timing contract (latency is measured submitted -> finished)."""

    request_id: int
    submitted_at: float
    deadline: float
    prompt_len: int = 1873      # paper Fig 2a: KG-RAG prompt, 100 triples
    max_new: int = 120


def make_pools(replica_speeds: Mapping[int, Sequence[float]],
               batch_slots: Optional[Mapping[int, int]] = None,
               base_token_time: float = 0.01) -> dict[int, TierScheduler]:
    """Replica pools from {tier: [per-replica speed multipliers]}."""
    slots = batch_slots or {}
    return {
        int(t): TierScheduler(
            int(t), [Replica(i, int(t), speed=float(s))
                     for i, s in enumerate(speeds)],
            batch_slots=int(slots.get(t, 8)),
            base_token_time=base_token_time)
        for t, speeds in replica_speeds.items()}


def apply_tier_topology(pools: Mapping[int, TierScheduler],
                        topology: Optional[Mapping]) -> None:
    """Stamp each pool with its execution mode from a policy's
    ``tier_topology()`` (``mode_select``): ``pools[t].mode`` becomes the
    tier's mode string. Pool runners read the mode at CALL time, so a
    ``no_rag`` tier's requests carry the bare-question prompt length
    instead of the full KG-RAG context — before this, depth-0 requests
    still transited the pool priced as 100-triple prompts."""
    if not topology:
        return
    modes = topology.get("modes") or []
    for t, mode in enumerate(modes):
        if t in pools:
            pools[t].mode = str(mode)


def make_pool_runners(pools: Mapping[int, TierScheduler]):
    """{tier: runner} for ``repro.api.build(spec, runners=...)``: each
    micro-batch of :class:`SimRequest` payloads becomes scheduler
    Requests admitted to that tier's replica pool.

    Runners are MODE-AWARE: a pool stamped ``no_rag`` (see
    :func:`apply_tier_topology`) admits requests at the bare-question
    prompt length — no retrieval context is shipped, so none is decoded.
    The mode is read per call, so topology applied after runner
    construction still takes effect."""
    def _make(tier: int):
        def run(batch: list) -> list[Request]:
            no_rag = getattr(pools[tier], "mode", "kg_rag") == "no_rag"
            reqs = [Request(request_id=p.request_id, tier=tier,
                            prompt_len=(TOKENS_BARE_QUESTION if no_rag
                                        else p.prompt_len),
                            max_new=p.max_new,
                            deadline=p.deadline,
                            submitted_at=p.submitted_at)
                    for p in batch]
            pools[tier].submit_batch(reqs)
            return reqs
        return run
    return {t: _make(t) for t in pools}


@dataclasses.dataclass
class LoadReport:
    """One trace replay: the spec, per-step trajectory, and summary."""

    trace: dict
    steps: list[dict]
    summary: dict

    def to_dict(self) -> dict:
        return {"trace": self.trace, "steps": self.steps,
                "summary": self.summary}


def _default_tier_quality(models: Sequence[str]) -> tuple[float, ...]:
    """Quality proxy per tier: paper Table-3 CWQ F1 where the tier model
    is a paper model id, else an index-proportional stand-in — only the
    ORDERING and spread matter (the proxy weights executed shares)."""
    table = PAPER_QUALITY["cwq"]
    return tuple(
        float(table[m]["f1"]) if m in table else 40.0 + 10.0 * (i + 1)
        for i, m in enumerate(models))


def canonical_policy_spec(policy: Optional[str], top_k: int):
    """The canonical per-policy :class:`~repro.policies.PolicySpec` used
    by ``canonical_load_runner`` and the examples' ``--policy`` flags —
    one tuned configuration per registered strategy so every harness
    stresses the same thing. ``None``/``"threshold"`` -> ``None`` (the
    default threshold policy, bit-for-bit pre-policy routing)."""
    from repro.api import (AdaptiveDepthPolicySpec,  # lazy: keep the
                           CascadePolicySpec,        # serving -> api
                           ModeSelectPolicySpec)     # edge soft
    if policy in (None, "threshold"):
        return None
    if policy == "cascade":
        return CascadePolicySpec(escalation_cutoffs=(6.0,),
                                 escalation_quantiles=(0.7,))
    if policy == "adaptive_depth":
        opts = tuple(sorted({max(1, top_k // 4), max(2, top_k // 2),
                             top_k}))
        return AdaptiveDepthPolicySpec(
            depth_options=opts,
            depth_cutoffs=tuple(5.0 + 1.5 * i
                                for i in range(len(opts) - 1)),
            depth_quantiles=tuple(
                (i + 1) / len(opts) for i in range(len(opts) - 1)))
    if policy == "mode_select":
        return ModeSelectPolicySpec(
            modes=("no_rag", "kg_rag", "kg_rag"))
    raise ValueError(f"unknown canonical policy {policy!r}; choose from "
                     f"(threshold, cascade, adaptive_depth, mode_select)")


def canonical_load_runner(with_admission: bool, trace: TraceSpec,
                          slo_latency: float = 1.0,
                          base_token_time: float = 8e-5,
                          record_every: int = 1,
                          policy: Optional[str] = None,
                          obs=None) -> "LoadRunner":
    """The tuned serving setup the canonical traces are stressed against
    (shared by benchmarks/load_sim_bench.py, CI, tests, and the example
    so they all measure the same thing):

    * 2 tiers, qwen7b/qwen72b paper pricing, entropy metric, streaming
      calibration at a 70/30 split;
    * cheap tier provisioned with real headroom (8 replicas at 2x) —
      spill only helps when there is somewhere to spill TO; expensive
      tier sized for the calm era (3 replicas at 0.5x), so the
      burst+drift eras saturate it;
    * admission (when on): $3e-4/query budget — binding once drift
      pushes traffic up-tier — and queue/p99 SLO pressure with
      hysteresis spill.

    ``policy`` selects a routing policy by canonical name
    (:func:`canonical_policy_spec`). ``mode_select`` routes a THREE-tier
    topology (no-RAG qwen7b / KG-RAG qwen14b / KG-RAG qwen72b) with a
    mid-sized middle pool; every other policy keeps the 2-tier setup.

    ``obs`` (an :class:`~repro.obs.Observability`) threads the unified
    observability plane through the whole replay: dispatch/policy/spill/
    execute trace events from the session plus the runner's completion
    events, so one replay yields a full per-request timeline.
    """
    from repro.api import (AdmissionSpec, CalibrationSpec,  # lazy: keep
                           RouteSpec, build)  # serving -> api edge soft
    admission = AdmissionSpec(
        cost_budget_per_query=3e-4, p99_slo=slo_latency,
        p99_horizon=5.0 * slo_latency,  # explicit: serializes with policy
        queue_depth_slo=24, control_interval=32,
        spill_on=1.0, spill_off=0.5) if with_admission else None
    policy_spec = canonical_policy_spec(policy, trace.top_k)
    if policy == "mode_select":
        tier_names = ("qwen7b", "qwen14b", "qwen72b")
        thresholds = (5.0, 6.5)
        target_shares = (0.4, 0.35, 0.25)
        speeds = {0: [2.0] * 8, 1: [1.0] * 4, 2: [0.5] * 3}
        slots = {0: 32, 1: 16, 2: 8}
    else:
        tier_names = ("qwen7b", "qwen72b")
        thresholds = (6.0,)
        target_shares = (0.7, 0.3)
        speeds = {0: [2.0] * 8, 1: [0.5] * 3}
        slots = {0: 32, 1: 8}
    spec = RouteSpec(
        metric="entropy", thresholds=thresholds, top_k=trace.top_k,
        tier_names=tier_names,
        calibration=CalibrationSpec(
            policy="streaming", target_shares=target_shares, window=512,
            min_samples=64, tolerance=0.08, cooldown=128),
        admission=admission,
        policy=policy_spec)
    pools = make_pools(speeds, batch_slots=slots,
                       base_token_time=base_token_time)
    session = build(spec, runners=make_pool_runners(pools), obs=obs)
    return LoadRunner(session, pools, slo_latency=slo_latency,
                      record_every=record_every)


class LoadRunner:
    """Replays traces through one session + replica-pool topology."""

    def __init__(self, session, pools: Mapping[int, TierScheduler],
                 slo_latency: float = 30.0,
                 tier_quality: Optional[Sequence[float]] = None,
                 record_every: int = 1,
                 p99_horizon: Optional[float] = None):
        tiers = set(range(session.spec.n_tiers))
        if set(pools) != tiers:
            raise ValueError(f"pools for tiers {sorted(pools)} but the "
                             f"session routes tiers {sorted(tiers)}")
        if session.pipeline is None:
            raise ValueError("session has no pipeline; build it with "
                             "runners=make_pool_runners(pools)")
        if slo_latency <= 0:
            raise ValueError(f"slo_latency must be > 0, got {slo_latency}")
        if record_every < 1:
            raise ValueError(f"record_every must be >= 1, "
                             f"got {record_every}")
        self.session = session
        self.pools = dict(pools)
        self.slo_latency = float(slo_latency)
        models = session.spec.models()
        self.tier_quality = tuple(
            float(q) for q in (tier_quality if tier_quality is not None
                               else _default_tier_quality(models)))
        if len(self.tier_quality) != len(models):
            raise ValueError(f"{len(models)} tiers but "
                             f"{len(self.tier_quality)} tier_quality values")
        self.record_every = int(record_every)
        # Latency-pressure probes only look this far back: an SLO
        # controller needs the current tail, and a tier that went quiet
        # after tightening would otherwise show its burst-era p99
        # forever. The horizon is POLICY (AdmissionSpec.p99_horizon —
        # every replica must judge pressure over the same lookback); the
        # ctor arg only overrides it for ad-hoc experiments, and the
        # 5x-SLO default covers sessions without admission control.
        adm = getattr(session.spec, "admission", None)
        if p99_horizon is None and adm is not None:
            p99_horizon = adm.p99_horizon
        self.p99_horizon = (float(p99_horizon) if p99_horizon is not None
                            else 5.0 * self.slo_latency)
        self._next_id = 0
        # Mode topology: a policy that distinguishes execution modes
        # (mode_select) stamps each pool, so no_rag tiers serve
        # bare-question prompts (make_pool_runners reads pool.mode).
        policy = getattr(session, "policy", None)
        topo = getattr(policy, "tier_topology", None)
        apply_tier_topology(self.pools, topo() if callable(topo) else None)
        # Observability rides the session's plane (NULL_OBS when the
        # session was built without one — every instrument is a no-op).
        self.obs = getattr(session, "obs", None) or NULL_OBS
        mx = self.obs.metrics
        lat_buckets = tuple(
            self.slo_latency * f
            for f in (0.1, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0, 8.0))
        self._m_completed = {
            t: mx.counter("load_completed_total", tier=str(t))
            for t in self.pools}
        self._h_latency = {
            t: mx.histogram("load_completion_seconds", lat_buckets,
                            tier=str(t))
            for t in self.pools}
        self._g_queue = {
            t: mx.gauge("load_queue_depth", tier=str(t))
            for t in self.pools}

    # -- per-tick pieces -------------------------------------------------------

    def _apply_events(self, events, now: float) -> list[dict]:
        applied = []
        for ev in events:
            pool = self.pools[ev.tier]
            if ev.kind == "down":
                pool.mark_unhealthy(ev.replica)
            else:
                pool.mark_healthy(ev.replica, speed=ev.speed)
            applied.append({"time": now, "tier": ev.tier,
                            "replica": ev.replica, "kind": ev.kind})
        return applied

    def _feed_load_probes(self) -> None:
        if getattr(self.session, "admission", None) is None:
            return
        for t, pool in self.pools.items():
            self.session.observe_tier_load(
                t, pool.queue_depth(),
                p99_latency=pool.p99_latency(horizon=self.p99_horizon))

    def _step_pools(self, now: float) -> None:
        """Advance every pool one tick; fold completions into the obs
        plane — latency histograms, completion counters, and one
        ``complete`` trace event per (tier, tick) batch."""
        obs_on = self.obs.enabled
        for t, pool in self.pools.items():
            completed = pool.step(now)
            if not obs_on:
                continue
            self._g_queue[t].set(pool.queue_depth())
            if not completed:
                continue
            self._m_completed[t].inc(len(completed))
            lats = [float(r.finished_at - r.submitted_at)
                    for r in completed]
            for lat in lats:
                self._h_latency[t].observe(lat)
            self.obs.tracer.event(
                "complete", tier=t,
                request_ids=[int(r.request_id) for r in completed],
                latencies=[round(l, 9) for l in lats])

    def _record_step(self, wstep, now: float) -> dict:
        adm = getattr(self.session, "admission", None)
        row = {
            "step": wstep.step,
            "time": now,
            "arrivals": wstep.n_arrivals,
            "queue_depths": {str(t): p.queue_depth()
                             for t, p in self.pools.items()},
            "inflight": {str(t): len(p.inflight)
                         for t, p in self.pools.items()},
            "thresholds": [float(x) for x in self.session.thresholds],
        }
        if adm is not None:
            row.update(spill_active=adm.spill_active,
                       pressure=round(adm.pressure, 6),
                       n_spilled=adm.n_spilled,
                       cost_per_query=adm.cost_per_query,
                       target_shares=list(adm.shares))
        return row

    # -- the replay ------------------------------------------------------------

    def run(self, spec: TraceSpec) -> LoadReport:
        steps: list[dict] = []
        failure_log: list[dict] = []
        n_arrivals = 0
        now = 0.0
        for wstep in generate(spec):
            now = wstep.time
            failure_log.extend(self._apply_events(wstep.events, now))
            self._feed_load_probes()
            n = wstep.n_arrivals
            if n:
                payloads = [
                    SimRequest(request_id=self._next_id + i,
                               submitted_at=now,
                               deadline=now + self.slo_latency)
                    for i in range(n)]
                self._next_id += n
                n_arrivals += n
                self.session.submit(wstep.scores, payloads)
                # bound micro-batch queueing delay to one tick
                self.session.flush()
            self._step_pools(now)
            if wstep.step % self.record_every == 0:
                steps.append(self._record_step(wstep, now))
        self.session.flush()
        now = self._drain(now, spec.dt)
        return LoadReport(trace=spec.to_dict(), steps=steps,
                          summary=self._summary(n_arrivals, now,
                                                failure_log))

    def _drain(self, now: float, dt: float, max_iters: int = 100000) -> float:
        for _ in range(max_iters):
            if not any(p.pending or p.inflight for p in self.pools.values()):
                return now
            now += max(dt, 0.05)
            self._step_pools(now)
        raise RuntimeError(
            "replica pools failed to drain (a replica left unhealthy "
            "forever, or work outpaces capacity unboundedly)")

    def _summary(self, n_arrivals: int, end_time: float,
                 failure_log: list[dict]) -> dict:
        done = [r for p in self.pools.values() for r in p.done]
        lats = np.asarray([r.finished_at - r.submitted_at for r in done
                           if r.finished_at is not None])
        slo_ok = int((lats <= self.slo_latency).sum()) if lats.size else 0
        pipe = self.session.pipeline.telemetry
        executed = {int(t): int(c) for t, c in pipe.tier_counts.items()}
        n_exec = max(sum(executed.values()), 1)
        models = self.session.spec.models()
        cost_model = self.session.spec.cost_model()
        cost_total = sum(
            (cost_model.request_cost(models[t])
             if models[t] in cost_model.cost_per_mtok else 0.0) * c
            for t, c in executed.items())
        top = len(models) - 1
        decisions = self.session.stats.tier_counts
        adm = getattr(self.session, "admission", None)
        summary = {
            "n_arrivals": n_arrivals,
            "n_completed": len(done),
            "end_time": end_time,
            "slo_latency": self.slo_latency,
            # completed-but-late AND never-completed both count as misses
            "slo_attainment": slo_ok / max(n_arrivals, 1),
            "latency_mean": float(lats.mean()) if lats.size else math.nan,
            "latency_p99": (float(np.percentile(lats, 99))
                            if lats.size else math.nan),
            "cost_per_query": cost_total / n_exec,
            "quality_proxy": sum(self.tier_quality[t] * c
                                 for t, c in executed.items()) / n_exec,
            "expensive_share_executed": executed.get(top, 0) / n_exec,
            "expensive_share_decision": (
                decisions.get(top, 0) / max(sum(decisions.values()), 1)),
            "tier_counts_executed": {str(t): c for t, c in executed.items()},
            "n_spilled": pipe.n_spilled,
            "n_recalibrations": self.session.stats.n_recalibrations,
            "n_redispatched": sum(1 for r in done if r.redispatched),
            "failures": failure_log,
            "tier_p99": {str(t): p.p99_latency()
                         for t, p in self.pools.items()},
        }
        if any(getattr(p, "mode", "kg_rag") != "kg_rag"
               for p in self.pools.values()):
            summary["tier_modes"] = {
                str(t): p.mode for t, p in self.pools.items()}
        if adm is not None:
            summary["admission"] = adm.telemetry()
        policy = getattr(self.session, "policy", None)
        if policy is not None:
            summary["policy"] = policy.telemetry()
        return summary
