"""Serving substrate: multi-tier LM engine, continuous-batching scheduler,
and the SkewRoute dispatcher that ties retrieval skewness to tier choice."""
