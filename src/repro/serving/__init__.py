"""Serving substrate: multi-tier LM engine bank, continuous-batching
scheduler with per-tier micro-batch queues, the SkewRoute dispatcher
running the fused skew-metrics fast path, and the pipeline wiring
dispatch → queues → engines → streaming recalibration together."""

from repro.serving.admission import (  # noqa: F401
    AdmissionController,
    AdmissionSpec,
)
from repro.serving.fabric import (  # noqa: F401
    ReplicaFabric,
)
from repro.serving.pipeline import (  # noqa: F401
    ExecutedBatch,
    PipelineTelemetry,
    ServingPipeline,
)
from repro.serving.router_service import (  # noqa: F401
    BatchDispatchResult,
    DispatchRecord,
    DispatcherStats,
    SkewRouteDispatcher,
)
from repro.serving.scheduler import (  # noqa: F401
    MicroBatchQueue,
    Replica,
    Request,
    TierScheduler,
)
