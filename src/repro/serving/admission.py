"""Cost-budget admission control with SLO-aware tier-spill.

The routing policy so far reacts only to the *difficulty distribution*
(the streaming calibrator keeps tier shares on target under drift). A
production router must also react to *load*: budget burn walking past
the spend ceiling, the expensive tier's replica pool saturating, p99
blowing through the SLO. This module closes that loop — the three-way
cost/quality/latency tension from "Cost-Aware Query Routing in RAG"
(PAPERS.md) as a feedback controller around the existing training-free
machinery:

* **Budget loop** (slow, structural): an EWMA of realized $/query
  (:class:`~repro.core.cost.CostModel` pricing over *executed* tiers) is
  compared against ``cost_budget_per_query``. Over budget ⇒ *tighten*
  the routing quantiles: shrink the expensive tier's target share,
  re-fit thresholds from the streaming calibrator's window, and hot-swap
  through the existing threshold-swap path
  (:meth:`~repro.serving.router_service.SkewRouteDispatcher.apply_config`).
  Under budget with pressure off ⇒ *relax* back toward the spec's
  baseline shares. Mutating the calibrator's ``target_shares`` (rather
  than fighting its swaps) keeps the two controllers convergent: drift
  refits now aim at the admission-adjusted shares.

* **Spill loop** (fast, reversible): sustained expensive-tier
  saturation — queue depth or p99 pressure above ``spill_on`` — engages
  *tier-spill*: requests routed to the top tier whose difficulty sits in
  the *marginal band* just above the threshold (the ``spill_margin``
  quantile slice of the calibrator window) are demoted one tier.
  Genuinely hard requests keep the big model; only near-threshold calls
  — where the paper's Fig. 3 quality gap is smallest — trade quality for
  latency. Hysteresis (``spill_off < spill_on`` on a smoothed pressure
  signal) makes the spill state sticky, so a burst tail doesn't flap it.

Everything is deterministic, host-side, and JSON-serializable
(``state_dict``/``load_state_dict`` ride in ``session.snapshot()``), so
a replica restored from bytes resumes mid-spill with the same shares.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Optional, Sequence

import numpy as np

from repro.core.cost import CostModel
from repro.core.router import RouterConfig
from repro.core.streaming_calibrate import StreamingCalibrator
from repro.obs import NULL_OBS, str_keyed


@dataclasses.dataclass(frozen=True)
class AdmissionSpec:
    """Admission-control policy knobs (frozen, JSON-round-trippable —
    rides inside :class:`repro.api.RouteSpec`).

    Pressure is a unitless saturation signal per tier:
    ``max(queue_depth / queue_depth_slo, p99 / p99_slo)``, smoothed by
    an EWMA with weight ``pressure_beta`` on the newest sample. 1.0
    means "exactly at the configured limit". The MOST EXPENSIVE tier's
    pressure drives the tighten/relax loop and engages spill; every
    spillable tier (1..top) keeps its own hysteresis flag so demotions
    cascade PAST a saturated middle tier instead of piling onto it.
    """

    cost_budget_per_query: Optional[float] = None  # $/query ceiling
    p99_slo: Optional[float] = None                # seconds; None = ignore
    # Recency horizon of the p99 probe (seconds): load reporters only
    # quote completions this far back, so a tier that went quiet after
    # tightening doesn't show its burst-era p99 forever. Policy, not a
    # runner knob — it serializes with the spec so every replica judges
    # pressure over the same lookback. None = the reporter's default
    # (LoadRunner uses 5x its slo_latency).
    p99_horizon: Optional[float] = None
    queue_depth_slo: int = 64       # top-tier waiting depth = pressure 1.0
    spill_on: float = 1.0           # smoothed pressure that ENGAGES spill
    spill_off: float = 0.6          # ... and DISENGAGES it (hysteresis)
    spill_margin: float = 0.10      # quantile band above the top cut that
                                    # counts as "marginal" (spillable)
    tighten_step: float = 0.05      # top-tier share removed per tighten
    relax_step: float = 0.05        # ... restored per relax
    deadband: float = 0.05          # budget ratio slack around 1.0
    min_top_share: float = 0.02     # tighten floor: never starve the top
    control_interval: int = 64      # requests between quantile actions
    pressure_beta: float = 0.3      # EWMA weight of the newest sample

    def __post_init__(self):
        if (self.cost_budget_per_query is not None
                and self.cost_budget_per_query <= 0):
            raise ValueError(f"cost_budget_per_query must be > 0, got "
                             f"{self.cost_budget_per_query}")
        if self.p99_slo is not None and self.p99_slo <= 0:
            raise ValueError(f"p99_slo must be > 0, got {self.p99_slo}")
        if self.p99_horizon is not None:
            if self.p99_horizon <= 0:
                raise ValueError(f"p99_horizon must be > 0, got "
                                 f"{self.p99_horizon}")
            if self.p99_slo is not None and self.p99_horizon < self.p99_slo:
                raise ValueError(
                    f"p99_horizon ({self.p99_horizon}) < p99_slo "
                    f"({self.p99_slo}): a lookback shorter than the SLO "
                    f"cannot even contain one SLO-length completion, so "
                    f"the latency probe would never see a breach")
        if self.queue_depth_slo < 1:
            raise ValueError(f"queue_depth_slo must be >= 1, got "
                             f"{self.queue_depth_slo}")
        if not 0.0 < self.spill_off < self.spill_on:
            raise ValueError(
                f"hysteresis needs 0 < spill_off < spill_on, got "
                f"spill_off={self.spill_off}, spill_on={self.spill_on}")
        if not 0.0 < self.spill_margin < 1.0:
            raise ValueError(f"spill_margin must be in (0, 1), got "
                             f"{self.spill_margin}")
        for name in ("tighten_step", "relax_step"):
            v = getattr(self, name)
            if not 0.0 < v < 1.0:
                raise ValueError(f"{name} must be in (0, 1), got {v}")
        if not 0.0 <= self.deadband < 1.0:
            raise ValueError(f"deadband must be in [0, 1), got "
                             f"{self.deadband}")
        if not 0.0 <= self.min_top_share < 1.0:
            raise ValueError(f"min_top_share must be in [0, 1), got "
                             f"{self.min_top_share}")
        if self.control_interval < 1:
            raise ValueError(f"control_interval must be >= 1, got "
                             f"{self.control_interval}")
        if not 0.0 < self.pressure_beta <= 1.0:
            raise ValueError(f"pressure_beta must be in (0, 1], got "
                             f"{self.pressure_beta}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "AdmissionSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown AdmissionSpec fields "
                             f"{sorted(unknown)}; known: {sorted(known)}")
        return cls(**dict(d))


def _finite(x) -> Optional[float]:
    """None / nan / inf -> None (the 'no signal' value)."""
    if x is None:
        return None
    x = float(x)
    return x if math.isfinite(x) else None


class AdmissionController:
    """The load-feedback loop wrapped around a StreamingCalibrator.

    Lifecycle per dispatched batch (driven by
    :class:`~repro.serving.pipeline.ServingPipeline`):

    1. whoever owns the replica pools feeds load probes via
       :meth:`observe_tier_load` (queue depth + p99; nan-safe);
    2. :meth:`control_step` updates the smoothed pressure, toggles spill
       with hysteresis, and — rate-limited by ``control_interval`` —
       tightens/relaxes the target shares, returning a re-fit
       :class:`RouterConfig` for the caller to hot-swap (or ``None``);
    3. :meth:`apply` demotes this batch's marginal top-tier requests
       while spill is engaged and folds the *executed* tier mix into the
       $/query EWMA the budget loop watches.

    The controller never swaps thresholds itself: it returns configs, the
    dispatcher's ``apply_config`` is the one swap path (same as drift).
    """

    def __init__(self, calibrator: StreamingCalibrator,
                 cost_model: CostModel, tier_models: Sequence[str],
                 spec: AdmissionSpec, obs=None):
        if calibrator is None:
            raise ValueError("admission control needs a streaming "
                             "calibrator (its window is the quantile "
                             "source for re-fits and the marginal band)")
        self.calibrator = calibrator
        self.cost_model = cost_model
        self.tier_models = tuple(str(m) for m in tier_models)
        self.spec = spec
        n_tiers = calibrator.config.n_tiers
        if len(self.tier_models) != n_tiers:
            raise ValueError(f"{n_tiers} tiers but "
                             f"{len(self.tier_models)} tier models")
        if n_tiers < 2:
            raise ValueError("admission control needs >= 2 tiers "
                             "(there is nowhere to spill)")
        missing = [m for m in self.tier_models
                   if m not in cost_model.cost_per_mtok]
        if spec.cost_budget_per_query is not None and missing:
            raise ValueError(
                f"cost_budget_per_query is set but tier models {missing} "
                f"have no cost_per_mtok entry — the budget loop cannot "
                f"price them")
        self._tier_cost = np.asarray(
            [cost_model.request_cost(m) if m in cost_model.cost_per_mtok
             else 0.0 for m in self.tier_models])
        self.top = n_tiers - 1
        self.baseline_shares = tuple(calibrator.target_shares)
        self.shares = tuple(calibrator.target_shares)
        # -- mutable state (all of it JSON-serializable) ----------------------
        # Per-tier pressure EWMAs + spill flags for every tier that CAN
        # spill (1..top; tier 0 has nowhere to go). The top tier's pair
        # is also exposed as .pressure/.spill_active — the legacy names
        # the 2-tier telemetry and v1 snapshots use.
        self.tier_pressure: dict[int, float] = {
            t: 0.0 for t in range(1, n_tiers)}
        self.tier_spill: dict[int, bool] = {
            t: False for t in range(1, n_tiers)}
        self.cost_per_query = None     # EWMA'd realized $/query
        self.n_seen = 0                # requests that passed apply()
        self.n_spilled = 0
        self.n_tighten = 0
        self.n_relax = 0
        self.events: list[dict] = []   # spill_on/off + tighten/relax log
        self._last_control = -spec.control_interval  # allow immediate action
        self._tier_load: dict[int, dict] = {}
        # Observability mirrors (no-ops under NULL_OBS); the counters /
        # event log above stay the serialization source.
        self.obs = obs or NULL_OBS
        m = self.obs.metrics
        self._m_spilled = m.counter("admission_spilled_total")
        self._m_tighten = m.counter("admission_tighten_total")
        self._m_relax = m.counter("admission_relax_total")
        self._g_cost = m.gauge("admission_cost_per_query")
        self._g_top_share = m.gauge("admission_top_share")
        self._g_pressure = {t: m.gauge("admission_pressure", tier=str(t))
                            for t in self.tier_pressure}
        self._g_spill = {t: m.gauge("admission_spill_engaged", tier=str(t))
                         for t in self.tier_spill}

    def _obs_resync(self) -> None:
        """Re-point the registry's admission mirrors at (restored) state."""
        if not self.obs.enabled:
            return
        self._m_spilled.value = self.n_spilled
        self._m_tighten.value = self.n_tighten
        self._m_relax.value = self.n_relax
        self._g_cost.set(self.cost_per_query or 0.0)
        self._g_top_share.set(self.shares[self.top])
        for t, g in self._g_pressure.items():
            g.set(self.tier_pressure[t])
        for t, g in self._g_spill.items():
            g.set(int(self.tier_spill[t]))

    # -- load probes ----------------------------------------------------------

    def observe_tier_load(self, tier: int, queue_depth: int,
                          p99_latency: Optional[float] = None) -> None:
        """Feed one tier's replica-pool load. ``p99_latency`` may be
        ``nan`` (TierScheduler reports nan below its completion floor) —
        treated as 'no latency signal', never as pressure."""
        self._tier_load[int(tier)] = {
            "queue_depth": int(queue_depth),
            "p99_latency": _finite(p99_latency),
        }

    def _raw_pressure(self, tier: Optional[int] = None) -> float:
        load = self._tier_load.get(self.top if tier is None else tier)
        if load is None:
            return 0.0
        p = load["queue_depth"] / self.spec.queue_depth_slo
        if self.spec.p99_slo is not None and load["p99_latency"] is not None:
            p = max(p, load["p99_latency"] / self.spec.p99_slo)
        return float(p)

    # -- legacy 2-tier names: the TOP tier's pressure/spill pair --------------

    @property
    def pressure(self) -> float:
        return self.tier_pressure[self.top]

    @property
    def spill_active(self) -> bool:
        return self.tier_spill[self.top]

    # -- the control loop ------------------------------------------------------

    def _event(self, kind: str, **extra) -> None:
        self.events.append({"at_request": self.n_seen, "kind": kind,
                            "pressure": round(self.pressure, 6),
                            "shares": list(self.shares), **extra})
        if self.obs.enabled:
            self.obs.tracer.event("admission_" + kind,
                                  at_request=self.n_seen,
                                  pressure=round(self.pressure, 6), **extra)

    def _with_top_share(self, new_top: float) -> tuple[float, ...]:
        """Current shares with the top tier moved to ``new_top``; lower
        tiers rescaled so their relative proportions are preserved."""
        cur_top = self.shares[self.top]
        lower = 1.0 - cur_top
        scale = (1.0 - new_top) / lower if lower > 1e-9 else 0.0
        out = [s * scale for s in self.shares[:-1]]
        if lower <= 1e-9:       # degenerate: everything was top tier
            out = [(1.0 - new_top) / self.top] * self.top
        out.append(new_top)
        return tuple(out)

    def control_step(self) -> Optional[RouterConfig]:
        """One feedback tick. Updates pressure + spill state every call;
        quantile tighten/relax at most once per ``control_interval``
        requests. Returns a re-fit config to hot-swap, or ``None``."""
        spec = self.spec
        for t in self.tier_pressure:
            p = self.tier_pressure[t]
            p += spec.pressure_beta * (self._raw_pressure(t) - p)
            self.tier_pressure[t] = p
            if not self.tier_spill[t] and p >= spec.spill_on:
                self.tier_spill[t] = True
                self._event("spill_on", tier=t)
            elif self.tier_spill[t] and p <= spec.spill_off:
                self.tier_spill[t] = False
                self._event("spill_off", tier=t)
            self._g_pressure[t].set(p)
            self._g_spill[t].set(int(self.tier_spill[t]))

        if self.n_seen - self._last_control < spec.control_interval:
            return None
        budget_ratio = None
        if (spec.cost_budget_per_query is not None
                and self.cost_per_query is not None):
            budget_ratio = self.cost_per_query / spec.cost_budget_per_query
        over_budget = (budget_ratio is not None
                       and budget_ratio > 1.0 + spec.deadband)
        saturated = self.pressure >= spec.spill_on
        slack = (self.pressure <= spec.spill_off
                 and (budget_ratio is None
                      or budget_ratio < 1.0 - spec.deadband))

        top = self.shares[self.top]
        new_shares = None
        if (over_budget or saturated) and top > spec.min_top_share:
            new_shares = self._with_top_share(
                max(spec.min_top_share, top - spec.tighten_step))
            kind = "tighten"
        elif slack and top < self.baseline_shares[self.top] - 1e-9:
            new_shares = self._with_top_share(
                min(self.baseline_shares[self.top], top + spec.relax_step))
            kind = "relax"
        if new_shares is None:
            return None
        # Re-fit needs a populated window; until then only the share
        # target moves (the calibrator's own drift loop will converge it).
        if len(self.calibrator.window) < self.calibrator.min_samples:
            return None
        self.shares = new_shares
        self.calibrator.target_shares = new_shares  # drift loop now aims here
        self._last_control = self.n_seen
        if kind == "tighten":
            self.n_tighten += 1
            self._m_tighten.inc()
        else:
            self.n_relax += 1
            self._m_relax.inc()
        self._g_top_share.set(self.shares[self.top])
        new_config = self.calibrator.fit_config()
        self._event(kind, budget_ratio=(None if budget_ratio is None
                                        else round(budget_ratio, 6)),
                    new_thresholds=list(new_config.thresholds))
        return new_config

    # -- spill ----------------------------------------------------------------

    def marginal_cutoff(self) -> float:
        """Difficulty value bounding the marginal band: the calibrator
        window quantile ``spill_margin`` above the top-tier cut. Top-tier
        requests AT OR BELOW it are the near-threshold calls spill may
        demote; ``nan`` while the window is too small to judge."""
        if len(self.calibrator.window) < self.calibrator.min_samples:
            return float("nan")
        cut = 1.0 - self.shares[self.top]
        q = min(1.0, cut + self.spec.spill_margin)
        return float(self.calibrator.window.quantile(q))

    def spill_target(self) -> int:
        """Where spilled top-tier requests land: the first tier below the
        top whose own spill flag is NOT engaged — a saturated middle tier
        is skipped, not piled onto. Bounded at tier 0 (which has no
        pressure flag), so the cascade always terminates."""
        target = self.top - 1
        while target > 0 and self.tier_spill[target]:
            target -= 1
        return target

    def apply(self, tiers: np.ndarray, difficulty: np.ndarray,
              request_cost: Optional[np.ndarray] = None
              ) -> tuple[np.ndarray, int]:
        """Demote this batch's marginal top-tier requests while spill is
        engaged; always folds the *executed* mix into the $/query EWMA.
        ``request_cost``: optional per-request $ the routing policy
        billed at DECISION time (cascade stage bills, depth-priced
        prompts); its per-request surcharge over the flat tier price
        survives spill adjustment, so the budget loop sees the policy's
        true spend. Returns (possibly-adjusted tiers, number spilled).
        """
        tiers = np.asarray(tiers)
        n = len(tiers)
        if n == 0:
            return tiers, 0
        # Per-request $ on top of the executed tier's flat price: zero
        # without a policy bill, so `(tier_cost + 0).mean()` reproduces
        # the pre-policy EWMA bit-for-bit.
        extra = 0.0
        if request_cost is not None:
            extra = np.asarray(request_cost) - self._tier_cost[tiers]
        spilled = 0
        if self.spill_active:
            cutoff = self.marginal_cutoff()
            if math.isfinite(cutoff):
                marginal = (tiers == self.top) & (np.asarray(difficulty)
                                                  <= cutoff)
                spilled = int(marginal.sum())
                if spilled:
                    tiers = tiers.copy()
                    tiers[marginal] = self.spill_target()
        self.n_seen += n
        self.n_spilled += spilled
        self._m_spilled.inc(spilled)
        batch_cost = float((self._tier_cost[tiers] + extra).mean())
        if self.cost_per_query is None:
            self.cost_per_query = batch_cost
        else:
            self.cost_per_query += self.spec.pressure_beta * (
                batch_cost - self.cost_per_query)
        self._g_cost.set(self.cost_per_query)
        return tiers, spilled

    # -- replica-fabric sync --------------------------------------------------

    def sync_state(self) -> dict:
        """The admission block a replica publishes in its fabric
        ``StateDelta``: just enough for the fleet to agree about spill
        and budget during a burst — per-tier smoothed pressure + spill
        flags, the $/query EWMA, the (possibly tightened) target shares,
        and ``n_seen`` as the merge weight. Deliberately NOT the full
        ``state_dict``: events/tier_load are local history, and counters
        other than ``n_seen`` don't participate in the merge."""
        return {
            "tier_pressure": str_keyed(self.tier_pressure),
            "tier_spill": str_keyed(self.tier_spill),
            "cost_per_query": self.cost_per_query,
            "shares": list(self.shares),
            "n_seen": self.n_seen,
        }

    def adopt_sync(self, merged: Mapping) -> None:
        """Adopt a deterministically merged fleet admission view (see
        ``distributed.replica_sync.merge_admission``): pressure/spill/
        budget/shares become the fleet's, local counters stay local.
        Setting ``calibrator.target_shares`` keeps the drift loop aimed
        at the merged shares — the same convergence rule as
        ``control_step``."""
        shares = tuple(float(s) for s in merged["shares"])
        if len(shares) != len(self.shares):
            raise ValueError(f"merged admission view has {len(shares)} tier "
                             f"shares, controller has {len(self.shares)}")
        for t in self.tier_pressure:
            if str(t) in merged["tier_pressure"]:
                self.tier_pressure[t] = float(merged["tier_pressure"][str(t)])
                self.tier_spill[t] = bool(merged["tier_spill"][str(t)])
        cpq = merged.get("cost_per_query")
        self.cost_per_query = None if cpq is None else float(cpq)
        self.shares = shares
        self.calibrator.target_shares = shares

    # -- telemetry / serializable state ---------------------------------------

    def telemetry(self) -> dict:
        return {
            "spill_active": self.spill_active,
            "pressure": self.pressure,
            "tier_pressure": str_keyed(self.tier_pressure),
            "tier_spill": str_keyed(self.tier_spill),
            "cost_per_query": self.cost_per_query,
            "target_shares": list(self.shares),
            "baseline_shares": list(self.baseline_shares),
            "n_seen": self.n_seen,
            "n_spilled": self.n_spilled,
            "n_tighten": self.n_tighten,
            "n_relax": self.n_relax,
            "n_events": len(self.events),
            "tier_load": {str(t): dict(v)
                          for t, v in self._tier_load.items()},
        }

    def state_dict(self) -> dict:
        """Complete mutable state, JSON-friendly (knobs live in the spec,
        baseline shares in the calibration spec — policy, not state)."""
        return {
            "shares": list(self.shares),
            # flat top-tier pair kept alongside the per-tier dicts so v1
            # 2-tier snapshots and this layout read the same way
            "spill_active": self.spill_active,
            "pressure": self.pressure,
            "tier_pressure": str_keyed(self.tier_pressure),
            "tier_spill": str_keyed(self.tier_spill),
            "cost_per_query": self.cost_per_query,
            "n_seen": self.n_seen,
            "n_spilled": self.n_spilled,
            "n_tighten": self.n_tighten,
            "n_relax": self.n_relax,
            "last_control": self._last_control,
            "events": [dict(e) for e in self.events],
            "tier_load": {str(t): dict(v)
                          for t, v in self._tier_load.items()},
        }

    def load_state_dict(self, state: Mapping) -> None:
        shares = tuple(float(s) for s in state["shares"])
        if len(shares) != len(self.shares):
            raise ValueError(f"admission state has {len(shares)} tier "
                             f"shares, controller has {len(self.shares)}")
        self.shares = shares
        self.calibrator.target_shares = shares  # keep the loops convergent
        # per-tier dicts when present; legacy flat state only knows the
        # top tier's pair (lower tiers were implicitly calm back then)
        tp = state.get("tier_pressure")
        ts = state.get("tier_spill")
        for t in self.tier_pressure:
            if tp is not None and str(t) in tp:
                self.tier_pressure[t] = float(tp[str(t)])
            elif t == self.top:
                self.tier_pressure[t] = float(state["pressure"])
            else:
                self.tier_pressure[t] = 0.0
            if ts is not None and str(t) in ts:
                self.tier_spill[t] = bool(ts[str(t)])
            elif t == self.top:
                self.tier_spill[t] = bool(state["spill_active"])
            else:
                self.tier_spill[t] = False
        cpq = state["cost_per_query"]
        self.cost_per_query = None if cpq is None else float(cpq)
        self.n_seen = int(state["n_seen"])
        self.n_spilled = int(state["n_spilled"])
        self.n_tighten = int(state["n_tighten"])
        self.n_relax = int(state["n_relax"])
        self._last_control = int(state["last_control"])
        self.events = [dict(e) for e in state["events"]]
        self._tier_load = {
            int(t): {"queue_depth": int(v["queue_depth"]),
                     "p99_latency": _finite(v["p99_latency"])}
            for t, v in state["tier_load"].items()}
