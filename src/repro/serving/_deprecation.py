"""Warn-once deprecation shims for the pre-`repro.api` serving surface.

The old constructors (`SkewRouteDispatcher`, `ServingPipeline`) keep
working — they ARE the internals `repro.api.build` composes — but
hand-wiring them is deprecated in favor of the declarative
`RouteSpec` -> `SkewRouteSession` path. Each old entry point warns
exactly once per process; the api suppresses the warning for its own
internal construction via :func:`suppress`.
"""

from __future__ import annotations

import contextlib
import threading
import warnings

_lock = threading.Lock()
_warned: set[str] = set()
_local = threading.local()  # per-thread: api builds on one thread must
                            # not mute a hand-wiring user on another


@contextlib.contextmanager
def suppress():
    """Internal (repro.api) construction: no deprecation warning."""
    _local.depth = getattr(_local, "depth", 0) + 1
    try:
        yield
    finally:
        _local.depth -= 1


def warn_once(key: str, message: str) -> bool:
    """Emit ``message`` as a DeprecationWarning the first time ``key`` is
    seen (and outside :func:`suppress` blocks). Returns whether it fired."""
    if getattr(_local, "depth", 0):
        return False
    with _lock:
        if key in _warned:
            return False
        _warned.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)
    return True


def reset() -> None:
    """Forget warn-once history (test hook)."""
    with _lock:
        _warned.clear()
