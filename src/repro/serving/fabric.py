"""`ReplicaFabric`: an in-process fleet of routing replicas wired
through the `distributed.replica_sync` exchange.

This is the deployment shape the ROADMAP's millions-of-users story
lands on: ONE declarative `RouteSpec`, N `SkewRouteSession` replicas
(each behind its own slice of the load balancer), and a periodic sync
round instead of centralized retraining. The fabric is deliberately
transport-free — `sync_round` moves the exact JSON wire dicts the
endpoints publish, through an in-memory full mesh. A real deployment
swaps the loop for a gossip bus or a coordinator without touching the
protocol: the payloads ARE the protocol.

Two contracts worth reading twice:

* **Replicas share a policy, not state.** ``add_replica`` refuses a
  session whose spec fingerprint differs from the fleet's. Bootstrap
  (``bootstrap_from=``) ships ONLY the ``state`` half of the source
  replica's snapshot envelope through ``restore_state`` — the policy
  half never travels, because every replica already holds it by
  construction.
* **Merges are deterministic.** After a full-mesh round every endpoint
  holds the same delta set, and the weighted-quantile merge is a pure
  function of that set — so all replicas land on IDENTICAL thresholds,
  not merely similar ones (asserted in tests/test_fabric.py).
"""

from __future__ import annotations

import json
from typing import Mapping, Optional

from repro.distributed.replica_sync import SyncEndpoint

__all__ = ["ReplicaFabric"]


class ReplicaFabric:
    """N named sessions + their sync endpoints, full-mesh in process."""

    def __init__(self, *, peer_window: Optional[int] = None):
        self.peer_window = peer_window
        self.endpoints: dict[str, SyncEndpoint] = {}
        self.n_rounds = 0

    def __len__(self) -> int:
        return len(self.endpoints)

    @property
    def sessions(self) -> dict:
        return {n: e.session for n, e in self.endpoints.items()}

    def _obs(self):
        """The fabric traces onto the first (sorted) member's
        observability plane — the common deployment shares ONE plane
        across replicas; disjoint planes still get their per-endpoint
        publish/merge events, just no round-level span."""
        from repro.obs import NULL_OBS
        for n in sorted(self.endpoints):
            obs = self.endpoints[n].obs
            if obs.enabled:
                return obs
        return NULL_OBS

    # -- membership -----------------------------------------------------------

    def add_replica(self, name: str, session, *,
                    bootstrap_from: Optional[str] = None) -> SyncEndpoint:
        """Join a session to the fleet. All members must be built from
        the SAME RouteSpec (checked by policy fingerprint, loudly).

        ``bootstrap_from=`` warm-starts a cold replica from an existing
        member: the source's snapshot is taken and ONLY its ``state``
        half is restored — thresholds, calibrator window, counters —
        which is exactly what a mid-run join needs to start routing like
        the fleet instead of like a fresh deploy.
        """
        name = str(name)
        if name in self.endpoints:
            raise ValueError(f"replica {name!r} already joined")
        if bootstrap_from is not None:
            src = self.endpoints.get(bootstrap_from)
            if src is None:
                raise ValueError(f"bootstrap_from={bootstrap_from!r} is not "
                                 f"a fleet member "
                                 f"({sorted(self.endpoints) or 'empty'})")
            # state half only — and BEFORE the endpoint exists, so the
            # inherited window counts as bootstrap, not as this
            # replica's own publishable traffic
            session.restore_state(src.session.snapshot()["state"])
        ep = SyncEndpoint(name, session, peer_window=self.peer_window)
        if bootstrap_from is not None:
            # ...and the source's replay-buffer view of the fleet, so
            # the joiner's very first merge agrees with everyone else's
            # instead of drifting until its buffers turn over
            ep.adopt_view(self.endpoints[bootstrap_from])
        if self.endpoints:
            fleet_fp = next(iter(self.endpoints.values())).fingerprint
            if ep.fingerprint != fleet_fp:
                raise ValueError(
                    f"replica {name!r} runs policy {ep.fingerprint!r} but "
                    f"the fleet runs {fleet_fp!r}; one RouteSpec per "
                    f"fabric — build the session from the fleet's spec")
        self.endpoints[name] = ep
        return ep

    # -- the sync round -------------------------------------------------------

    def sync_round(self) -> dict:
        """One full exchange: every endpoint publishes its delta, every
        delta reaches every OTHER endpoint (publishers self-receive at
        publish time), then every endpoint merges and hot-swaps. The
        wire dicts make a JSON round trip so the in-process fabric can't
        accidentally lean on shared object identity.

        Returns a per-replica report (thresholds after merge, bytes
        moved) — the convergence bench's raw material.
        """
        names = sorted(self.endpoints)
        obs = self._obs()
        with obs.tracer.span("sync_round", round=self.n_rounds,
                             n_replicas=len(names)):
            payloads = {n: json.loads(json.dumps(self.endpoints[n].publish()))
                        for n in names}
            for n in names:
                for origin, payload in payloads.items():
                    if origin != n:
                        self.endpoints[n].receive(payload)
            report: dict = {"round": self.n_rounds, "replicas": {}}
            for n in names:
                ep = self.endpoints[n]
                merged = ep.merge(apply=True)
                report["replicas"][n] = {
                    "merged": merged is not None,
                    "thresholds": [float(t) for t in ep.session.thresholds],
                    "bytes_sent": ep.bytes_sent,
                }
        self.n_rounds += 1
        obs.metrics.counter("fabric_rounds_total").inc()
        return report

    # -- telemetry ------------------------------------------------------------

    def telemetry(self) -> dict:
        eps = {n: self.endpoints[n].telemetry()
               for n in sorted(self.endpoints)}
        return {
            "n_replicas": len(self.endpoints),
            "n_rounds": self.n_rounds,
            "bytes_sent": sum(e["bytes_sent"] for e in eps.values()),
            "bytes_sent_raw": sum(e["bytes_sent_raw"] for e in eps.values()),
            "endpoints": eps,
        }
