"""Token-based inference cost model (paper Table 4 / Fig 2).

Costs are $/M tokens on SiliconFlow as reported by the paper; the framework
uses them to score routing policies and to drive the serving dispatcher's
cost telemetry. Token counts follow the paper's Fig 2a measurement: a
KG-RAG prompt with 100 retrieved triples averages 1873 input tokens on CWQ
(vs 62 for the bare question).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

# $/M tokens (paper Table 4, SiliconFlow pricing).
PAPER_COST_PER_MTOK: dict[str, float] = {
    "qwen7b": 0.0485,
    "qwen14b": 0.0970,
    "qwen32b": 0.1746,
    "qwen72b": 0.5724,
    "llama8b": 0.0485,
    "llama70b": 0.5724,
}

# Paper Fig 2a: mean input tokens per question on CWQ.
TOKENS_BARE_QUESTION = 62
TOKENS_WITH_100_TRIPLES = 1873
TOKENS_PER_TRIPLE = (TOKENS_WITH_100_TRIPLES - TOKENS_BARE_QUESTION) / 100.0

# Paper Table 3: SubgraphRAG quality (Hit@1 / F1) with 100 triples.
PAPER_QUALITY: dict[str, dict[str, dict[str, float]]] = {
    "cwq": {
        "llama8b": {"f1": 46.83, "hit1": 49.90},
        "llama70b": {"f1": 53.53, "hit1": 57.94},
        "qwen7b": {"f1": 42.77, "hit1": 45.68},
        "qwen72b": {"f1": 52.11, "hit1": 55.25},
    },
    "webqsp": {
        "llama8b": {"f1": 69.29, "hit1": 78.56},
        "llama70b": {"f1": 73.93, "hit1": 84.15},
        "qwen7b": {"f1": 67.55, "hit1": 77.52},
        "qwen72b": {"f1": 70.76, "hit1": 80.84},
    },
}

# Interpolated mid-tier quality for the 3-tier experiment (paper §4.3.1
# reports Qwen14b ~7.45% over 7b; 72b ~2.12% over 14b on their platform).
PAPER_QUALITY["cwq"]["qwen14b"] = {"f1": 45.96, "hit1": 49.08}
PAPER_QUALITY["webqsp"]["qwen14b"] = {"f1": 69.3, "hit1": 79.4}


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Maps (tier model name, token counts) -> $ cost per request."""

    cost_per_mtok: Mapping[str, float] = dataclasses.field(
        default_factory=lambda: dict(PAPER_COST_PER_MTOK))
    n_triples: int = 100
    output_tokens: int = 120  # typical answer+reasoning length

    def input_tokens(self, n_triples: int | None = None) -> float:
        n = self.n_triples if n_triples is None else n_triples
        return TOKENS_BARE_QUESTION + TOKENS_PER_TRIPLE * n

    def request_cost(self, model: str, n_triples: int | None = None) -> float:
        toks = self.input_tokens(n_triples) + self.output_tokens
        return self.cost_per_mtok[model] * toks / 1e6

    def policy_cost(self, tier_models: Sequence[str],
                    tier_shares: Sequence[float]) -> float:
        """Expected $/query for a routing policy with given traffic shares."""
        if len(tier_models) != len(tier_shares):
            raise ValueError("tier_models and tier_shares length mismatch")
        return sum(self.request_cost(m) * s
                   for m, s in zip(tier_models, tier_shares))

    def relative_cost(self, tier_models: Sequence[str],
                      tier_shares: Sequence[float]) -> float:
        """Cost normalized to the all-largest-tier policy (paper's x-axis
        'larger LLM call ratio' is the binary special case)."""
        full = self.request_cost(tier_models[-1])
        return self.policy_cost(tier_models, tier_shares) / full
