"""KGQA evaluation metrics: Hit@1 and F1 over answer sets (paper §4.1)."""

from __future__ import annotations

from typing import Iterable, Sequence


def _norm(ans: str) -> str:
    return " ".join(str(ans).strip().lower().split())


def hit_at_1(predictions: Sequence[str], gold: Iterable[str]) -> float:
    """1.0 if the top prediction matches any gold answer."""
    if not predictions:
        return 0.0
    golds = {_norm(g) for g in gold}
    return 1.0 if _norm(predictions[0]) in golds else 0.0


def f1_score(predictions: Sequence[str], gold: Iterable[str]) -> float:
    """Set F1 between predicted answers and gold answers."""
    pset = {_norm(p) for p in predictions if str(p).strip()}
    gset = {_norm(g) for g in gold}
    if not pset and not gset:
        return 1.0
    if not pset or not gset:
        return 0.0
    tp = len(pset & gset)
    if tp == 0:
        return 0.0
    precision = tp / len(pset)
    recall = tp / len(gset)
    return 2 * precision * recall / (precision + recall)


def batch_metrics(batch_predictions: Sequence[Sequence[str]],
                  batch_gold: Sequence[Iterable[str]]) -> dict[str, float]:
    if len(batch_predictions) != len(batch_gold):
        raise ValueError("prediction/gold batch length mismatch")
    n = max(len(batch_gold), 1)
    hits = sum(hit_at_1(p, g) for p, g in zip(batch_predictions, batch_gold))
    f1s = sum(f1_score(p, g) for p, g in zip(batch_predictions, batch_gold))
    return {"hit@1": hits / n, "f1": f1s / n}
