"""Training-free threshold calibration.

The paper sweeps thresholds to trace the performance-vs-cost curve (Figs
5-9). Operationally a deployment wants the inverse: *given a target
large-LLM call ratio rho (a budget), find theta*. Because the router is a
monotone threshold rule, theta is exactly the (1 - rho)-quantile of the
difficulty metric over any unlabeled calibration sample — no labels, no
training, preserving the paper's training-free property.

Also provides the full sweep used by the benchmark harness to reproduce the
paper's routing curves.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import skewness
from repro.core.router import RouterConfig, route_from_difficulty


def calibrate_threshold(
    scores: jax.Array,
    target_large_ratio: float,
    metric: str = "gini",
    cumulative_p: float = 0.95,
    mask: Optional[jax.Array] = None,
) -> float:
    """theta s.t. ~``target_large_ratio`` of queries route to the large tier.

    Quantile matching on an unlabeled calibration set: difficulty is
    monotone in "hardness", so the (1-rho)-quantile of the difficulty
    distribution sends the top-rho hardest queries to F_L.
    """
    if not 0.0 <= target_large_ratio <= 1.0:
        raise ValueError(f"target_large_ratio must be in [0,1], got {target_large_ratio}")
    diff = skewness.difficulty(scores, metric=metric, p=cumulative_p, mask=mask)
    q = 1.0 - target_large_ratio
    return float(jnp.quantile(diff, jnp.clip(q, 0.0, 1.0)))


def calibrate_multi_tier(
    scores: jax.Array,
    tier_shares: Sequence[float],
    metric: str = "gini",
    cumulative_p: float = 0.95,
    mask: Optional[jax.Array] = None,
) -> RouterConfig:
    """Thresholds for N tiers with the given traffic shares (sum to 1).

    ``tier_shares[i]`` is the desired fraction of traffic on tier i
    (ascending model size). Returns a ready-to-use RouterConfig.
    """
    shares = np.asarray(list(tier_shares), dtype=np.float64)
    if shares.ndim != 1 or len(shares) < 2:
        raise ValueError("need >= 2 tier shares")
    if np.any(shares < 0) or not np.isclose(shares.sum(), 1.0, atol=1e-6):
        raise ValueError(f"tier shares must be >= 0 and sum to 1, got {shares}")
    diff = skewness.difficulty(scores, metric=metric, p=cumulative_p, mask=mask)
    cuts = np.cumsum(shares)[:-1]  # quantile cut points
    thresholds = tuple(float(jnp.quantile(diff, float(c))) for c in cuts)
    # Enforce strictly ascending (ties can collapse with discrete metrics).
    ts = list(thresholds)
    for i in range(1, len(ts)):
        ts[i] = max(ts[i], ts[i - 1])
    return RouterConfig(metric=metric, thresholds=tuple(ts),
                        cumulative_p=cumulative_p, top_k=scores.shape[-1])


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    threshold: float
    large_call_ratio: float
    quality: float  # Hit@1 or F1 depending on the evaluator
    cost: float     # $ per query under the cost model


def sweep_thresholds(
    difficulty: jax.Array,
    quality_small: jax.Array,
    quality_large: jax.Array,
    cost_small: jax.Array,
    cost_large: jax.Array,
    n_points: int = 21,
) -> list[SweepPoint]:
    """Trace the performance-cost curve (paper Figs 5/6/8/9).

    ``quality_*``: per-query quality (1/0 hit or F1 in [0,1]) under each
    tier; ``cost_*``: per-query cost. The sweep moves theta across the
    difficulty quantiles so point i routes the hardest i/(n-1) fraction
    large.
    """
    diff = np.asarray(difficulty, dtype=np.float64)
    qs = np.asarray(quality_small, dtype=np.float64)
    ql = np.asarray(quality_large, dtype=np.float64)
    cs = np.asarray(cost_small, dtype=np.float64)
    cl = np.asarray(cost_large, dtype=np.float64)
    points: list[SweepPoint] = []
    for i in range(n_points):
        rho = i / max(n_points - 1, 1)
        theta = float(np.quantile(diff, 1.0 - rho)) if rho > 0 else float(diff.max()) + 1.0
        large = diff > theta
        # guarantee exact-ish ratio under ties by nudging
        ratio = float(large.mean())
        quality = float(np.where(large, ql, qs).mean())
        cost = float(np.where(large, cl, cs).mean())
        points.append(SweepPoint(threshold=theta, large_call_ratio=ratio,
                                 quality=quality, cost=cost))
    return points


def random_mix_curve(
    quality_small: jax.Array,
    quality_large: jax.Array,
    cost_small: jax.Array,
    cost_large: jax.Array,
    n_points: int = 21,
    seed: int = 0,
) -> list[SweepPoint]:
    """The paper's random-mixing baseline: route a uniform-random rho
    fraction of queries to the large model."""
    rng = np.random.default_rng(seed)
    qs = np.asarray(quality_small, dtype=np.float64)
    ql = np.asarray(quality_large, dtype=np.float64)
    cs = np.asarray(cost_small, dtype=np.float64)
    cl = np.asarray(cost_large, dtype=np.float64)
    n = qs.shape[0]
    order = rng.permutation(n)
    points = []
    for i in range(n_points):
        rho = i / max(n_points - 1, 1)
        cutoff = int(round(rho * n))
        large = np.zeros(n, dtype=bool)
        large[order[:cutoff]] = True
        points.append(SweepPoint(
            threshold=float("nan"),
            large_call_ratio=float(large.mean()),
            quality=float(np.where(large, ql, qs).mean()),
            cost=float(np.where(large, cl, cs).mean()),
        ))
    return points


def oracle_curve(
    quality_small: jax.Array,
    quality_large: jax.Array,
    cost_small: jax.Array,
    cost_large: jax.Array,
    n_points: int = 21,
) -> list[SweepPoint]:
    """Upper bound: an omniscient router that sends exactly the queries the
    small model fails (and the large model solves) to the large model first."""
    qs = np.asarray(quality_small, dtype=np.float64)
    ql = np.asarray(quality_large, dtype=np.float64)
    cs = np.asarray(cost_small, dtype=np.float64)
    cl = np.asarray(cost_large, dtype=np.float64)
    gain = ql - qs
    order = np.argsort(-gain)  # biggest win first
    n = qs.shape[0]
    points = []
    for i in range(n_points):
        rho = i / max(n_points - 1, 1)
        cutoff = int(round(rho * n))
        large = np.zeros(n, dtype=bool)
        large[order[:cutoff]] = True
        points.append(SweepPoint(
            threshold=float("nan"),
            large_call_ratio=float(large.mean()),
            quality=float(np.where(large, ql, qs).mean()),
            cost=float(np.where(large, cl, cs).mean()),
        ))
    return points
