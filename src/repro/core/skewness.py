"""Skewness metrics over retrieved-context score distributions.

This is the mathematical heart of SkewRoute (paper §3.2/§3.3): four metrics
that quantify how concentrated ("skewed") the score distribution of the
retrieved top-K knowledge contexts is. High skew <=> simple query.

All metrics are vectorized over a leading batch dimension and jit-safe:
``scores`` is ``[..., K]`` (descending-sorted is NOT required unless noted;
we sort internally where the math needs it, and expose ``*_sorted`` variants
used by the fused Pallas fast path which receives already-sorted top-K
output from the retrieval stage).

Conventions
-----------
* Scores may be arbitrary reals (the SubgraphRAG scorer emits logits); each
  metric performs the normalization the paper specifies.
* A ``mask`` of valid entries supports ragged retrieval (fewer than K
  candidates); masked-out entries contribute nothing.
* Numerical guards: every normalization adds ``_EPS`` so empty / constant
  score vectors yield well-defined values (entropy 0, gini 0, area K·0…).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

_EPS = 1e-12


def _apply_mask(scores: jax.Array, mask: Optional[jax.Array], fill: float) -> jax.Array:
    if mask is None:
        return scores
    return jnp.where(mask, scores, fill)


def _valid_count(scores: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    if mask is None:
        return jnp.full(scores.shape[:-1], scores.shape[-1], dtype=scores.dtype)
    return jnp.sum(mask, axis=-1).astype(scores.dtype)


def normalize_minmax(scores: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Min-max normalize to [0, 1] along the last axis (paper §3.2)."""
    s = _apply_mask(scores, mask, jnp.inf)
    lo = jnp.min(s, axis=-1, keepdims=True)
    s = _apply_mask(scores, mask, -jnp.inf)
    hi = jnp.max(s, axis=-1, keepdims=True)
    out = (scores - lo) / (hi - lo + _EPS)
    return _apply_mask(out, mask, 0.0)


def normalize_prob(scores: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Normalize scores into a probability distribution (paper §3.3:
    p_i = s_i / sum_j s_j).

    The paper's scorer emits probabilities in [0,1]; raw logits are made
    non-negative by shifting with min(min, 0) — positive inputs pass
    through UNSHIFTED (shifting everything by the min would zero out
    constant vectors and change the paper's math on its own score range).
    """
    neg_min = jnp.minimum(
        jnp.min(_apply_mask(scores, mask, jnp.inf), axis=-1, keepdims=True), 0.0)
    shifted = _apply_mask(scores - jax.lax.stop_gradient(neg_min), mask, 0.0)
    total = jnp.sum(shifted, axis=-1, keepdims=True)
    return shifted / (total + _EPS)


def area_metric(scores: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Area under min-max-normalized scores (paper §3.2).

    Small area  <=> high skew <=> simple query.
    Range: [0, K]. The paper's Figure-3 examples give 1.07 (power-law) and
    65.65 (flat) for K=100.
    """
    return jnp.sum(normalize_minmax(scores, mask), axis=-1)


def cumulative_k(
    scores: jax.Array,
    p: float = 0.95,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Cumulative-threshold metric: smallest k with CDF_k >= p (paper §3.3).

    Scores are sorted descending, normalized to a probability distribution;
    returns the (1-indexed) count of contexts needed to reach cumulative
    probability ``p``.  Small k <=> high skew <=> simple query.
    """
    probs = normalize_prob(scores, mask)
    probs = jnp.sort(probs, axis=-1)[..., ::-1]
    cdf = jnp.cumsum(probs, axis=-1)
    reached = cdf >= (p - _EPS)
    # First index where the CDF crosses p; if never (degenerate, e.g. an
    # all-zero score vector), the number of VALID contexts — returning the
    # padded width K would overstate difficulty for ragged rows.
    k = jnp.argmax(reached, axis=-1) + 1
    any_reached = jnp.any(reached, axis=-1)
    return jnp.where(any_reached, k,
                     _valid_count(scores, mask)).astype(jnp.float32)


def entropy_metric(scores: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Shannon entropy (bits) of the normalized score distribution (§3.3).

    Low entropy <=> high skew <=> simple query. Range [0, log2 K].
    """
    probs = normalize_prob(scores, mask)
    plogp = jnp.where(probs > _EPS, probs * jnp.log2(probs + _EPS), 0.0)
    return -jnp.sum(plogp, axis=-1)


def gini_metric(scores: jax.Array, mask: Optional[jax.Array] = None) -> jax.Array:
    """Gini coefficient of the score distribution (paper §3.3).

    Uses the paper's formula over ascending-sorted scores s'_1<=...<=s'_K:

        G = (K + 1 - 2 * sum_i (K - i + 1) s'_i / sum_j s'_j) / K

    High Gini <=> high skew <=> simple query. Range [0, 1 - 1/K].
    Scores are shifted to be non-negative first (Gini is defined for
    non-negative quantities). Masked entries are treated as absent by
    computing over the shifted values with zero fill — for a correct ragged
    Gini we renormalize using the valid count.
    """
    kk = scores.shape[-1]
    neg_min = jnp.minimum(
        jnp.min(_apply_mask(scores, mask, jnp.inf), axis=-1, keepdims=True), 0.0)
    shifted = _apply_mask(scores - neg_min, mask, 0.0)
    asc = jnp.sort(shifted, axis=-1)
    n_valid = _valid_count(scores, mask)
    # Ranks: with zero-fill the invalid entries sort to the front and carry 0
    # weight; valid entries occupy the LAST n_valid slots. Rank within valid
    # entries (ascending, 1-indexed) is i - (K - n_valid).
    idx = jnp.arange(1, kk + 1, dtype=scores.dtype)
    rank_in_valid = idx - (kk - n_valid)[..., None]
    rank_in_valid = jnp.maximum(rank_in_valid, 0.0)
    weight = n_valid[..., None] - rank_in_valid + 1.0  # (K - i + 1) over valid
    weight = jnp.where(rank_in_valid > 0, weight, 0.0)
    total = jnp.sum(asc, axis=-1)
    weighted = jnp.sum(weight * asc, axis=-1)
    g = (n_valid + 1.0 - 2.0 * weighted / (total + _EPS)) / jnp.maximum(n_valid, 1.0)
    return jnp.clip(g, 0.0, 1.0)


# --- registry ---------------------------------------------------------------

#: Direction convention: for every metric we expose a *difficulty score*
#: where LARGER means MORE DIFFICULT (lower skew), so a single thresholding
#: rule `difficulty > theta -> large LLM` serves all metrics.
#: area: larger = flatter = harder (already aligned).
#: cumulative_k: larger = harder (aligned).
#: entropy: larger = harder (aligned).
#: gini: larger = MORE skewed = EASIER -> negate.

def difficulty_area(scores, mask=None):
    return area_metric(scores, mask)


def difficulty_cumulative(scores, p: float = 0.95, mask=None):
    return cumulative_k(scores, p, mask)


def difficulty_entropy(scores, mask=None):
    return entropy_metric(scores, mask)


def difficulty_gini(scores, mask=None):
    return -gini_metric(scores, mask)


METRICS = {
    "area": difficulty_area,
    "cumulative": difficulty_cumulative,
    "entropy": difficulty_entropy,
    "gini": difficulty_gini,
}


@functools.partial(jax.jit, static_argnames=("metric", "p"))
def difficulty(scores: jax.Array, metric: str = "gini", p: float = 0.95,
               mask: Optional[jax.Array] = None) -> jax.Array:
    """Compute the difficulty score for a batch of score vectors ``[..., K]``."""
    if metric == "cumulative":
        return METRICS[metric](scores, p, mask)
    return METRICS[metric](scores, mask)


def all_metrics(scores: jax.Array, p: float = 0.95,
                mask: Optional[jax.Array] = None) -> dict[str, jax.Array]:
    """All four difficulty metrics in one call (shared normalization work
    is left to XLA CSE; the fused single-pass version lives in
    ``repro.kernels.skew_metrics``)."""
    return {
        "area": difficulty_area(scores, mask),
        "cumulative": difficulty_cumulative(scores, p, mask),
        "entropy": difficulty_entropy(scores, mask),
        "gini": difficulty_gini(scores, mask),
    }
