"""Streaming, drift-aware threshold calibration (training-free, online).

``core.calibrate`` fits thresholds from a static unlabeled sample; serving
needs the inverse problem solved CONTINUOUSLY: live traffic drifts away
from the calibration distribution (RAGRouter and cost-aware-routing both
document the quality cliff), and the tier mix silently walks off the
budget. Because the SkewRoute router is a pure quantile rule, the fix
stays training-free: keep a sliding window of recent difficulty samples,
watch the OBSERVED tier shares under the current thresholds, and when
they drift past a tolerance re-fit the thresholds from window quantiles
and hot-swap the (frozen, trivially swappable) ``RouterConfig``.

The window is an exact ring buffer — at serving batch sizes the O(W log W)
quantile over a few-thousand-float window is noise next to a single LLM
token, and exactness keeps the convergence guarantee of
``calibrate_threshold`` (same quantile, same data ⇒ same theta).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.router import RouterConfig


class SlidingWindow:
    """Fixed-capacity ring buffer over a scalar stream (float32).

    Keeps the most recent ``capacity`` samples; O(1) amortized pushes,
    exact quantiles over the current contents.
    """

    def __init__(self, capacity: int):
        if capacity < 2:
            raise ValueError(f"window capacity must be >= 2, got {capacity}")
        self.capacity = capacity
        self._buf = np.empty(capacity, np.float32)
        self._n = 0          # total samples ever pushed
        self._head = 0       # next write position

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def total_seen(self) -> int:
        return self._n

    def push(self, values: np.ndarray) -> None:
        v = np.asarray(values, np.float32).ravel()
        if v.size >= self.capacity:       # batch alone fills the window
            self._buf[:] = v[-self.capacity:]
            self._head = 0
        else:
            end = self._head + v.size
            if end <= self.capacity:
                self._buf[self._head:end] = v
            else:
                split = self.capacity - self._head
                self._buf[self._head:] = v[:split]
                self._buf[:end - self.capacity] = v[split:]
            self._head = end % self.capacity
        self._n += v.size

    def values(self) -> np.ndarray:
        """Current window contents (order-free copy)."""
        return self._buf[:len(self)].copy()

    def recent(self, n: int) -> np.ndarray:
        """The most recent ``min(n, len(self))`` samples, OLDEST first —
        the chronological tail replica sync publishes as its delta."""
        n = min(int(n), len(self))
        if n <= 0:
            return np.empty(0, np.float32)
        if self._n <= self.capacity and self._head >= len(self):
            # ring has not wrapped: chronological order IS buffer order
            return self._buf[len(self) - n:len(self)].copy()
        # wrapped ring: chronological order is [head:] then [:head]
        chron = np.concatenate([self._buf[self._head:len(self)],
                                self._buf[:self._head]])
        return chron[-n:].copy()

    def quantile(self, q) -> np.ndarray:
        if len(self) == 0:
            raise ValueError("empty window has no quantiles")
        return np.quantile(self._buf[:len(self)], q)

    # -- serializable state (exact: restores the ring bit-for-bit) -----------

    def state_dict(self) -> dict:
        return {"capacity": self.capacity,
                "buffer": [float(x) for x in self._buf[:len(self)]],
                "head": self._head,
                "total_seen": self._n}

    def load_state_dict(self, state: dict) -> None:
        # the ring layout (head/wrap positions) only makes sense at the
        # capacity it was recorded under — cross-capacity restores would
        # corrupt sample order or read uninitialized slots
        if int(state["capacity"]) != self.capacity:
            raise ValueError(
                f"window state mismatch: state from a capacity-"
                f"{int(state['capacity'])} window cannot restore into a "
                f"capacity-{self.capacity} window")
        buf = np.asarray(state["buffer"], np.float32)
        n = int(state["total_seen"])
        if buf.size != min(n, self.capacity):
            raise ValueError(
                f"window state mismatch: {buf.size} samples with "
                f"total_seen={n} (expected {min(n, self.capacity)})")
        self._buf[:buf.size] = buf
        self._head = int(state["head"])
        self._n = n


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One hot-swap: what was observed and what the thresholds became."""

    at_sample: int                       # total_seen when the swap fired
    observed_shares: tuple[float, ...]
    target_shares: tuple[float, ...]
    old_thresholds: tuple[float, ...]
    new_thresholds: tuple[float, ...]

    @property
    def max_drift(self) -> float:
        return max(abs(o - t) for o, t in
                   zip(self.observed_shares, self.target_shares))


class StreamingCalibrator:
    """Sliding-window quantile calibrator with drift-triggered hot-swap.

    Feed per-batch difficulty samples via :meth:`observe`; it returns a
    fresh :class:`RouterConfig` whenever the observed tier shares under
    the CURRENT thresholds drift more than ``tolerance`` (L-inf over
    shares) from ``target_shares`` — and ``None`` otherwise. The caller
    (the dispatcher) owns the swap; the calibrator owns the statistics.

    Knobs:
      window:       samples of history the quantiles see (drift response
                    time ~ window / batch_rate).
      min_samples:  don't judge drift before the window has this much.
      tolerance:    max |observed - target| share before refitting.
      cooldown:     samples to wait after a swap before the next one
                    (prevents threshold flapping while the window still
                    mixes pre- and post-drift traffic).
    """

    def __init__(self, config: RouterConfig,
                 target_shares: Sequence[float],
                 window: int = 4096, min_samples: int = 256,
                 tolerance: float = 0.05,
                 cooldown: Optional[int] = None):
        shares = tuple(float(s) for s in target_shares)
        if len(shares) != config.n_tiers:
            raise ValueError(f"{config.n_tiers} tiers but "
                             f"{len(shares)} target shares")
        if any(s < 0 for s in shares) or abs(sum(shares) - 1.0) > 1e-6:
            raise ValueError(f"target shares must be >= 0 and sum to 1, "
                             f"got {shares}")
        if not 0.0 < tolerance < 1.0:
            raise ValueError(f"tolerance must be in (0,1), got {tolerance}")
        self.config = config
        self.target_shares = shares
        self.tolerance = tolerance
        self.min_samples = max(int(min_samples), 2)
        self.cooldown = int(cooldown) if cooldown is not None else max(
            self.min_samples, window // 4)
        self.window = SlidingWindow(window)
        self.events: list[DriftEvent] = []
        self._last_swap_at = -self.cooldown  # allow an immediate first swap

    # -- statistics -----------------------------------------------------------

    def observed_shares(self) -> tuple[float, ...]:
        """Empirical tier shares of the window under CURRENT thresholds."""
        d = self.window.values()
        ts = np.asarray(self.config.thresholds)
        tiers = np.sum(d[:, None] > ts[None, :], axis=1)
        n = max(d.size, 1)
        return tuple(float(np.sum(tiers == t)) / n
                     for t in range(self.config.n_tiers))

    def fit_config(self) -> RouterConfig:
        """Thresholds hitting ``target_shares`` on the current window —
        the streaming analogue of ``calibrate.calibrate_multi_tier``."""
        cuts = np.cumsum(self.target_shares)[:-1]
        ts = [float(q) for q in self.window.quantile(cuts)]
        for i in range(1, len(ts)):     # ties can collapse; keep ascending
            ts[i] = max(ts[i], ts[i - 1])
        return dataclasses.replace(self.config, thresholds=tuple(ts))

    def quantile_source(self):
        """The window as a quantile callable (levels -> values) — the
        per-policy fit hook: routing policies with their own calibrated
        cutoffs (cascade escalation, depth buckets) re-fit from the SAME
        sample set that produced the thresholds, so a threshold hot-swap
        and its policy refit are consistent by construction. Replica sync
        passes its merged-fleet quantile instead (see
        ``distributed.replica_sync``)."""
        return lambda qs: np.asarray(self.window.quantile(np.asarray(qs)))

    # -- the streaming step ---------------------------------------------------

    def observe(self, difficulty: np.ndarray) -> Optional[RouterConfig]:
        """Absorb one batch of difficulty samples; maybe emit new config."""
        self.window.push(np.asarray(difficulty))
        if len(self.window) < self.min_samples:
            return None
        if self.window.total_seen - self._last_swap_at < self.cooldown:
            return None
        observed = self.observed_shares()
        drift = max(abs(o - t)
                    for o, t in zip(observed, self.target_shares))
        if drift <= self.tolerance:
            return None
        new = self.fit_config()
        self.events.append(DriftEvent(
            at_sample=self.window.total_seen,
            observed_shares=observed,
            target_shares=self.target_shares,
            old_thresholds=self.config.thresholds,
            new_thresholds=new.thresholds))
        self.config = new
        self._last_swap_at = self.window.total_seen
        return new

    @property
    def n_swaps(self) -> int:
        return len(self.events)

    # -- serializable state ---------------------------------------------------

    def state_dict(self) -> dict:
        """The calibrator's complete mutable state as JSON-friendly data
        (thresholds, exact sample window, swap history). Knobs/targets are
        NOT included — they are policy, carried by the config/spec."""
        return {
            "thresholds": list(self.config.thresholds),
            "window": self.window.state_dict(),
            "last_swap_at": self._last_swap_at,
            "events": [{"at_sample": e.at_sample,
                        "observed_shares": list(e.observed_shares),
                        "target_shares": list(e.target_shares),
                        "old_thresholds": list(e.old_thresholds),
                        "new_thresholds": list(e.new_thresholds)}
                       for e in self.events],
        }

    def load_state_dict(self, state: dict) -> None:
        self.config = dataclasses.replace(
            self.config, thresholds=tuple(state["thresholds"]))
        self.window.load_state_dict(state["window"])
        self._last_swap_at = int(state["last_swap_at"])
        self.events = [
            DriftEvent(at_sample=int(e["at_sample"]),
                       observed_shares=tuple(e["observed_shares"]),
                       target_shares=tuple(e["target_shares"]),
                       old_thresholds=tuple(e["old_thresholds"]),
                       new_thresholds=tuple(e["new_thresholds"]))
            for e in state["events"]]
