"""SkewRoute router: training-free, threshold-based LLM tier selection.

Implements Algorithm 1 of the paper, generalized to N tiers (paper §4.3.1
shows 3 tiers: Qwen-7b / 14b / 72b). The router consumes the *difficulty
score* (see ``repro.core.skewness`` — larger = harder) and N-1 ascending
thresholds; queries land in the lowest tier whose threshold exceeds their
difficulty.

The router is a frozen dataclass of plain floats — it is deliberately
trivial to serialize, replicate across serving replicas, and hot-swap when
the calibrator produces new thresholds (no weights, no training state).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import skewness


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Configuration of a training-free skew router.

    Attributes:
      metric: one of ``area | cumulative | entropy | gini``.
      thresholds: ascending difficulty thresholds; ``len(thresholds) + 1``
        tiers. Queries with difficulty <= thresholds[0] go to tier 0 (the
        smallest model), etc.
      cumulative_p: the P of the cumulative-threshold metric (paper Fig. 9).
      top_k: number of retrieved contexts whose scores feed the metric.
    """

    metric: str = "gini"
    thresholds: tuple[float, ...] = (0.0,)
    cumulative_p: float = 0.95
    top_k: int = 100

    def __post_init__(self):
        if self.metric not in skewness.METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; "
                             f"choose from {sorted(skewness.METRICS)}")
        if self.top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if not 0.0 < self.cumulative_p <= 1.0:
            raise ValueError(f"cumulative_p must be in (0, 1], "
                             f"got {self.cumulative_p}")
        if len(self.thresholds) < 1:
            raise ValueError("need at least one threshold (two tiers)")
        ts = tuple(float(t) for t in self.thresholds)
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError(f"thresholds must be ascending, got {ts}")
        object.__setattr__(self, "thresholds", ts)

    @property
    def n_tiers(self) -> int:
        return len(self.thresholds) + 1


def route(scores: jax.Array, config: RouterConfig,
          mask: Optional[jax.Array] = None) -> jax.Array:
    """Assign each query to a tier. ``scores``: [..., K] -> tiers [...]."""
    diff = skewness.difficulty(scores, metric=config.metric,
                               p=config.cumulative_p, mask=mask)
    return route_from_difficulty(diff, jnp.asarray(config.thresholds))


@dataclasses.dataclass(frozen=True)
class RouteBatchResult:
    """Everything the fused fast path produces for one batch.

    ``metrics`` keeps ALL four metric columns (kernel order — see
    ``repro.kernels.skew_metrics.ops.METRIC_COLUMNS``) so telemetry and
    the streaming calibrator get the full picture for free.
    """

    tiers: jax.Array        # [B] int32
    difficulty: jax.Array   # [B] float32, larger = harder
    metrics: jax.Array      # [B, 4] float32 raw metric values


def difficulty_from_metrics(metrics: jax.Array, metric: str) -> jax.Array:
    """Column-select one metric from the fused [B, 4] output and orient it
    as a difficulty score (larger = harder). Gini is the only metric where
    high skew = high value, so it is negated (see skewness registry)."""
    from repro.kernels.skew_metrics.kernel import METRIC_COLUMNS
    try:
        col = METRIC_COLUMNS.index(metric)
    except ValueError:
        raise ValueError(f"unknown metric {metric!r}; "
                         f"choose from {sorted(METRIC_COLUMNS)}") from None
    sign = -1.0 if metric == "gini" else 1.0
    return sign * metrics[..., col]


def route_all_metrics(scores_desc: jax.Array, config: RouterConfig,
                      n_valid: Optional[jax.Array] = None,
                      interpret: Optional[bool] = None) -> RouteBatchResult:
    """Batched fast path: ONE fused Pallas pass (interpret-mode on CPU)
    computes all four skew metrics; tier choice is a column select plus a
    threshold compare — no per-metric recompiles, no per-request calls.

    ``scores_desc``: [B, K] descending-sorted top-K retrieval scores.
    ``n_valid``: optional [B] valid-prefix counts for ragged retrieval.
    """
    from repro.kernels.skew_metrics import ops as skew_ops
    metrics = skew_ops.skew_metrics(scores_desc, p_cdf=config.cumulative_p,
                                    n_valid=n_valid, interpret=interpret)
    diff = difficulty_from_metrics(metrics, config.metric)
    tiers = route_from_difficulty(diff, jnp.asarray(config.thresholds))
    return RouteBatchResult(tiers=tiers, difficulty=diff, metrics=metrics)


def route_from_difficulty(difficulty: jax.Array,
                          thresholds: jax.Array) -> jax.Array:
    """Bucket difficulty scores by ascending thresholds -> int32 tier ids.

    tier = #thresholds strictly below the difficulty value, i.e.
    ``difficulty <= t[0]`` -> 0 (smallest model), ``> t[-1]`` -> N-1.
    """
    return jnp.sum(difficulty[..., None] > thresholds, axis=-1).astype(jnp.int32)


def route_binary(scores: jax.Array, config: RouterConfig,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Paper's two-tier form: True -> large LLM (F_L), False -> small (F_S)."""
    return route(scores, config, mask) > 0


@dataclasses.dataclass(frozen=True)
class RoutingStats:
    """Aggregate telemetry for a routed batch (exported by the dispatcher)."""

    tier_counts: tuple[int, ...]
    large_call_ratio: float  # fraction sent to the top tier
    mean_difficulty: float

    @staticmethod
    def from_assignments(tiers: jax.Array, n_tiers: int,
                         difficulty: jax.Array) -> "RoutingStats":
        counts = tuple(int(jnp.sum(tiers == t)) for t in range(n_tiers))
        n = max(int(tiers.size), 1)
        return RoutingStats(
            tier_counts=counts,
            large_call_ratio=counts[-1] / n,
            mean_difficulty=float(jnp.mean(difficulty)),
        )


def expected_tier_shares(difficulty: jax.Array,
                         thresholds: Sequence[float]) -> list[float]:
    """Empirical share of traffic per tier for a difficulty sample."""
    tiers = route_from_difficulty(difficulty, jnp.asarray(tuple(thresholds)))
    n = max(int(tiers.size), 1)
    return [float(jnp.sum(tiers == t)) / n for t in range(len(tuple(thresholds)) + 1)]
