"""SkewRoute router: training-free, threshold-based LLM tier selection.

Implements Algorithm 1 of the paper, generalized to N tiers (paper §4.3.1
shows 3 tiers: Qwen-7b / 14b / 72b). The router consumes the *difficulty
score* (see ``repro.core.skewness`` — larger = harder) and N-1 ascending
thresholds; queries land in the lowest tier whose threshold exceeds their
difficulty.

The router is a frozen dataclass of plain floats — it is deliberately
trivial to serialize, replicate across serving replicas, and hot-swap when
the calibrator produces new thresholds (no weights, no training state).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import skewness
from repro.kernels.device import default_interpret


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Configuration of a training-free skew router.

    Attributes:
      metric: one of ``area | cumulative | entropy | gini``.
      thresholds: ascending difficulty thresholds; ``len(thresholds) + 1``
        tiers. Queries with difficulty <= thresholds[0] go to tier 0 (the
        smallest model), etc.
      cumulative_p: the P of the cumulative-threshold metric (paper Fig. 9).
      top_k: number of retrieved contexts whose scores feed the metric.
    """

    metric: str = "gini"
    thresholds: tuple[float, ...] = (0.0,)
    cumulative_p: float = 0.95
    top_k: int = 100

    def __post_init__(self):
        if self.metric not in skewness.METRICS:
            raise ValueError(f"unknown metric {self.metric!r}; "
                             f"choose from {sorted(skewness.METRICS)}")
        if self.top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {self.top_k}")
        if not 0.0 < self.cumulative_p <= 1.0:
            raise ValueError(f"cumulative_p must be in (0, 1], "
                             f"got {self.cumulative_p}")
        if len(self.thresholds) < 1:
            raise ValueError("need at least one threshold (two tiers)")
        ts = tuple(float(t) for t in self.thresholds)
        if any(b < a for a, b in zip(ts, ts[1:])):
            raise ValueError(f"thresholds must be ascending, got {ts}")
        object.__setattr__(self, "thresholds", ts)

    @property
    def n_tiers(self) -> int:
        return len(self.thresholds) + 1


def route(scores: jax.Array, config: RouterConfig,
          mask: Optional[jax.Array] = None) -> jax.Array:
    """Assign each query to a tier. ``scores``: [..., K] -> tiers [...]."""
    diff = skewness.difficulty(scores, metric=config.metric,
                               p=config.cumulative_p, mask=mask)
    return route_from_difficulty(diff, jnp.asarray(config.thresholds))


@dataclasses.dataclass(frozen=True)
class RouteBatchResult:
    """Everything the fused fast path produces for one batch.

    ``metrics`` keeps ALL four metric columns (kernel order — see
    ``repro.kernels.skew_metrics.ops.METRIC_COLUMNS``) so telemetry and
    the streaming calibrator get the full picture for free.
    """

    tiers: jax.Array        # [B] int32
    difficulty: jax.Array   # [B] float32, larger = harder
    metrics: jax.Array      # [B, 4] float32 raw metric values


def difficulty_from_metrics(metrics: jax.Array, metric: str) -> jax.Array:
    """Column-select one metric from the fused [B, 4] output and orient it
    as a difficulty score (larger = harder). Gini is the only metric where
    high skew = high value, so it is negated (see skewness registry)."""
    from repro.kernels.skew_metrics.kernel import METRIC_COLUMNS
    try:
        col = METRIC_COLUMNS.index(metric)
    except ValueError:
        raise ValueError(f"unknown metric {metric!r}; "
                         f"choose from {sorted(METRIC_COLUMNS)}") from None
    sign = -1.0 if metric == "gini" else 1.0
    return sign * metrics[..., col]


@functools.partial(jax.jit, static_argnames=("metric", "p_cdf", "ragged",
                                             "use_kernel", "interpret"))
def _decision_program(scores_desc: jax.Array, thresholds: jax.Array,
                      n_valid: Optional[jax.Array], *, metric: str,
                      p_cdf: float, ragged: bool, use_kernel: bool,
                      interpret: bool):
    """metrics -> column select -> threshold compare as ONE jitted device
    program — a routing decision is a single dispatch regardless of which
    metric implementation (fused Pallas kernel or the XLA oracle) feeds
    it. Thresholds ride along as a runtime array so calibration hot-swaps
    never trigger a recompile."""
    if use_kernel:
        from repro.kernels.skew_metrics import ops as skew_ops
        metrics = skew_ops.skew_metrics(scores_desc, p_cdf=p_cdf,
                                        n_valid=n_valid if ragged else None,
                                        interpret=interpret)
    else:
        from repro.kernels.skew_metrics.ref import (mask_from_n_valid,
                                                    skew_metrics_ref)
        mask = (mask_from_n_valid(n_valid, scores_desc.shape[-1])
                if ragged else None)
        metrics = skew_metrics_ref(scores_desc, p_cdf=p_cdf, mask=mask)
    diff = difficulty_from_metrics(metrics, metric)
    tiers = route_from_difficulty(diff, thresholds)
    return tiers, diff, metrics


@functools.lru_cache(maxsize=512)
def _thresholds_array(thresholds: tuple[float, ...]) -> jax.Array:
    """Device copy of a threshold tuple, cached — B=1 dispatch latency is
    overhead-dominated, and re-uploading an unchanged 8-byte array every
    call is pure overhead (hot-swaps produce a new tuple -> new entry)."""
    return jnp.asarray(thresholds)


def route_all_metrics(scores_desc: jax.Array, config: RouterConfig,
                      n_valid: Optional[jax.Array] = None,
                      interpret: Optional[bool] = None,
                      use_kernel: bool = True) -> RouteBatchResult:
    """Batched fast path: ONE device program (fused Pallas pass by
    default; interpret-mode off-TPU) computes all four skew metrics, the
    column select, and the threshold compare — no per-metric recompiles,
    no per-request calls, no host hop between metrics and decision.

    ``scores_desc``: [B, K] descending-sorted top-K retrieval scores.
    ``n_valid``: optional [B] valid-prefix counts for ragged retrieval.
    ``use_kernel=False`` swaps in the XLA oracle metrics (same single-
    program shape — what the ``oracle`` difficulty backend runs).
    """
    if interpret is None:
        interpret = default_interpret()
    tiers, diff, metrics = _decision_program(
        scores_desc, _thresholds_array(config.thresholds), n_valid,
        metric=config.metric, p_cdf=config.cumulative_p,
        ragged=n_valid is not None, use_kernel=use_kernel,
        interpret=interpret)
    return RouteBatchResult(tiers=tiers, difficulty=diff, metrics=metrics)


def route_from_difficulty(difficulty: jax.Array,
                          thresholds: jax.Array) -> jax.Array:
    """Bucket difficulty scores by ascending thresholds -> int32 tier ids.

    tier = #thresholds strictly below the difficulty value, i.e.
    ``difficulty <= t[0]`` -> 0 (smallest model), ``> t[-1]`` -> N-1.
    """
    return jnp.sum(difficulty[..., None] > thresholds, axis=-1).astype(jnp.int32)


def route_binary(scores: jax.Array, config: RouterConfig,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Paper's two-tier form: True -> large LLM (F_L), False -> small (F_S)."""
    return route(scores, config, mask) > 0


@jax.jit
def select_depths(difficulty: jax.Array, depth_cutoffs: jax.Array,
                  depth_options: jax.Array) -> jax.Array:
    """Route retrieval DEPTH per query: bucket difficulty by ascending
    cutoffs (the same compare as :func:`route_from_difficulty`) and pick
    the matching depth option — easy (high-skew) queries take a shallow
    k, flat distributions the deep one. Cutoffs and options ride along
    as runtime arrays so depth-policy refits never recompile; jitted so
    the `adaptive_depth` policy's second routed axis stays a device
    program next to the decision, not a host loop."""
    bucket = route_from_difficulty(difficulty, depth_cutoffs)
    return jnp.take(jnp.asarray(depth_options, jnp.int32), bucket)


# -- end-to-end: retrieval scoring -> top-k -> skew -> decision ---------------

_NEG_INF = -1e30  # masks padded/invalid candidates out of top-k


@dataclasses.dataclass(frozen=True)
class RetrievedRouteResult:
    """Everything the fused retrieve-to-decision program produces.

    ``indices``/``probs`` are the top-K retrieval output (candidate index
    into the per-query feature rows, sigmoid score in [0, 1], descending);
    ``n_valid`` counts the usable leading entries per row (< K when a
    query had fewer than K candidates). The routing triple
    (tiers/difficulty/metrics) matches :class:`RouteBatchResult`.
    """

    indices: jax.Array      # [B, K] int32 candidate indices, desc by score
    probs: jax.Array        # [B, K] float32 sigmoid scores
    n_valid: jax.Array      # [B] int32 usable prefix length (= min(n_cand, K))
    tiers: jax.Array        # [B] int32
    difficulty: jax.Array   # [B] float32
    metrics: jax.Array      # [B, 4] float32


def topk_sigmoid_decision(logits: jax.Array, thresholds: jax.Array,
                          n_cand: Optional[jax.Array], *, top_k: int,
                          metric: str, p_cdf: float, ragged: bool,
                          use_kernel: bool, interpret: bool):
    """The decision tail shared by every retrieve-to-decision program:
    candidate logits [B, N] -> ragged mask -> device top-k -> sigmoid ->
    skew metrics -> threshold compare. Factored out so the mesh-sharded
    backend (which gathers per-shard logits over the candidate axis
    first) runs BYTE-IDENTICAL math after its all_gather — parity with
    the single-device program is structural, not coincidental."""
    b, n = logits.shape
    if ragged:
        nc = jnp.clip(jnp.asarray(n_cand, jnp.int32), 1, n)
        col = jnp.arange(n, dtype=jnp.int32)[None, :]
        logits = jnp.where(col < nc[:, None], logits, _NEG_INF)
        nv = jnp.minimum(nc, top_k)
    else:
        nv = jnp.full((b,), min(n, top_k), jnp.int32)
    vals, idx = jax.lax.top_k(logits, top_k)      # descending by score
    probs = jax.nn.sigmoid(vals)                  # paper scores are [0, 1]
    tiers, diff, metrics = _decision_program(
        probs, thresholds, nv, metric=metric, p_cdf=p_cdf, ragged=True,
        use_kernel=use_kernel, interpret=interpret)
    return idx.astype(jnp.int32), probs, nv, tiers, diff, metrics


def score_candidates(feats: jax.Array, query_emb: jax.Array,
                     w1_t, w1_q, b1, w2, b2, *, use_kernels: bool,
                     interpret: bool, tile: int) -> jax.Array:
    """[B, N, Dt] features + [B, Dq] queries -> [B, N] candidate logits
    (Pallas `triple_score` kernel or its XLA ref). Row-and-candidate
    local: safe to shard over both the request and candidate axes."""
    if use_kernels:
        from repro.kernels.triple_score import kernel as ts_kernel
        return ts_kernel.triple_score_batched(
            feats, query_emb, w1_t, w1_q, b1, w2, b2,
            tile=tile, interpret=interpret)
    from repro.kernels.triple_score.ref import triple_score_batched_ref
    return triple_score_batched_ref(feats, query_emb, w1_t, w1_q, b1, w2, b2)


@functools.partial(jax.jit, static_argnames=("top_k", "metric", "p_cdf",
                                             "ragged", "use_kernels",
                                             "interpret", "tile"))
def _retrieved_program(feats: jax.Array, query_emb: jax.Array,
                       w1_t, w1_q, b1, w2, b2,
                       thresholds: jax.Array, n_cand: Optional[jax.Array],
                       *, top_k: int, metric: str, p_cdf: float,
                       ragged: bool, use_kernels: bool, interpret: bool,
                       tile: int):
    """The tentpole: scoring -> top-k -> skew metrics -> tier decision in
    ONE jitted device program. Candidate scores never leave HBM; the host
    sees only the [B, K] retrieval output and the [B] tier ids."""
    logits = score_candidates(feats, query_emb, w1_t, w1_q, b1, w2, b2,
                              use_kernels=use_kernels, interpret=interpret,
                              tile=tile)
    return topk_sigmoid_decision(
        logits, thresholds, n_cand, top_k=top_k, metric=metric,
        p_cdf=p_cdf, ragged=ragged, use_kernel=use_kernels,
        interpret=interpret)


def route_retrieved(feats: jax.Array, query_emb: jax.Array,
                    params: Mapping[str, jax.Array], config: RouterConfig,
                    n_cand: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None,
                    use_kernels: bool = True,
                    tile: int = 128) -> RetrievedRouteResult:
    """Fused end-to-end routing: per-query candidate features in, tier
    decisions out, with zero host round-trips in between.

    ``feats``: [B, N, Dt] per-query candidate triple features (padded to a
    common N; see `repro.retrieval.scorer.batch_triple_features`).
    ``query_emb``: [B, Dq]. ``params``: the scorer weight dict — its
    layout (``w1_t``/``w1_q``/``b1``/``w2``/``b2``) is the Pallas
    `triple_score` kernel's argument order, making the kernel a drop-in.
    ``n_cand``: optional [B] real candidate counts (ragged retrieval);
    padded rows beyond ``n_cand`` are masked out of the top-k.
    ``use_kernels=False`` runs the identical chain on the XLA reference
    ops (the oracle variant — still one jitted program).

    ``interpret=None`` re-resolves compiled-vs-interpret at every call
    (`repro.kernels.device.default_interpret`), so a policy restored on a
    different host never replays the donor device's choice.
    """
    if interpret is None:
        interpret = default_interpret()
    k = min(config.top_k, feats.shape[1])
    idx, probs, nv, tiers, diff, metrics = _retrieved_program(
        feats, query_emb, params["w1_t"], params["w1_q"], params["b1"],
        params["w2"], params["b2"], jnp.asarray(config.thresholds),
        None if n_cand is None else jnp.asarray(n_cand, jnp.int32),
        top_k=k, metric=config.metric, p_cdf=config.cumulative_p,
        ragged=n_cand is not None, use_kernels=use_kernels,
        interpret=interpret, tile=tile)
    return RetrievedRouteResult(indices=idx, probs=probs, n_valid=nv,
                                tiers=tiers, difficulty=diff, metrics=metrics)


def route_retrieved_staged(feats, query_emb, params: Mapping,
                           config: RouterConfig,
                           n_cand=None) -> RetrievedRouteResult:
    """The readable host-staged reference for :func:`route_retrieved` —
    exactly what the pre-fusion serving path did per request: XLA scoring,
    scores back to host, numpy argsort top-k, sigmoid, then the oracle
    skew metrics and threshold compare. Used by the parity tests and as
    the end-to-end benchmark baseline; never the serving path.
    """
    import numpy as np

    from repro.kernels.skew_metrics.ref import (mask_from_n_valid,
                                                skew_metrics_ref)
    from repro.kernels.triple_score.ref import triple_score_ref

    feats = np.asarray(feats)
    query_emb = np.asarray(query_emb)
    b, n, _ = feats.shape
    k = min(config.top_k, n)
    nc = (np.full(b, n, np.int32) if n_cand is None
          else np.clip(np.asarray(n_cand, np.int32), 1, n))
    idx = np.zeros((b, k), np.int32)
    probs = np.zeros((b, k), np.float32)
    nv = np.minimum(nc, k).astype(np.int32)
    for i in range(b):
        scores = np.asarray(triple_score_ref(
            jnp.asarray(feats[i, :nc[i]]), jnp.asarray(query_emb[i][None]),
            params["w1_t"], params["w1_q"], params["b1"],
            params["w2"], params["b2"]))[0]
        order = np.argsort(-scores, kind="stable")[:k]
        idx[i, :len(order)] = order
        probs[i, :len(order)] = 1.0 / (1.0 + np.exp(-scores[order]))
    mask = mask_from_n_valid(jnp.asarray(nv), k)
    metrics = skew_metrics_ref(jnp.asarray(probs), p_cdf=config.cumulative_p,
                               mask=mask)
    diff = difficulty_from_metrics(metrics, config.metric)
    tiers = route_from_difficulty(diff, jnp.asarray(config.thresholds))
    return RetrievedRouteResult(indices=jnp.asarray(idx),
                                probs=jnp.asarray(probs),
                                n_valid=jnp.asarray(nv), tiers=tiers,
                                difficulty=diff, metrics=metrics)


@dataclasses.dataclass(frozen=True)
class RoutingStats:
    """Aggregate telemetry for a routed batch (exported by the dispatcher)."""

    tier_counts: tuple[int, ...]
    large_call_ratio: float  # fraction sent to the top tier
    mean_difficulty: float

    @staticmethod
    def from_assignments(tiers: jax.Array, n_tiers: int,
                         difficulty: jax.Array) -> "RoutingStats":
        counts = tuple(int(jnp.sum(tiers == t)) for t in range(n_tiers))
        n = max(int(tiers.size), 1)
        return RoutingStats(
            tier_counts=counts,
            large_call_ratio=counts[-1] / n,
            mean_difficulty=float(jnp.mean(difficulty)),
        )


def expected_tier_shares(difficulty: jax.Array,
                         thresholds: Sequence[float]) -> list[float]:
    """Empirical share of traffic per tier for a difficulty sample."""
    tiers = route_from_difficulty(difficulty, jnp.asarray(tuple(thresholds)))
    n = max(int(tiers.size), 1)
    return [float(jnp.sum(tiers == t)) / n for t in range(len(tuple(thresholds)) + 1)]
