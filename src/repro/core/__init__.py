"""SkewRoute core: the paper's primary contribution.

Training-free LLM routing for KG-RAG via score skewness of retrieved
context — skewness metrics, threshold router, training-free calibration,
cost model, and KGQA evaluation.
"""

from repro.core.skewness import (  # noqa: F401
    METRICS,
    all_metrics,
    area_metric,
    cumulative_k,
    difficulty,
    entropy_metric,
    gini_metric,
    normalize_minmax,
    normalize_prob,
)
from repro.core.router import (  # noqa: F401
    RetrievedRouteResult,
    RouteBatchResult,
    RouterConfig,
    RoutingStats,
    difficulty_from_metrics,
    route,
    route_all_metrics,
    route_binary,
    route_from_difficulty,
    route_retrieved,
    route_retrieved_staged,
)
from repro.core.streaming_calibrate import (  # noqa: F401
    DriftEvent,
    SlidingWindow,
    StreamingCalibrator,
)
from repro.core.calibrate import (  # noqa: F401
    SweepPoint,
    calibrate_multi_tier,
    calibrate_threshold,
    oracle_curve,
    random_mix_curve,
    sweep_thresholds,
)
from repro.core.cost import CostModel, PAPER_COST_PER_MTOK, PAPER_QUALITY  # noqa: F401
from repro.core.metrics import batch_metrics, f1_score, hit_at_1  # noqa: F401
