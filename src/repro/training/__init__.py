"""Training substrate: optimizers, schedules, train-step factory,
distributed checkpointing, gradient compression."""
