"""Optimizers in pure JAX: AdamW, Adafactor, Adagrad, SGD(+momentum).

Why hand-rolled: the container has no optax, and the dry-run needs full
control over state dtypes/shardings. Optimizer state inherits the param's
PartitionSpec leaf-for-leaf (fully sharded states — ZeRO-ish by
construction since params are 2D-sharded over (fsdp, model)).

Adafactor (Shazeer & Stern 2018) is the memory play for `arctic-480b`:
factored second moments (row+col statistics instead of a full [E,D,F]
tensor) + optional bf16 momentum — Adam fp32 m+v for 480B params would
need ~3.8 TB, over the 16 GB/chip budget at 256 chips (DESIGN §4).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | adagrad | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999              # adafactor: decay exponent base
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    momentum_dtype: Any = jnp.float32  # bf16 halves momentum memory
    # Scan the update over the layer-stack dim of scan-stacked params.
    # Shrinks fp32 update temporaries L-fold but DEFEATS buffer donation
    # (lax.map outputs are fresh allocations: +params-sized copy; dry-run
    # measured +7 GiB/device on arctic-480b) — off by default, kept as a
    # measured §Perf data point.
    layer_chunked_update: bool = False
    # schedule
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine | linear | constant
    min_lr_ratio: float = 0.1


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------


def learning_rate(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps) /
                        jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:  # linear
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
    return cfg.lr * warm * decay


# ---------------------------------------------------------------------------
# Grad utilities
# ---------------------------------------------------------------------------


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    # scale in the grad's own dtype: an f32 round-trip materializes a full
    # f32 copy of every (sharded) gradient tensor simultaneously
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# State init
# ---------------------------------------------------------------------------


def _factored_dims(shape) -> Optional[tuple[int, int]]:
    """Adafactor factors the last two dims when both are >= 128-ish."""
    if len(shape) < 2 or shape[-1] < 2 or shape[-2] < 2:
        return None
    return (len(shape) - 2, len(shape) - 1)


def init_state(params: Any, cfg: OptimizerConfig) -> dict:
    if cfg.name == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.momentum_dtype), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }
    if cfg.name == "adafactor":
        def vr(p):
            f = _factored_dims(p.shape)
            if f is None:
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros(p.shape[:-1], jnp.float32)       # reduce cols away

        def vc(p):
            f = _factored_dims(p.shape)
            if f is None:
                return jnp.zeros((), jnp.float32)             # unused
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)

        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.momentum_dtype), params),
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
        }
    if cfg.name == "adagrad":
        return {"acc": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}
    if cfg.name == "sgd":
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.momentum_dtype), params)}
    raise ValueError(f"unknown optimizer {cfg.name!r}")


# ---------------------------------------------------------------------------
# Updates
# ---------------------------------------------------------------------------


def state_pspecs(params: Any, param_pspecs: Any, cfg: OptimizerConfig) -> dict:
    """PartitionSpecs for the optimizer state, derived from param specs.

    m/v mirror the param's spec; Adafactor's factored vr/vc drop the last /
    second-to-last sharding entry to match their reduced shapes. (Path-regex
    rules can't do this — a reduced-rank state leaf would mis-bind axes.)
    """
    from jax.sharding import PartitionSpec as P

    if cfg.name in ("adamw",):
        return {"m": param_pspecs, "v": param_pspecs}
    if cfg.name == "adafactor":
        def vr_spec(p, s):
            if _factored_dims(p.shape) is None:
                return s
            return P(*tuple(s)[:-1]) if len(tuple(s)) == p.ndim else s

        def vc_spec(p, s):
            if _factored_dims(p.shape) is None:
                return P()
            t = tuple(s)
            if len(t) == p.ndim:
                return P(*(t[:-2] + t[-1:]))
            return s
        return {
            "m": param_pspecs,
            "vr": jax.tree.map(vr_spec, params, param_pspecs),
            "vc": jax.tree.map(vc_spec, params, param_pspecs),
        }
    if cfg.name == "adagrad":
        return {"acc": param_pspecs}
    if cfg.name == "sgd":
        return {"m": param_pspecs}
    raise ValueError(f"unknown optimizer {cfg.name!r}")


def _leafwise(fn, cfg: OptimizerConfig, *arrays):
    """Apply a per-leaf update, scanning over the layer-stack dim of
    scan-stacked params (ndim >= 3, shared leading dim) when enabled."""
    p = arrays[0]
    if (cfg.layer_chunked_update and p.ndim >= 3
            and all(a.ndim >= 1 and a.shape[:1] == p.shape[:1] for a in arrays)):
        return jax.lax.map(lambda xs: fn(*xs), arrays)
    return fn(*arrays)


def apply_updates(params: Any, grads: Any, state: dict, cfg: OptimizerConfig,
                  step: jax.Array) -> tuple[Any, dict]:
    lr = learning_rate(cfg, step)
    t = step.astype(jnp.float32) + 1.0

    if cfg.name == "adamw":
        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
            v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
            mhat = m_new / (1 - cfg.b1 ** t)
            vhat = v_new / (1 - cfg.b2 ** t)
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m_new.astype(cfg.momentum_dtype), v_new)
        out = jax.tree.map(lambda *a: _leafwise(upd, cfg, *a), params, grads, state["m"], state["v"])
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        p_new = treedef.unflatten([x[0] for x in flat])
        m_new = treedef.unflatten([x[1] for x in flat])
        v_new = treedef.unflatten([x[2] for x in flat])
        return p_new, {"m": m_new, "v": v_new}

    if cfg.name == "adafactor":
        decay = 1.0 - t ** -0.8  # standard adafactor beta2 schedule

        def upd(p, g, m, vr, vc):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + 1e-30
            if _factored_dims(p.shape) is not None:
                vr_new = decay * vr + (1 - decay) * jnp.mean(g2, axis=-1)
                vc_new = decay * vc + (1 - decay) * jnp.mean(g2, axis=-2)
                r = vr_new / jnp.maximum(
                    jnp.mean(vr_new, axis=-1, keepdims=True), 1e-30)
                precond = jax.lax.rsqrt(r)[..., None] * jax.lax.rsqrt(
                    jnp.maximum(vc_new, 1e-30))[..., None, :]
                u = g32 * precond
            else:
                vr_new = decay * vr + (1 - decay) * g2
                vc_new = vc
                u = g32 * jax.lax.rsqrt(jnp.maximum(vr_new, 1e-30))
            # RMS-clip the update (adafactor d=1.0)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
            delta = m_new + cfg.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m_new.astype(cfg.momentum_dtype), vr_new, vc_new)

        out = jax.tree.map(lambda *a: _leafwise(upd, cfg, *a), params, grads, state["m"], state["vr"], state["vc"])
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        return (treedef.unflatten([x[0] for x in flat]),
                {"m": treedef.unflatten([x[1] for x in flat]),
                 "vr": treedef.unflatten([x[2] for x in flat]),
                 "vc": treedef.unflatten([x[3] for x in flat])})

    if cfg.name == "adagrad":
        def upd(p, g, acc):
            g32 = g.astype(jnp.float32)
            acc_new = acc + g32 * g32
            delta = g32 / (jnp.sqrt(acc_new) + cfg.eps)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype), acc_new)
        out = jax.tree.map(lambda *a: _leafwise(upd, cfg, *a), params, grads, state["acc"])
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        return (treedef.unflatten([x[0] for x in flat]),
                {"acc": treedef.unflatten([x[1] for x in flat])})

    if cfg.name == "sgd":
        def upd(p, g, m):
            g32 = g.astype(jnp.float32)
            m_new = cfg.b1 * m.astype(jnp.float32) + g32
            return ((p.astype(jnp.float32) - lr * m_new).astype(p.dtype),
                    m_new.astype(cfg.momentum_dtype))
        out = jax.tree.map(lambda *a: _leafwise(upd, cfg, *a), params, grads, state["m"])
        flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        return (treedef.unflatten([x[0] for x in flat]),
                {"m": treedef.unflatten([x[1] for x in flat])})

    raise ValueError(f"unknown optimizer {cfg.name!r}")
