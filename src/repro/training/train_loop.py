"""Train-step factory: loss -> (grads, clip, optimizer update) as one jit.

The returned ``train_step(state, batch)`` is the unit the dry-run lowers
for every ``train_*`` shape and the unit `launch/train.py` runs. State is
a plain dict pytree (params/opt/step) so sharding rules apply uniformly
and checkpointing is trivial.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.training import optimizer as opt


TrainState = dict  # {"params": ..., "opt": ..., "step": int32 scalar}


def init_train_state(params: Any, opt_cfg: opt.OptimizerConfig) -> TrainState:
    return {
        "params": params,
        "opt": opt.init_state(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }


def make_train_step(loss_fn: Callable[[Any, dict], jax.Array],
                    opt_cfg: opt.OptimizerConfig,
                    accum_steps: int = 1) -> Callable:
    """loss_fn(params, batch) -> scalar. Returns train_step(state, batch).

    With ``accum_steps > 1`` the batch's leading dim is split into
    microbatches scanned sequentially (gradient accumulation) — the
    standard trick to fit global batch when activations dominate.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def accum_grads(params, batch):
        def micro(b):
            return jax.tree.map(lambda x: x.reshape(
                (accum_steps, x.shape[0] // accum_steps) + x.shape[1:]), b)

        micro_batches = micro(batch)

        def step_fn(carry, mb):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, mb)
            grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
            return (loss_acc + loss, grad_acc), None

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grad_sum), _ = jax.lax.scan(
            step_fn, (jnp.zeros((), jnp.float32), zero), micro_batches)
        scale = 1.0 / accum_steps
        return loss_sum * scale, jax.tree.map(lambda g: g * scale, grad_sum)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if accum_steps > 1:
            loss, grads = accum_grads(state["params"], batch)
        else:
            loss, grads = grads_of(state["params"], batch)
        grads, gnorm = opt.clip_by_global_norm(grads, opt_cfg.grad_clip)
        params, opt_state = opt.apply_updates(
            state["params"], grads, state["opt"], opt_cfg, state["step"])
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.learning_rate(opt_cfg, state["step"])}
        return new_state, metrics

    return train_step
