"""Distributed checkpointing: sharded save/restore with atomic commits.

No orbax in this container, so the manager is built from first principles
the way production ones are:

* one ``.npy`` file per pytree leaf (per-host shard in a real multi-host
  run — the leaf is saved from the addressable shards), named by a
  flattened tree path;
* a JSON manifest holding the tree structure, shapes, dtypes, step and
  the sharding spec string of every leaf (restore validates against it);
* **atomic commit**: everything is written into ``<dir>/tmp.<step>`` and
  os.rename()d to ``<dir>/step_<step>`` — a torn write can never be
  mistaken for a valid checkpoint (rename is atomic on POSIX);
* an **async writer** thread so training doesn't stall on I/O
  (``save(..., blocking=False)``); ``wait()`` joins before the next save;
* retention of the newest ``keep`` checkpoints;
* ``latest_step`` / ``restore`` for crash-restart (the fault-tolerance
  manager's recovery path).
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        self.wait()
        # Device -> host transfer happens on the caller's thread (cheap,
        # and keeps jax out of the writer thread); serialization + fsync +
        # rename run async.
        host_leaves = [(name, np.asarray(leaf))
                       for name, leaf in _flatten(state)]
        treedef = jax.tree_util.tree_structure(state)

        def _write():
            tmp = self.dir / f"tmp.{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            manifest = {"step": int(step), "leaves": [], "keep": self.keep,
                        "treedef": str(treedef)}
            for i, (name, arr) in enumerate(host_leaves):
                fname = f"leaf_{i:05d}.npy"
                np.save(tmp / fname, arr)
                manifest["leaves"].append({
                    "name": name, "file": fname,
                    "shape": list(arr.shape), "dtype": str(arr.dtype)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self.dir / f"step_{step:08d}"
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic commit
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None) -> Any:
        """Restore into the structure (and shardings) of ``target``.

        ``target`` may be a pytree of arrays or ShapeDtypeStructs; leaves
        are validated against the manifest and device_put with the
        target leaf's sharding when present.
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())
        names = {e["name"]: e for e in manifest["leaves"]}
        flat_t = _flatten(target)
        treedef = jax.tree_util.tree_structure(target)
        leaves = []
        for name, leaf in flat_t:
            if name not in names:
                raise KeyError(f"checkpoint missing leaf {name!r}")
            entry = names[name]
            arr = np.load(cdir / entry["file"])
            leaf_shape = tuple(getattr(leaf, "shape", np.shape(leaf)))
            if tuple(arr.shape) != leaf_shape:
                raise ValueError(f"shape mismatch for {name}: "
                                 f"{arr.shape} vs {leaf_shape}")
            dtype = getattr(leaf, "dtype", arr.dtype)
            sharding = getattr(leaf, "sharding", None)
            if sharding is not None and not isinstance(
                    leaf, jax.ShapeDtypeStruct):
                leaves.append(jax.device_put(arr.astype(dtype), sharding))
            elif isinstance(leaf, (int, float, bool)):
                leaves.append(type(leaf)(arr.item()))
            else:
                leaves.append(jax.numpy.asarray(arr.astype(dtype)))
        return jax.tree_util.tree_unflatten(treedef, leaves)
