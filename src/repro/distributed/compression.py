"""Gradient compression for the cross-pod (DCN) reduction.

On the multi-pod mesh the ``pod`` axis crosses data-center network, not
ICI; a bf16 all-reduce there costs ~25 GB/step for arctic-480b. This
module implements the standard mitigation: **int8 block-quantized
all-gather with error feedback** —

  1. residual-corrected grad  g' = g + e   (error feedback buffer e)
  2. per-block (128) absmax scales; int8 quantize
  3. all_gather(int8) over the pod axis (half the bytes of bf16,
     quarter of f32), dequantize, mean
  4. e <- g' - dequant(quant(g'))  (what compression lost, re-injected
     next step — keeps SGD convergence, Karimireddy et al. 2019)

Exposed as a shard_map transform over a per-pod-grads function, plus
raw quantize/dequantize utilities (property-tested in
tests/test_compression.py: error-feedback residual decays the bias).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

BLOCK = 128


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-128-block absmax int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = -flat.shape[0] % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def compress_decompress(x: jax.Array) -> jax.Array:
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.shape, x.dtype)


def apply_error_feedback(grads: Any, residual: Any) -> tuple[Any, Any]:
    """(grads+residual, new_residual) after a quantize/dequantize round."""
    corrected = jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, residual)
    compressed = jax.tree.map(compress_decompress, corrected)
    new_residual = jax.tree.map(lambda c, q: (c - q).astype(jnp.float32),
                                corrected, compressed)
    return compressed, new_residual


def cross_pod_mean_int8(mesh, axis: str = "pod"):
    """shard_map transform: int8 all-gather mean over the pod axis.

    Input: per-pod gradient pytree (replicated within the pod, distinct
    across pods). Output: cross-pod mean, computed by exchanging int8.
    """
    def transform(grads: Any) -> Any:
        def body(g_tree):
            def one(g):
                q, s = quantize_int8(g)
                qg = jax.lax.all_gather(q, axis)          # [pods, blocks, B]
                sg = jax.lax.all_gather(s, axis)
                deq = jax.vmap(lambda qq, ss: dequantize_int8(
                    qq, ss, g.shape, jnp.float32))(qg, sg)
                return jnp.mean(deq, axis=0).astype(g.dtype)
            return jax.tree.map(one, g_tree)

        specs = jax.tree.map(lambda _: P(), grads)
        # replication check off: the int8 gather+mean provably replicates
        # the result across the pod axis, but the varying-manual-axes
        # checker can't see through the quantize/dequantize round trip.
        return _shard_map(body, mesh, (specs,), specs)(grads)
    return transform


def _shard_map(body, mesh, in_specs, out_specs):
    from repro.distributed.sharding import shard_map_compat
    return shard_map_compat(body, mesh, in_specs, out_specs)
