"""Rules-based logical-axis sharding.

Model code annotates activations with *logical* axis names
(``shd.logical(x, "batch", None, "model")``); a rules table maps logical
names to physical mesh axes. Outside any rules context the annotations are
identity — the same model code runs on one CPU device (tests) and on the
(pod, data, model) production mesh (dry-run / deployment) unchanged.

Parameter sharding is by naming convention (`param_pspec`): the tree path
of each weight decides its PartitionSpec (e.g. ``wq [D, H*Dh]`` is
(fsdp-in, tensor-out)-sharded). Stacked scan layers get a leading None.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, tuple[str, ...]]

_state = threading.local()


# ---------------------------------------------------------------------------
# Logical axis rules
# ---------------------------------------------------------------------------

#: Default logical->physical mapping for the production mesh.
#: "batch" covers the pod axis too when present (pure DP across pods).
DEFAULT_RULES: dict[str, Axis] = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),  # flattened (batch*seq) token dim
    "dp": ("pod", "data"),      # strictly data axes (MoE dispatch groups)
    "seq": None,           # sequence-parallel off in the baseline
    "model": "model",      # TP: attention heads-merged dim, d_ff, vocab
    "kv_seq": "model",     # KV-cache seq axis (split-KV decode layout)
    "fsdp": ("pod", "data"),  # ZeRO-3 weight sharding; spans pods on the
                              # multi-pod mesh (multislice FSDP over DCN)
    "expert": "model",     # EP shares the model axis
    "edge": ("pod", "data"),   # GNN edge-parallel
    "node": None,          # GNN node features replicated in the baseline
    "table": ("pod", "data", "model"),  # recsys embedding rows (all devices)
    "candidate": "model",  # retrieval candidate scoring
    "request": ("pod", "data"),  # routing dispatch batch (serving fan-out)
}


#: Training adds Megatron-SP-style sequence sharding of the residual
#: stream at layer boundaries (saved scan carries shrink 16x).
TRAIN_RULES: dict[str, Axis] = {**DEFAULT_RULES, "seq": "model",
                                "tokens": ("pod", "data", "model")}


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _resolve(axis: Axis, mesh: Mesh) -> Axis:
    """Drop physical axes the mesh doesn't have (e.g. 'pod' single-pod)."""
    if axis is None:
        return None
    names = _mesh_axes(mesh)
    if isinstance(axis, str):
        return axis if axis in names else None
    kept = tuple(a for a in axis if a in names)
    return kept if kept else None


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict[str, Axis]] = None):
    """Activate sharding annotations for model code traced inside."""
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, dict(DEFAULT_RULES if rules is None else rules))
    try:
        with mesh:
            yield
    finally:
        _state.ctx = prev


def active_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def mesh_axis_size(name: str) -> int:
    """Size of a physical mesh axis under the active mesh (1 if absent)."""
    mesh = active_mesh()
    if mesh is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get(name, 1)


def spec_for(*logical_axes: Optional[str]) -> Optional[P]:
    """PartitionSpec for logical axes under the active rules, else None."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, rules = ctx
    phys = []
    for ax in logical_axes:
        if ax is None:
            phys.append(None)
        else:
            if ax not in rules:
                raise KeyError(f"unknown logical axis {ax!r}; rules: {sorted(rules)}")
            phys.append(_resolve(rules[ax], mesh))
    return P(*phys)


def logical(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; identity w/o rules.

    Axes that don't divide the corresponding dim are dropped (batch==1
    decode, 47-class heads, ...) so the same model code traces for every
    cell shape.
    """
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = spec_for(*logical_axes)
    guarded = tuple(
        ax if (ax is None or dim % _axis_size(mesh, ax) == 0) else None
        for ax, dim in zip(tuple(spec), x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*guarded)))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, spec_for(*logical_axes))


def shard_map_compat(body, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` across jax versions: ``jax.shard_map(check_vma=...)``
    on current jax, ``jax.experimental.shard_map(check_rep=...)`` on 0.4.x.

    Replication checking is off in both spellings: the callers here
    (int8 gather+mean in ``distributed.compression``, the sharded
    dispatch backend) provably replicate what they claim, but the
    varying-manual-axes checker can't see through quantize/dequantize
    round trips or gathered top-k.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm_experimental
    return sm_experimental(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Parameter sharding by tree-path convention
# ---------------------------------------------------------------------------

#: (path regex, logical axes per trailing dim). Longest match wins; a
#: leading scan/stack dim (params under "layers" or per-table stacks) is
#: handled by left-padding Nones to the array rank.
_PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    (r"embed$", ("model", "fsdp")),          # [V, D] vocab-TP + fsdp
    (r"lm_head$", ("fsdp", "model")),        # [D, V]
    (r"attn/w[qkv]$", ("fsdp", "model")),    # [D, H*Dh] col-parallel
    (r"attn/wo$", ("model", "fsdp")),        # [H*Dh, D] row-parallel
    (r"(mlp|shared)/w_(gate|up)$", ("fsdp", "model")),
    (r"(mlp|shared)/w_down$", ("model", "fsdp")),
    (r"moe/router$", ("fsdp", None)),
    # EP owns the model axis; the within-expert dims use fsdp only
    (r"moe/w_(gate|up)$", ("expert", "fsdp", None)),     # [E, D, F]
    (r"moe/w_down$", ("expert", None, "fsdp")),          # [E, F, D]
    (r"ln_\w+$", (None,)),
    # --- GNN: weights are tiny (8x8 heads) — replicate ---
    (r"gnn/", ()),
    # --- recsys ---
    (r"tables$", ("table", None)),            # [sum_vocab, dim] row-sharded
    (r"fm/w1$", ("table", None)),             # first-order FM weights
    (r"(bot|top|deep|mlp|cross|fm|gru|augru)\w*/w\d*$", ("fsdp", "model")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axis, str):
        return sizes.get(axis, 1)
    n = 1
    for a in axis:
        n *= sizes.get(a, 1)
    return n


def param_pspec(path, leaf) -> P:
    """PartitionSpec for one parameter leaf by its tree path.

    jit in_shardings require every sharded dim to divide evenly; axes whose
    size doesn't divide the dim are dropped (e.g. a [64, 47] GAT head or a
    [13, 512] DLRM bottom-MLP stays replicated on that dim).
    """
    name = _path_str(path)
    shape = tuple(getattr(leaf, "shape", ()))
    ndim = len(shape)
    for pattern, axes in _PARAM_RULES:
        if re.search(pattern, name):
            ctx = getattr(_state, "ctx", None)
            if ctx is None:
                return P()
            mesh, rules = ctx
            phys = tuple(
                _resolve(rules.get(a), mesh) if a is not None else None
                for a in axes)
            pad = ndim - len(phys)
            if pad < 0:  # rank-deficient leaf (e.g. scalar) — replicate
                return P()
            full = (None,) * pad + phys
            guarded = tuple(
                ax if (ax is not None and dim % _axis_size(mesh, ax) == 0)
                else None
                for ax, dim in zip(full, shape))
            return P(*guarded)
    return P()  # replicate by default (norm scales, biases, scalars)


def tree_pspecs(tree: Any) -> Any:
    """Pytree of PartitionSpecs matching ``tree``'s structure."""
    return jax.tree_util.tree_map_with_path(lambda p, l: param_pspec(p, l), tree)


def tree_shardings(tree: Any, mesh: Optional[Mesh] = None) -> Any:
    mesh = mesh or active_mesh()
    if mesh is None:
        raise RuntimeError("no active mesh; wrap in shd.use_mesh(mesh)")
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_pspec(p, l)), tree)
