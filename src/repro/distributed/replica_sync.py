"""Replica state-sync: delta-compressed calibrator exchange + the
deterministic weighted-quantile merge.

The millions-of-users deployment runs N routing replicas behind a load
balancer, each seeing a biased slice of traffic (sticky sessions, geo
affinity, whatever the balancer hashes on). Per-replica streaming
calibration then converges each replica to thresholds for ITS slice —
the fleet's tier shares drift apart and the global budget is violated
even though every replica believes it is on target. Learned routers fix
this with centralized retraining; SkewRoute's whole state is a few
thousand window floats and a threshold tuple, so the fix is snapshot
exchange:

1. **Publish** (:meth:`SyncEndpoint.publish`): each replica ships the
   window samples it accumulated since its last publish — the DELTA, not
   the window — int8 block-quantized via `distributed.compression`
   (4x smaller than f32; difficulty values span a few units, so the
   absmax block scale costs ~1e-2 absolute error, far below threshold
   granularity). The payload is JSON-serializable and stamped with the
   policy fingerprint, so state can never silently cross policies.
2. **Receive** (:meth:`SyncEndpoint.receive`): deltas land in per-origin
   replay buffers. Crucially the publisher feeds its OWN delta through
   the same quantize/dequantize round trip into its own buffer — every
   endpoint holding the same delta set then has bit-identical buffers,
   which makes the merge a deterministic function of the payloads alone.
3. **Merge** (:meth:`SyncEndpoint.merge`): a weighted quantile over the
   union of the replay buffers — each origin's samples weighted by its
   lifetime traffic share, so a cold replica's thin window doesn't drag
   the fleet — cut at ``cumsum(target_shares)[:-1]``, exactly the rule
   `StreamingCalibrator.fit_config` applies locally. The merged config
   is hot-swapped through the ONE existing path
   (``dispatcher.apply_config``), and the local drift loop's cooldown is
   re-armed so it doesn't immediately refit from its biased local window
   and undo the merge.

Everything here is host-side numpy + JSON: the fabric in
`serving.fabric` drives it in-process, and the same payloads could ride
any real transport (the delta dict IS the wire format).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.streaming_calibrate import SlidingWindow

__all__ = ["StateDelta", "SyncEndpoint", "merge_admission",
           "weighted_quantile", "delta_nbytes"]


def _quantize(samples: np.ndarray) -> tuple[list[int], list[float]]:
    """int8 block-quantize a float sample vector via
    `distributed.compression.quantize_int8`. The quantizer pads the last
    block with zeros, which quantize to exactly 0 — so the wire carries
    only the first ``len(samples)`` values and the decoder re-pads,
    keeping small deltas smaller than raw f32 instead of paying a full
    128-value block."""
    from repro.distributed.compression import quantize_int8
    q, scales = quantize_int8(np.asarray(samples, np.float32))
    flat = np.asarray(q).ravel()[:len(samples)]
    return ([int(v) for v in flat],
            [float(s) for s in np.asarray(scales)])


def _dequantize(q: Sequence[int], scales: Sequence[float],
                n: int) -> np.ndarray:
    from repro.distributed.compression import BLOCK, dequantize_int8
    qa = np.zeros(len(scales) * BLOCK, np.int8)
    qa[:n] = np.asarray(q, np.int8)
    sa = np.asarray(scales, np.float32)
    return np.asarray(dequantize_int8(qa.reshape(-1, BLOCK), sa,
                                      (n,), np.float32))


@dataclasses.dataclass(frozen=True)
class StateDelta:
    """One replica's sync payload: the calibrator-window samples it
    accumulated since its previous publish, int8-compressed, plus the
    counters the merge weights by. ``to_dict``/``from_dict`` are the
    wire format (plain JSON)."""

    replica: str
    seq: int                         # publisher's sync-round counter
    policy_fingerprint: str
    from_seen: int                   # window.total_seen at previous publish
    to_seen: int                     # ... and at this one
    n_samples: int                   # samples actually shipped (<= window)
    q: tuple[int, ...]               # int8 blocks, flattened
    scales: tuple[float, ...]        # per-128-block absmax scales
    thresholds: tuple[float, ...]    # publisher's live thresholds (telemetry)
    # Admission-controller view (AdmissionController.sync_state():
    # per-tier pressure/spill, $/query EWMA, target shares, n_seen) —
    # None for sessions without admission AND on legacy wire payloads
    # that predate the block, which merge exactly as before.
    admission: Optional[Mapping] = None

    def samples(self) -> np.ndarray:
        if self.n_samples == 0:
            return np.empty(0, np.float32)
        return _dequantize(self.q, self.scales, self.n_samples)

    def to_dict(self) -> dict:
        d = {
            "replica": self.replica, "seq": self.seq,
            "policy_fingerprint": self.policy_fingerprint,
            "from_seen": self.from_seen, "to_seen": self.to_seen,
            "n_samples": self.n_samples,
            "q": list(self.q), "scales": list(self.scales),
            "thresholds": list(self.thresholds),
        }
        if self.admission is not None:
            d["admission"] = dict(self.admission)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "StateDelta":
        adm = d.get("admission")  # absent on legacy deltas
        return cls(replica=str(d["replica"]), seq=int(d["seq"]),
                   policy_fingerprint=str(d["policy_fingerprint"]),
                   from_seen=int(d["from_seen"]), to_seen=int(d["to_seen"]),
                   n_samples=int(d["n_samples"]),
                   q=tuple(int(v) for v in d["q"]),
                   scales=tuple(float(s) for s in d["scales"]),
                   thresholds=tuple(float(t) for t in d["thresholds"]),
                   admission=None if adm is None else dict(adm))


def delta_nbytes(delta: StateDelta) -> tuple[int, int]:
    """(compressed, raw-f32) wire sizes of a delta's sample payload."""
    return len(delta.q) + 4 * len(delta.scales), 4 * delta.n_samples


def weighted_quantile(values: np.ndarray, weights: np.ndarray,
                      qs: Sequence[float]) -> np.ndarray:
    """Deterministic weighted quantiles (midpoint / type-7-like rule).

    Stable mergesort + cumulative midpoint weights + linear
    interpolation: a pure function of (values, weights) with no RNG and
    no platform-dependent reduction order, so every replica computing it
    over the same payload set gets bit-identical cuts. With equal
    weights it agrees with ``np.quantile`` to O(1/n) (midpoint positions
    vs type-7's endpoint positions) — determinism is the contract here,
    not a particular interpolation family.
    """
    v = np.asarray(values, np.float64)
    w = np.asarray(weights, np.float64)
    if v.size == 0:
        raise ValueError("weighted_quantile over zero samples")
    if v.shape != w.shape:
        raise ValueError(f"values {v.shape} vs weights {w.shape}")
    if np.any(w < 0) or not np.all(np.isfinite(w)):
        raise ValueError("weights must be finite and >= 0")
    total = w.sum()
    if total <= 0:               # degenerate: fall back to equal weights
        w = np.ones_like(w)
        total = w.sum()
    order = np.argsort(v, kind="stable")
    v, w = v[order], w[order]
    cum = np.cumsum(w) - 0.5 * w          # midpoint of each sample's mass
    pos = cum / total
    return np.interp(np.asarray(qs, np.float64), pos, v)


def merge_admission(views: Sequence[Mapping]) -> dict:
    """Deterministic fleet-wide admission view from per-replica
    ``sync_state`` blocks (pass them in a canonical order — the fabric
    sorts by origin name — and every replica computes the same merge):

    * per-tier **pressure** takes the max (saturation anywhere in the
      fleet is saturation: the load balancer can route any request to
      the hot replica's pool) and **spill** ORs — so replicas can't
      disagree about spill during a burst;
    * the **$/query EWMA** and **target shares** are traffic-weighted
      means (by ``n_seen``), shares renormalized — the fleet's realized
      spend and quantile aim, not the loudest replica's.
    """
    if not views:
        raise ValueError("merge_admission over zero views")
    tiers = sorted({t for v in views for t in v["tier_pressure"]}, key=int)
    pressure = {t: max(float(v["tier_pressure"].get(t, 0.0)) for v in views)
                for t in tiers}
    spill = {t: any(bool(v["tier_spill"].get(t, False)) for v in views)
             for t in tiers}
    weights = np.asarray([max(int(v["n_seen"]), 0) for v in views],
                         np.float64)
    if weights.sum() <= 0:
        weights = np.ones(len(views), np.float64)
    weights = weights / weights.sum()
    cpqs = [(w, float(v["cost_per_query"]))
            for w, v in zip(weights, views) if v["cost_per_query"] is not None]
    cost = (None if not cpqs
            else float(sum(w * c for w, c in cpqs)
                       / sum(w for w, _ in cpqs)))
    share_mat = np.asarray([[float(s) for s in v["shares"]] for v in views],
                           np.float64)
    shares = weights @ share_mat
    shares = shares / shares.sum()
    return {
        "tier_pressure": pressure,
        "tier_spill": spill,
        "cost_per_query": cost,
        "shares": [float(s) for s in shares],
        "n_seen": int(sum(int(v["n_seen"]) for v in views)),
    }


class SyncEndpoint:
    """One replica's half of the sync fabric: publishes deltas of its own
    calibrator window, replays peers' deltas into per-origin buffers, and
    merges the union into fleet-consistent thresholds.

    ``peer_window`` bounds each origin's replay buffer (default: the
    local calibrator's window capacity) — sync traffic is windowed the
    same way local traffic is, so stale eras age out of the merge.
    """

    def __init__(self, name: str, session, *,
                 peer_window: Optional[int] = None):
        from repro.api.spec import policy_fingerprint
        from repro.obs import NULL_OBS
        self.name = str(name)
        self.session = session
        # Sync traffic rides the session's observability plane: wire
        # volume as counters, publish/merge as trace events (no-ops on
        # obs-less sessions).
        self.obs = getattr(session, "obs", None) or NULL_OBS
        mx = self.obs.metrics
        self._m_bytes = mx.counter("fabric_bytes_sent_total",
                                   replica=self.name)
        self._m_bytes_raw = mx.counter("fabric_bytes_raw_total",
                                       replica=self.name)
        self._m_publishes = mx.counter("fabric_publishes_total",
                                       replica=self.name)
        self._m_merges = mx.counter("fabric_merges_total",
                                    replica=self.name)
        cal = session.calibrator
        if cal is None:
            raise ValueError(
                f"replica {name!r} has no streaming calibrator — sync "
                f"exchanges calibrator windows; use "
                f"CalibrationSpec(policy='streaming')")
        self.fingerprint = policy_fingerprint(session.spec)
        self.peer_window = int(peer_window or cal.window.capacity)
        self.seq = 0
        # Publish starts from the window as it stands at join: samples a
        # bootstrap restored into it are the SOURCE replica's traffic
        # (already published under its name) — republishing them here
        # would double-count that distribution in every merge.
        self._published_seen = cal.window.total_seen
        self.buffers: dict[str, SlidingWindow] = {}
        self.traffic: dict[str, int] = {}  # origin -> lifetime total_seen
        # origin -> latest admission sync_state block (empty for fleets
        # without admission control or running legacy peers)
        self.adm_views: dict[str, dict] = {}
        self.n_merges = 0
        self.bytes_sent = 0
        self.bytes_sent_raw = 0

    # -- bootstrap ------------------------------------------------------------

    def adopt_view(self, src: "SyncEndpoint") -> None:
        """Inherit ``src``'s replay buffers and traffic counters (the
        bootstrap path). A joiner that warm-starts from a member's
        state-half must also merge from that member's view of the fleet:
        with empty buffers its weighted-quantile merge disagrees with
        everyone else's until every origin's buffer fully turns over,
        and the fleet loses its replicas-agree-exactly property for that
        whole stretch. After a full-mesh round all members hold
        identical buffers, so any member's view is THE fleet view."""
        if src.fingerprint != self.fingerprint:
            raise ValueError(
                f"cannot adopt peer view across policies "
                f"({src.fingerprint!r} vs {self.fingerprint!r})")
        for origin, buf in src.buffers.items():
            mine = SlidingWindow(buf.capacity)
            mine.load_state_dict(buf.state_dict())
            self.buffers[origin] = mine
        self.traffic.update(src.traffic)
        self.adm_views.update({o: dict(v)
                               for o, v in src.adm_views.items()})

    # -- publish --------------------------------------------------------------

    def publish(self) -> dict:
        """This replica's delta since its last publish, as the JSON wire
        dict. Also self-receives it (through the same quantize round
        trip), so local samples enter the merge exactly as peers see
        them."""
        cal = self.session.calibrator
        win = cal.window
        fresh = min(win.total_seen - self._published_seen, win.capacity)
        samples = win.recent(fresh)
        q, scales = (_quantize(samples) if samples.size else ([], []))
        admission = getattr(self.session, "admission", None)
        delta = StateDelta(
            replica=self.name, seq=self.seq,
            policy_fingerprint=self.fingerprint,
            from_seen=self._published_seen, to_seen=win.total_seen,
            n_samples=int(samples.size),
            q=tuple(q), scales=tuple(scales),
            thresholds=tuple(self.session.thresholds),
            admission=(None if admission is None
                       else admission.sync_state()))
        self._published_seen = win.total_seen
        self.seq += 1
        comp, raw = delta_nbytes(delta)
        self.bytes_sent += comp
        self.bytes_sent_raw += raw
        self._m_publishes.inc()
        self._m_bytes.inc(comp)
        self._m_bytes_raw.inc(raw)
        if self.obs.enabled:
            self.obs.tracer.event(
                "sync_publish", replica=self.name, seq=delta.seq,
                n_samples=delta.n_samples, bytes=comp, bytes_raw=raw)
        self.receive(delta.to_dict())
        return delta.to_dict()

    # -- receive --------------------------------------------------------------

    def receive(self, payload: Mapping) -> None:
        """Replay one delta (wire dict or :class:`StateDelta`) into its
        origin's buffer. Policy mismatches are refused loudly; stale or
        replayed sequence numbers are dropped idempotently."""
        delta = (payload if isinstance(payload, StateDelta)
                 else StateDelta.from_dict(payload))
        if delta.policy_fingerprint != self.fingerprint:
            raise ValueError(
                f"delta from {delta.replica!r} carries policy fingerprint "
                f"{delta.policy_fingerprint!r} but replica {self.name!r} "
                f"runs {self.fingerprint!r}; state never transfers across "
                f"policies")
        last = self.traffic.get(delta.replica)
        if last is not None and delta.to_seen <= last:
            return                        # duplicate / out-of-order replay
        buf = self.buffers.get(delta.replica)
        if buf is None:
            buf = self.buffers[delta.replica] = SlidingWindow(
                self.peer_window)
        if delta.n_samples:
            buf.push(delta.samples())
        self.traffic[delta.replica] = delta.to_seen
        if delta.admission is not None:
            self.adm_views[delta.replica] = dict(delta.admission)

    # -- merge ----------------------------------------------------------------

    def merge(self, apply: bool = True):
        """Weighted-quantile thresholds over every origin's replay buffer
        (self included). Returns the merged :class:`RouterConfig`, or
        ``None`` while the union holds fewer samples than the local
        calibrator's ``min_samples`` floor.

        ``apply=True`` hot-swaps it through ``dispatcher.apply_config``
        and re-arms the drift cooldown (a merge IS a swap: the local
        loop judging drift right after would mix pre-merge samples with
        post-merge thresholds).
        """
        cal = self.session.calibrator
        parts, weights = [], []
        for origin in sorted(self.buffers):
            vals = self.buffers[origin].values()
            if vals.size == 0:
                continue
            # chronological tail not needed for quantiles; per-sample
            # weight = origin's lifetime traffic spread over its buffer
            share = float(self.traffic.get(origin, 0))
            parts.append(vals)
            weights.append(np.full(vals.size, share / vals.size
                                   if share > 0 else 0.0))
        if not parts:
            return None
        values = np.concatenate(parts)
        if values.size < cal.min_samples:
            return None
        w = np.concatenate(weights)
        # Adopt the fleet admission view FIRST (when we run admission and
        # peers published blocks): pressure/spill max-OR so the fleet
        # can't disagree about spill mid-burst, and — crucially — the
        # merged target shares land in calibrator.target_shares BEFORE
        # the cuts below are taken, so thresholds aim at the fleet's
        # shares, not this replica's possibly-stale local tightening.
        admission = getattr(self.session, "admission", None)
        if apply and admission is not None and self.adm_views:
            admission.adopt_sync(merge_admission(
                [self.adm_views[o] for o in sorted(self.adm_views)]))
        cuts = np.cumsum(cal.target_shares)[:-1]
        ts = [float(t) for t in weighted_quantile(values, w, cuts)]
        for i in range(1, len(ts)):       # ties can collapse; keep ascending
            ts[i] = max(ts[i], ts[i - 1])
        merged = dataclasses.replace(cal.config, thresholds=tuple(ts))
        if apply:
            # The merged sample union is also the policy-refit quantile
            # source: replicas holding identical buffers re-fit their
            # policy cutoffs (cascade escalation, depth buckets) to
            # identical values in the same round.
            self.session.dispatcher.apply_config(
                merged,
                quantile_source=lambda qs: weighted_quantile(values, w, qs))
            cal._last_swap_at = cal.window.total_seen
            self.n_merges += 1
            self._m_merges.inc()
            if self.obs.enabled:
                self.obs.tracer.event(
                    "sync_merge", replica=self.name,
                    n_origins=len(self.buffers),
                    n_samples=int(values.size),
                    thresholds=[float(t) for t in merged.thresholds])
        return merged

    # -- telemetry ------------------------------------------------------------

    def telemetry(self) -> dict:
        return {
            "replica": self.name,
            "seq": self.seq,
            "n_merges": self.n_merges,
            "bytes_sent": self.bytes_sent,
            "bytes_sent_raw": self.bytes_sent_raw,
            "compression_ratio": (self.bytes_sent_raw
                                  / max(self.bytes_sent, 1)),
            "origins": {o: {"buffered": len(b),
                            "traffic": self.traffic.get(o, 0)}
                        for o, b in sorted(self.buffers.items())},
            "thresholds": [float(t) for t in self.session.thresholds],
        }
