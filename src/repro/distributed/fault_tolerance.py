"""Fault tolerance: failure detection, checkpoint/restart, elastic remesh.

The coordinator pattern used at multi-pod scale, runnable in-process for
tests (failures injected via `inject_failure`):

* **Heartbeats**: every worker (host) reports each step; a worker silent
  for ``timeout_steps`` is declared dead.
* **Recovery plan**: on failure the coordinator picks the restart point
  (latest committed checkpoint — commits are atomic, see
  training/checkpoint.py), the surviving worker set, and an **elastic
  mesh**: the data axis shrinks to the largest divisor-of-batch size the
  survivors support; the model axis never shrinks (TP state is not
  re-shardable without weights movement, so losing a model-column peer
  means waiting for a replacement — this matches production practice).
* **Straggler mitigation** (training): synchronous-with-backup — workers
  whose step latency exceeds ``straggler_factor`` x median get flagged;
  the plan reassigns their data shard to a hot spare. (Serving-side
  mitigation lives in serving/scheduler.py as deadline re-dispatch.)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_step: int = -1
    last_beat: float = 0.0
    step_latency: float = 0.0
    alive: bool = True
    is_spare: bool = False


@dataclasses.dataclass
class RecoveryPlan:
    restart_step: int
    survivors: list[int]
    new_data_parallel: int
    reassigned_shards: dict[int, int]   # failed worker -> replacement
    notes: str = ""


class FaultToleranceManager:
    def __init__(self, n_workers: int, data_parallel: int, model_parallel: int,
                 timeout_steps: int = 3, straggler_factor: float = 2.0,
                 n_spares: int = 0):
        self.workers = {i: WorkerState(i) for i in range(n_workers + n_spares)}
        for i in range(n_workers, n_workers + n_spares):
            self.workers[i].is_spare = True
        self.n_active = n_workers
        self.dp = data_parallel
        self.mp = model_parallel
        self.timeout_steps = timeout_steps
        self.straggler_factor = straggler_factor
        self.global_step = 0

    # -- heartbeat ingestion --------------------------------------------------

    def heartbeat(self, worker_id: int, step: int,
                  latency_s: float = 0.0, now: Optional[float] = None) -> None:
        w = self.workers[worker_id]
        w.last_step = step
        w.last_beat = time.monotonic() if now is None else now
        w.step_latency = latency_s
        self.global_step = max(self.global_step, step)

    def inject_failure(self, worker_id: int) -> None:
        self.workers[worker_id].alive = False

    # -- detection -------------------------------------------------------------

    def dead_workers(self) -> list[int]:
        return [w.worker_id for w in self.workers.values()
                if not w.is_spare and (
                    not w.alive
                    or self.global_step - w.last_step > self.timeout_steps)]

    def stragglers(self) -> list[int]:
        lats = [w.step_latency for w in self.workers.values()
                if w.alive and not w.is_spare and w.step_latency > 0]
        if len(lats) < 2:
            return []
        med = float(np.median(lats))
        return [w.worker_id for w in self.workers.values()
                if w.alive and not w.is_spare
                and w.step_latency > self.straggler_factor * med]

    # -- recovery --------------------------------------------------------------

    def make_recovery_plan(self, latest_checkpoint_step: int) -> RecoveryPlan:
        dead = set(self.dead_workers())
        spares = [w.worker_id for w in self.workers.values()
                  if w.is_spare and w.alive]
        reassigned = {}
        for d in sorted(dead):
            if spares:
                s = spares.pop(0)
                reassigned[d] = s
                self.workers[s].is_spare = False
        still_dead = dead - set(reassigned)
        survivors = [w.worker_id for w in self.workers.values()
                     if w.alive and not w.is_spare
                     and w.worker_id not in still_dead]
        # data axis shrinks by whole model-columns: each lost worker kills
        # its model-parallel column for training purposes
        lost_columns = -(-len(still_dead) // self.mp) if still_dead else 0
        new_dp = self.dp - lost_columns
        notes = (f"{len(dead)} failures, {len(reassigned)} absorbed by "
                 f"spares, dp {self.dp}->{new_dp}")
        return RecoveryPlan(restart_step=latest_checkpoint_step,
                            survivors=survivors, new_data_parallel=new_dp,
                            reassigned_shards=reassigned, notes=notes)

    def elastic_batch_plan(self, global_batch: int, new_dp: int) -> dict:
        """Keep the global batch by rebalancing per-shard batch (divisor-
        aware); callers rebuild the mesh + data shards from this."""
        per = global_batch // max(new_dp, 1)
        return {"data_parallel": new_dp, "per_shard_batch": per,
                "global_batch": per * new_dp,
                "dropped": global_batch - per * new_dp}
