"""Distribution substrate: sharding rules, fault tolerance, compression,
and the replica state-sync exchange (`replica_sync` — delta-compressed
calibrator windows + the deterministic weighted-quantile merge that
`serving.fabric.ReplicaFabric` drives)."""
