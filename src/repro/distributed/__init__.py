"""Distribution substrate: sharding rules, fault tolerance, compression."""
