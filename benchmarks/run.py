"""Benchmark harness entry: one function per paper table/figure.

Prints ``name,value,derived`` CSV. Asserts inside each benchmark double as
integration tests of the reproduction's claims (routing beats random, the
skew-difficulty correlation holds, token-cost blowup matches, ...).

  PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller KG / fewer queries")
    args = ap.parse_args()

    from benchmarks import kernel_bench, kgqa_experiment, paper_figures as F

    rows: list[tuple] = []
    t0 = time.monotonic()

    # -- static cost-model benchmarks (paper Fig 2 / Table 4) ---------------
    rows += F.fig2a_token_cost()
    rows += F.fig2b_scale_tradeoff()

    # -- KGQA pipeline (paper Figs 3-9, Table 3) ----------------------------
    n_q = 300 if args.quick else 600
    n_e = 8000 if args.quick else 12000
    steps = 300 if args.quick else 600
    for dataset in (["cwq"] if args.quick else ["cwq", "webqsp"]):
        _, _, _, records = kgqa_experiment.build_experiment(
            dataset, n_queries=n_q, n_entities=n_e, train_steps=steps)
        rows.append((f"{dataset}/n_records", len(records), "queries evaluated"))
        rows += F.fig3_skew_examples(records)
        rows += F.fig4_skew_vs_difficulty(records)
        rows += F.table3_baselines(records, dataset)
        rows += F.fig56_routing(records, dataset, "qwen7b", "qwen72b")
        rows += F.fig56_routing(records, dataset, "llama8b", "llama70b")
        rows += F.fig56_routing(records, dataset, "qwen7b", "llama70b",
                                strict_parity=False)  # Fig 8
        if dataset == "cwq":
            rows += F.fig7_multi_tier(records)
            rows += F.fig9_cumulative_p(records)

    # -- kernels --------------------------------------------------------------
    rows += kernel_bench.run_all()

    # -- routing-policy frontier (gates asserted inside; full bench with
    # tracked JSON: python -m benchmarks.policy_frontier_bench) -------------
    from benchmarks import policy_frontier_bench
    rows += policy_frontier_bench.csv_rows(quick=args.quick)

    # -- serving-stack smokes (each bench's gates assert inside; the full
    # sweeps with tracked JSON remain the standalone entries) ---------------
    from benchmarks import (fabric_sync_bench, load_sim_bench,
                            roofline_report, sharded_dispatch_bench)
    rows += load_sim_bench.csv_rows(quick=True)
    rows += fabric_sync_bench.csv_rows(quick=True)
    rows += sharded_dispatch_bench.csv_rows(quick=True)
    rows += roofline_report.csv_rows(quick=args.quick)

    rows.append(("total_wall_s", time.monotonic() - t0, ""))
    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
