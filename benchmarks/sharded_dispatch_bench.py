"""Sharded-dispatch benchmark: the ``sharded`` backend vs ``auto``,
parity-gated bit-for-bit on a forced multi-device host mesh.

Forces ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (default
8, ``--devices`` overrides) BEFORE jax import, builds the dispatch mesh,
and runs two sections:

parity (the acceptance gate)
  ``sharded`` must reproduce ``auto`` EXACTLY — tiers, difficulty, all
  four skew metrics — at the headline shape B=1024 / K=100 with ragged
  ``n_valid``, plus a dense batch and the fused retrieve-to-decision
  path (indices, probs, tiers). Bit-for-bit, not allclose: the shards
  run the identical row-local programs, so any drift is a bug.

throughput (recorded, not gated)
  median wall time of ``route_batch`` over a batch sweep for both
  backends. On the forced HOST mesh the shards timeshare one CPU, so
  speedup here measures dispatch overhead, not the real-mesh win — the
  number worth tracking is that sharding costs ~nothing at the shapes
  where a real pod would fan out.

Full runs (default device count, no --smoke) also write structured JSON
to ``BENCH_sharded_dispatch.json`` at the repo root — the parity/perf
trajectory tracked across PRs (``--json`` overrides the path, ``--json
''`` disables writing).

  PYTHONPATH=src python -m benchmarks.sharded_dispatch_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import statistics
import sys
import time

DEFAULT_DEVICES = 8
GATE_SHAPE = (1024, 100)          # B, K of the headline parity gate
E2E_SHAPE = (96, 64, 32)          # B, N candidates, top-K end-to-end
FULL_SWEEP = (64, 256, 1024, 4096)
SMOKE_SWEEP = (64, 256)
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_sharded_dispatch.json"


def _early_devices() -> int:
    """--devices must take effect before jax import; argparse runs too
    late, so peek at argv here."""
    argv = sys.argv
    if "--devices" in argv:
        try:
            return int(argv[argv.index("--devices") + 1])
        except (IndexError, ValueError):
            pass
    return DEFAULT_DEVICES


_FORCED = _early_devices()
if "jax" not in sys.modules and _FORCED > 1:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + f" --xla_force_host_platform_device_count={_FORCED}"
        ).strip()

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402
import numpy.testing as npt                                    # noqa: E402

from repro.api import make_backend                             # noqa: E402
from repro.api.sharded import make_dispatch_mesh               # noqa: E402
from repro.core.router import RouterConfig                     # noqa: E402
from repro.retrieval.scorer import ScorerConfig, init_scorer   # noqa: E402


def desc_scores(rng, b, k) -> np.ndarray:
    return -np.sort(-rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                    axis=1)


def check_parity(cfg: RouterConfig) -> dict:
    """The acceptance gate: bit-for-bit equality with ``auto`` on the
    headline batch, a dense batch, and the fused end-to-end path."""
    auto, shard = make_backend("auto"), make_backend("sharded")
    b, k = GATE_SHAPE
    rng = np.random.default_rng(0)
    scores = desc_scores(rng, b, k)
    nv = rng.integers(5, k + 1, b)

    ra = auto.route_batch(scores, cfg, n_valid=nv)
    rs = shard.route_batch(scores, cfg, n_valid=nv)
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rs.tiers))
    npt.assert_array_equal(np.asarray(ra.difficulty),
                           np.asarray(rs.difficulty))
    npt.assert_array_equal(np.asarray(ra.metrics), np.asarray(rs.metrics))

    rd_a = auto.route_batch(scores, cfg)
    rd_s = shard.route_batch(scores, cfg)
    npt.assert_array_equal(np.asarray(rd_a.tiers), np.asarray(rd_s.tiers))
    npt.assert_array_equal(np.asarray(rd_a.metrics),
                           np.asarray(rd_s.metrics))

    eb, n, ek = E2E_SHAPE
    sc = ScorerConfig(d_emb=16, d_hidden=32)
    params = init_scorer(jax.random.PRNGKey(0), sc)
    feats = rng.standard_normal((eb, n, sc.d_triple)).astype(np.float32)
    qemb = rng.standard_normal((eb, sc.d_query)).astype(np.float32)
    nc = rng.integers(ek, n + 1, eb)
    ecfg = RouterConfig(metric=cfg.metric, thresholds=(3.0,), top_k=ek)
    ea = auto.route_retrieved(feats, qemb, params, ecfg, n_cand=nc)
    es = shard.route_retrieved(feats, qemb, params, ecfg, n_cand=nc)
    for field in ("indices", "probs", "n_valid", "tiers", "metrics"):
        npt.assert_array_equal(np.asarray(getattr(ea, field)),
                               np.asarray(getattr(es, field)))

    mesh = shard.mesh
    gates = {
        "gate_shape": {"B": b, "K": k},
        "e2e_shape": {"B": eb, "N": n, "K": ek},
        "mesh": {ax: int(sz) for ax, sz in mesh.shape.items()},
        "bit_for_bit": True,
        "passed": True,
    }
    print(f"parity PASSED: sharded == auto bit-for-bit at B={b} K={k} "
          f"(ragged + dense) and end-to-end B={eb} N={n} K={ek} on mesh "
          f"{gates['mesh']}")
    return gates


def _time(fn, reps: int) -> float:
    fn()                                   # warmup / compile
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn().tiers)
        walls.append(time.perf_counter() - t0)
    return statistics.median(walls)


def sweep(cfg: RouterConfig, batches, reps: int) -> list[dict]:
    auto, shard = make_backend("auto"), make_backend("sharded")
    rng = np.random.default_rng(1)
    cells = []
    for b in batches:
        scores = desc_scores(rng, b, GATE_SHAPE[1])
        t_auto = _time(lambda: auto.route_batch(scores, cfg), reps)
        t_shard = _time(lambda: shard.route_batch(scores, cfg), reps)
        cell = {"B": b, "K": GATE_SHAPE[1],
                "auto_ms": 1e3 * t_auto, "sharded_ms": 1e3 * t_shard,
                "speedup": t_auto / t_shard}
        cells.append(cell)
        print(f"B={b:5d} K={GATE_SHAPE[1]}: auto {cell['auto_ms']:8.3f}ms  "
              f"sharded {cell['sharded_ms']:8.3f}ms  "
              f"x{cell['speedup']:.2f}")
    return cells


def csv_rows(quick: bool = True) -> list[tuple]:
    """``benchmarks.run`` harness entry: the bit-for-bit parity gate +
    a short throughput sweep. NOTE: when the harness imported jax before
    this module, the forced multi-device host mesh is whatever that
    import resolved (usually 1 device) — parity still gates; the
    timings measure dispatch overhead only."""
    cfg = RouterConfig(metric="entropy", thresholds=(4.0,),
                       top_k=GATE_SHAPE[1])
    gates = check_parity(cfg)
    cells = sweep(cfg, SMOKE_SWEEP if quick else FULL_SWEEP,
                  reps=3 if quick else 7)
    rows: list[tuple] = [
        ("sharded/parity", int(gates["bit_for_bit"]),
         f"sharded == auto bit-for-bit on mesh {gates['mesh']}"),
    ]
    for c in cells:
        rows.append((f"sharded/B{c['B']}_K{c['K']}/speedup",
                     round(c["speedup"], 2),
                     "auto wall / sharded wall (host mesh)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short sweep (same parity gate)")
    ap.add_argument("--devices", type=int, default=DEFAULT_DEVICES,
                    help="forced host device count (applied before jax "
                    "import; ignored if jax was already imported)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing repetitions per cell")
    ap.add_argument("--json", default=None,
                    help="structured-output path ('' disables; default: "
                    "repo-root BENCH_sharded_dispatch.json for full "
                    "default-device runs)")
    args = ap.parse_args()

    n_dev = jax.local_device_count()
    print(f"devices: {n_dev} ({jax.devices()[0].platform}), mesh "
          f"{dict(make_dispatch_mesh().shape)}")
    cfg = RouterConfig(metric="entropy", thresholds=(4.0,),
                       top_k=GATE_SHAPE[1])
    gates = check_parity(cfg)
    batches = SMOKE_SWEEP if args.smoke else FULL_SWEEP
    reps = args.reps or (3 if args.smoke else 7)
    cells = sweep(cfg, batches, reps)

    if args.json is not None:
        json_path = pathlib.Path(args.json) if args.json else None
    elif not args.smoke and args.devices == DEFAULT_DEVICES:
        json_path = DEFAULT_JSON     # full default run: track it
    else:
        json_path = None
    if json_path is not None:
        payload = {
            "bench": "sharded_dispatch",
            "devices": n_dev,
            "platform": jax.devices()[0].platform,
            "gates": gates,
            "cells": cells,
        }
        json_path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
        print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
