"""One benchmark per paper table/figure, on the synthetic KGQA pipeline.

Each ``fig_*``/``table_*`` function returns a list of (name, value,
derived-note) rows that benchmarks/run.py renders as CSV, and asserts the
paper's qualitative claim it reproduces (so `python -m benchmarks.run`
doubles as an integration test of the reproduction).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import kgqa_experiment as X
from repro.core.cost import (CostModel, PAPER_COST_PER_MTOK,
                             TOKENS_BARE_QUESTION, TOKENS_PER_TRIPLE)


def fig2a_token_cost() -> list[tuple]:
    """Fig 2a: input-token blowup vs number of retrieved triples."""
    cm = CostModel()
    rows = []
    for n in [0, 25, 50, 100, 200]:
        toks = cm.input_tokens(n)
        rows.append((f"fig2a/tokens_n{n}", toks,
                     f"{toks / cm.input_tokens(0):.1f}x bare question"))
    blowup = cm.input_tokens(100) / cm.input_tokens(0)
    assert blowup > 25, f"expected >25x token blowup at 100 triples, got {blowup:.1f}"
    return rows


def fig2b_scale_tradeoff() -> list[tuple]:
    """Fig 2b / Table 4: cost-vs-quality across model scales."""
    cm = CostModel()
    rows = []
    for model in ["qwen7b", "qwen14b", "qwen32b", "qwen72b"]:
        c = cm.request_cost(model) * 1e3
        rows.append((f"fig2b/cost_per_kquery_{model}", c,
                     f"${PAPER_COST_PER_MTOK[model]}/Mtok"))
    r72 = cm.request_cost("qwen72b") / cm.request_cost("qwen14b")
    assert r72 > 4, "72b should cost >4x 14b (paper: ~6-7x)"
    return rows


def fig3_skew_examples(records) -> list[tuple]:
    """Fig 3/10: high- vs low-skew score distributions exist side by side."""
    from repro.core import skewness
    import jax.numpy as jnp
    areas = []
    for r in records:
        areas.append(float(skewness.area_metric(jnp.asarray(r["scores"])[None])[0]))
    areas = np.asarray(areas)
    rows = [("fig3/area_p10", float(np.percentile(areas, 10)), "high-skew tail"),
            ("fig3/area_p90", float(np.percentile(areas, 90)), "low-skew tail")]
    # CWQ spans ~5x (multi-hop tail); WebQSP is 1-2 hop only so its spread
    # is narrower (paper Fig 10 shows the same compression) — assert the
    # qualitative claim at 2x.
    assert np.percentile(areas, 90) > 2 * np.percentile(areas, 10), \
        "score distributions should span a wide skewness range (paper Fig 3)"
    return rows


def fig4_skew_vs_difficulty(records) -> list[tuple]:
    """Fig 4/12: skewness correlates with difficulty (hops + answer rank).

    Reports mean area per hop bucket + a one-way ANOVA F statistic over
    answer-position groups split by skewness quartile (paper Fig 12).
    """
    diffs = X.difficulty_matrix(records)["area"]
    hops = np.asarray([r["hops"] for r in records])
    rows = []
    means = {}
    for h in sorted(set(hops)):
        means[h] = float(diffs[hops == h].mean())
        rows.append((f"fig4/mean_area_hops{h}", means[h],
                     f"n={int((hops == h).sum())}"))
    ks = sorted(means)
    assert means[ks[-1]] > means[ks[0]], \
        "multi-hop queries must show lower skewness (larger area)"
    # ANOVA of answer position across skewness quartiles
    anspos = np.asarray([r["gold_rank"] if r["gold_rank"] is not None
                         else len(r["scores"]) for r in records], float)
    quart = np.digitize(diffs, np.percentile(diffs, [25, 50, 75]))
    groups = [anspos[quart == i] for i in range(4) if (quart == i).sum() > 1]
    grand = anspos.mean()
    ss_b = sum(len(g) * (g.mean() - grand) ** 2 for g in groups)
    ss_w = sum(((g - g.mean()) ** 2).sum() for g in groups)
    df_b, df_w = len(groups) - 1, len(anspos) - len(groups)
    f_stat = (ss_b / df_b) / max(ss_w / df_w, 1e-9)
    rows.append(("fig4/anova_F", float(f_stat), f"df=({df_b},{df_w})"))
    return rows


def fig56_routing(records, dataset: str, small: str, large: str,
                  quality_metric: str = "hit1",
                  strict_parity: bool = True) -> list[tuple]:
    """Figs 5/6: all four skew metrics beat random mixing; ~half the large
    calls at parity with all-large inference.

    ``strict_parity=False`` for the cross-family pair (paper Fig 8): there
    the claim is "+~3% over random mixing at ~5% extra cost", not a call-
    ratio reduction at parity — the parity ratio is reported, not asserted.
    """
    curves = X.routing_curves(records, dataset, small, large, quality_metric)
    rows = []
    rand = curves["random"]
    all_large_q = curves["random"].quality[-1]
    for name in ["area", "cumulative", "entropy", "gini"]:
        c = curves[name]
        # area under the routing curve vs random (quality advantage)
        adv = float(np.trapezoid(c.quality - np.interp(c.ratios, rand.ratios,
                                                       rand.quality), c.ratios))
        parity = X.call_ratio_at_parity(c, all_large_q * 0.995)
        rows.append((f"{dataset}/{small}->{large}/{name}/auc_vs_random",
                     adv, f"parity_ratio={parity:.2f}"))
        assert adv > 0, f"{name} routing must beat random mixing ({dataset})"
    best_parity = min(X.call_ratio_at_parity(curves[m], all_large_q * 0.995)
                      for m in ["area", "cumulative", "entropy", "gini"])
    rows.append((f"{dataset}/{small}->{large}/best_parity_ratio",
                 best_parity, "paper: ~0.5 (synthetic scorer separates "
                 "slightly less cleanly than SubgraphRAG on real CWQ)"))
    if strict_parity:
        assert best_parity <= 0.8, \
            f"expected large-call reduction at parity, got {best_parity}"
    return rows


def fig7_multi_tier(records, dataset: str = "cwq") -> list[tuple]:
    """Fig 7: adding a medium tier improves the cost-quality tradeoff."""
    qs = X.oracle_quality(records, "qwen7b", dataset)
    qm = X.oracle_quality(records, "qwen14b", dataset)
    ql = X.oracle_quality(records, "qwen72b", dataset)
    d = X.difficulty_matrix(records)["gini"]
    cm = CostModel()
    cost = {m: cm.request_cost(m) for m in ["qwen7b", "qwen14b", "qwen72b"]}
    order = np.argsort(-d, kind="stable")
    n = len(records)

    def two_tier(f_large):
        sel = np.zeros(n, bool)
        sel[order[: int(f_large * n)]] = True
        q = float(np.where(sel, ql, qs).mean())
        c = float(np.where(sel, cost["qwen72b"], cost["qwen7b"]).mean())
        return q, c

    def three_tier(f_large, f_med):
        tiers = np.zeros(n, np.int32)
        tiers[order[: int(f_large * n)]] = 2
        tiers[order[int(f_large * n): int((f_large + f_med) * n)]] = 1
        q = float(np.select([tiers == 2, tiers == 1], [ql, qm], qs).mean())
        c = float(np.select([tiers == 2, tiers == 1],
                            [cost["qwen72b"], cost["qwen14b"]],
                            cost["qwen7b"]).mean())
        return q, c

    q2, c2 = two_tier(0.3)
    q3, c3 = three_tier(0.2, 0.4)
    rows = [("fig7/two_tier_quality", q2, f"cost=${c2*1e3:.3f}/kq"),
            ("fig7/three_tier_quality", q3, f"cost=${c3*1e3:.3f}/kq")]
    assert q3 >= q2 - 0.005 and c3 < c2, \
        "medium tier should improve the cost/quality frontier (paper Fig 7)"
    return rows


def fig9_cumulative_p(records, dataset: str = "cwq") -> list[tuple]:
    """Fig 9: cumulative-threshold routing beats random for P in
    [0.35, 0.95] (the paper's robustness claim).

    Deviation note (EXPERIMENTS.md §Paper-validation): the paper
    additionally finds P=0.95 steadily ahead of P=0.35; on the synthetic
    scorer the ordering is mixed — our score TAILS are noisier than
    SubgraphRAG's on real CWQ, and high P reads deep into the tail. The
    robustness claim (every P beats random) reproduces; the P-ordering
    claim is scorer-dependent and is reported, not asserted.
    """
    rows = []
    aucs = {}
    for p in [0.35, 0.65, 0.95]:
        curves = X.routing_curves(records, dataset, "qwen7b", "qwen72b",
                                  p_cdf=p)
        c, rand = curves["cumulative"], curves["random"]
        auc = float(np.trapezoid(c.quality - np.interp(
            c.ratios, rand.ratios, rand.quality), c.ratios))
        aucs[p] = auc
        rows.append((f"fig9/auc_P{p}", auc, "vs random"))
        assert auc > 0, f"cumulative routing must beat random at P={p}"
    rows.append(("fig9/P_ordering", float(aucs[0.95] - aucs[0.35]),
                 "paper: positive; scorer-dependent here (see note)"))
    return rows


def table3_baselines(records, dataset: str) -> list[tuple]:
    """Table 3: all-small / all-large aggregate quality (oracle check)."""
    rows = []
    for model in (["qwen7b", "qwen72b", "llama8b", "llama70b"]):
        q = float(X.oracle_quality(records, model, dataset).mean())
        ref = X.PAPER_QUALITY[dataset][model]["hit1"] / 100.0
        rows.append((f"table3/{dataset}/{model}", q, f"paper={ref:.3f}"))
        assert abs(q - ref) < 0.08, \
            f"oracle {model}@{dataset} drifted from Table 3: {q} vs {ref}"
    return rows
