"""Routing-policy frontier benchmark: every registered policy on the
same canonical drift trace, on one cost/quality plane.

Replays the canonical drift workload (seeded arrival + score-skew drift;
``repro.serving.loadgen.workload.CANONICAL_TRACES``) through ROUTE-ONLY
sessions — one per registered routing policy — and places each on the
($/query, quality-proxy) frontier. No replica pools: this bench isolates
the DECISION economics (which tier, which depth, which mode, at what
prompt price) from queueing effects, which ``load_sim_bench`` covers.

Hardness model (the part a share-weighted proxy cannot express): a
seeded latent ``needs_big`` bit per query — true for the hardest ~15%
by fused difficulty, with 5% label noise so skew correlates with but
does not determine hardness — plus a near-noiseless engine self-score
observing it (3% miss / 3% false-alarm). Quality per query is
hardness-aware, per paper Fig 4's reading: EASY queries score the top
model's paper CWQ F1 at ANY tier (both models answer them equally
well — extra escalation buys nothing), while a ``needs_big`` query
scores top-tier F1 only if it FINISHES on the top tier and a flat
collapse penalty otherwise. The same rule prices every policy.

Why cascade can dominate the single threshold here: the threshold
policy must buy the top tier for a fixed SHARE of traffic (30% at the
canonical calibration) chosen blind to hardness, so it both overpays
(easy queries above the cut) and still misses the hard queries below
it. The cascade escalates on calibrated difficulty OR the self-score,
so it buys the expensive tier for roughly P(needs_big) of traffic —
below the ~27.5% cost-crossover at paper pricing — while catching the
hard queries the threshold's skew cut misses.

Acceptance gates (asserted on every run, smoke included):

* cascade is STRICTLY cheaper per query than the single-threshold
  baseline at EQUAL-OR-BETTER hardness-aware quality;
* cascade's realized escalation rate stays below the analytic cost
  crossover for the paper's price pair;
* adaptive_depth prices below the full-depth threshold baseline (it
  routes identical tiers on strictly shorter prompts).

Full runs (default trace, no --smoke) write structured JSON to
``BENCH_policy_frontier.json`` at the repo root — the policy-frontier
trajectory tracked across PRs (``--json`` overrides, ``--json ''``
disables; smoke runs don't touch the tracked file unless asked).

  PYTHONPATH=src python -m benchmarks.policy_frontier_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import numpy as np

from repro.core.cost import PAPER_QUALITY

DEFAULT_TRACE = "bursty_drift_saturation"
SMOKE_TRACE = "smoke"
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_policy_frontier.json"

# Hardness model: hardest ~12% by difficulty are latently hard, with 4%
# label noise; the engine self-score observes the latent bit at 97%.
HARD_QUANTILE = 0.88
LABEL_FLIP = 0.04
SELF_SCORE_ERR = 0.03
MISS_PENALTY = 15.0      # F1 points a hard query loses below the top tier
NO_RAG_PENALTY = 3.0     # F1 points for answering without any context
WARMUP_FRAC = 0.3        # calibration warmup share of the trace
HARDNESS_SEED = 20250808


def _sanitize(x):
    """nan/inf -> None so the tracked JSON stays strictly parseable."""
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, dict):
        return {k: _sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_sanitize(v) for v in x]
    return x


def trace_batches(trace_name: str) -> list[np.ndarray]:
    from repro.serving.loadgen import canonical_trace, generate
    trace = canonical_trace(trace_name)
    return [w.scores for w in generate(trace) if w.n_arrivals], trace


def probe_difficulty(batches: list[np.ndarray], top_k: int) -> np.ndarray:
    """Per-request fused difficulty under a static probe session (no
    calibration — difficulty is threshold-independent)."""
    from repro.api import RouteSpec, build
    session = build(RouteSpec(metric="entropy", thresholds=(6.0,),
                              top_k=top_k,
                              tier_names=("qwen7b", "qwen72b")))
    return np.concatenate([np.asarray(session.route(b).difficulty)
                           for b in batches])


def hardness_model(difficulty: np.ndarray, seed: int = HARDNESS_SEED):
    """(needs_big, self_scores): the latent hard bit + its noisy engine
    observation, both seeded so every policy prices the same queries."""
    rng = np.random.default_rng(seed)
    cut = np.quantile(difficulty, HARD_QUANTILE)
    needs_big = difficulty > cut
    flip = rng.random(needs_big.size) < LABEL_FLIP
    needs_big = needs_big ^ flip
    observed = needs_big ^ (rng.random(needs_big.size) < SELF_SCORE_ERR)
    self_scores = np.where(observed,
                           rng.uniform(0.70, 1.00, needs_big.size),
                           rng.uniform(0.00, 0.30, needs_big.size))
    return needs_big, self_scores.astype(np.float32)


def policy_sessions(top_k: int) -> dict:
    """{name: (RouteSpec, uses_self_scores)} — the contenders."""
    from repro.api import (AdaptiveDepthPolicySpec, CalibrationSpec,
                           CascadePolicySpec, ModeSelectPolicySpec,
                           RouteSpec)
    cal = CalibrationSpec(policy="streaming", target_shares=(0.7, 0.3),
                          window=512, min_samples=64, tolerance=0.08,
                          cooldown=128)
    two = dict(metric="entropy", thresholds=(6.0,), top_k=top_k,
               tier_names=("qwen7b", "qwen72b"), calibration=cal)
    opts = tuple(sorted({max(1, top_k // 4), max(2, top_k // 2), top_k}))
    return {
        "threshold": (RouteSpec(**two), False),
        "cascade": (RouteSpec(**two, policy=CascadePolicySpec(
            escalation_cutoffs=(6.5,),
            # lax difficulty cut (hardest 5% escalate unconditionally);
            # the self-score catches the hard queries below it
            escalation_quantiles=(0.95,),
            self_score_cutoff=0.5)), True),
        "adaptive_depth": (RouteSpec(**two, policy=AdaptiveDepthPolicySpec(
            depth_options=opts,
            depth_cutoffs=tuple(5.0 + 1.5 * i
                                for i in range(len(opts) - 1)),
            depth_quantiles=tuple((i + 1) / len(opts)
                                  for i in range(len(opts) - 1)))), False),
        "mode_select": (RouteSpec(
            metric="entropy", thresholds=(5.0, 6.5), top_k=top_k,
            tier_names=("qwen7b", "qwen14b", "qwen72b"),
            calibration=CalibrationSpec(
                policy="streaming", target_shares=(0.4, 0.35, 0.25),
                window=512, min_samples=64, tolerance=0.08, cooldown=128),
            policy=ModeSelectPolicySpec(
                modes=("no_rag", "kg_rag", "kg_rag"))), False),
    }


def run_policy(name: str, spec, uses_self_scores: bool,
               batches: list[np.ndarray], needs_big: np.ndarray,
               self_scores: np.ndarray) -> dict:
    """Warmup (calibration + policy refit) then measure cost/quality."""
    from repro.api import build
    session = build(spec)
    models = spec.models()
    cost_model = spec.cost_model()
    tier_cost = np.asarray([cost_model.request_cost(m)
                            if m in cost_model.cost_per_mtok else 0.0
                            for m in models])
    f1 = PAPER_QUALITY["cwq"]
    tier_f1 = np.asarray([float(f1[m]["f1"]) if m in f1 else 40.0
                          for m in models])
    top = len(models) - 1
    modes = getattr(spec.policy, "modes", None)

    n_warm = max(1, int(WARMUP_FRAC * len(batches)))
    cost_total, qual_total, n_meas, n_missed_hard = 0.0, 0.0, 0, 0
    t0, i0 = time.perf_counter(), 0
    for bi, scores in enumerate(batches):
        n = scores.shape[0]
        ss = self_scores[i0:i0 + n] if uses_self_scores else None
        res = session.route(scores, self_scores=ss)
        if bi == n_warm - 1:
            # end of warmup: force one policy refit from the calibrator
            # window so data-dependent cutoffs enter measurement fitted
            session.dispatcher.apply_config(session.dispatcher.router)
        elif bi >= n_warm:
            tiers = np.asarray(res.tiers)
            cost = (np.asarray(res.request_cost)
                    if res.request_cost is not None else tier_cost[tiers])
            nb = needs_big[i0:i0 + n]
            # hardness-aware proxy: easy queries score top-tier F1 at
            # any tier; hard queries collapse unless finished on top
            q = np.full(n, tier_f1[top])
            if modes is not None:
                q = q - NO_RAG_PENALTY * (
                    np.asarray(modes)[tiers] == "no_rag")
            missed = nb & (tiers < top)
            q[missed] = tier_f1[0] - MISS_PENALTY
            cost_total += float(cost.sum())
            qual_total += float(q.sum())
            n_meas += n
            n_missed_hard += int(missed.sum())
        i0 += n
    out = {
        "policy": name,
        "cost_per_query": cost_total / max(n_meas, 1),
        "quality_proxy": qual_total / max(n_meas, 1),
        "n_measured": n_meas,
        "hard_miss_rate": n_missed_hard / max(n_meas, 1),
        "wall_s": time.perf_counter() - t0,
        "telemetry": session.policy.telemetry(),
    }
    print(f"{name:15s} $/query={out['cost_per_query']:.6f}  "
          f"quality={out['quality_proxy']:.2f}  "
          f"hard_miss={out['hard_miss_rate']:.4f}  "
          f"wall={out['wall_s']:.1f}s")
    return out


def escalation_crossovers(spec, base_cost: float) -> tuple[float, float]:
    """Cascade-vs-threshold cost crossovers for the 2-tier paper price
    pair: cascade (always pay tier-0, pay tier-1 on escalation) is
    cheaper iff its escalation rate e satisfies c0 + e*c1 < baseline
    $/query. Returns (analytic, realized): analytic assumes the
    canonical 70/30 split exactly; realized uses the baseline's actual
    measured $/query (the calibrator chases 30% but drifts between
    swaps), which is the number cost dominance is literally gated on."""
    cm = spec.cost_model()
    c0, c1 = (cm.request_cost(m) for m in spec.models())
    return (0.3 * c1 - 0.3 * c0) / c1, (base_cost - c0) / c1


def check_gates(rows: dict, specs: dict) -> dict:
    base, casc = rows["threshold"], rows["cascade"]
    analytic, realized = escalation_crossovers(specs["threshold"][0],
                                               base["cost_per_query"])
    esc_rate = casc["telemetry"]["escalation_rate"]

    assert casc["cost_per_query"] < base["cost_per_query"], (
        f"cascade (${casc['cost_per_query']:.6f}/query) is not strictly "
        f"cheaper than the threshold baseline "
        f"(${base['cost_per_query']:.6f}/query)")
    assert casc["quality_proxy"] >= base["quality_proxy"], (
        f"cascade quality {casc['quality_proxy']:.2f} fell below the "
        f"threshold baseline {base['quality_proxy']:.2f} — dominance "
        f"requires equal-or-better quality at lower cost")
    assert esc_rate < realized, (
        f"cascade escalation rate {esc_rate:.4f} is not below the "
        f"realized cost crossover {realized:.4f}")
    assert rows["adaptive_depth"]["cost_per_query"] \
        < base["cost_per_query"], (
        "adaptive_depth did not price below the full-depth baseline")
    for r in rows.values():
        assert r["cost_per_query"] > 0, f"{r['policy']} priced at zero"

    gates = {
        "cascade_cost_delta": (casc["cost_per_query"]
                               - base["cost_per_query"]),
        "cascade_quality_delta": (casc["quality_proxy"]
                                  - base["quality_proxy"]),
        "escalation_rate": esc_rate,
        "escalation_crossover_analytic": analytic,
        "escalation_crossover_realized": realized,
        "passed": True,
    }
    print(f"gates PASSED: cascade {gates['cascade_cost_delta']:+.6f} "
          f"$/query, quality {gates['cascade_quality_delta']:+.2f}, "
          f"escalation {esc_rate:.4f} < crossover {realized:.4f} "
          f"(analytic {analytic:.4f})")
    return gates


def run_frontier(trace_name: str) -> tuple[dict, dict, dict]:
    """(rows, gates, meta): the full bench minus I/O — shared by
    ``main`` and the ``benchmarks.run`` harness registration."""
    batches, trace = trace_batches(trace_name)
    difficulty = probe_difficulty(batches, trace.top_k)
    needs_big, self_scores = hardness_model(difficulty)
    print(f"{difficulty.size} queries, "
          f"P(needs_big)={needs_big.mean():.4f}")
    specs = policy_sessions(trace.top_k)
    rows = {name: run_policy(name, spec, uses_ss, batches,
                             needs_big, self_scores)
            for name, (spec, uses_ss) in specs.items()}
    gates = check_gates(rows, specs)
    meta = {"trace": trace.to_dict(),
            "p_needs_big": float(needs_big.mean())}
    return rows, gates, meta


def csv_rows(quick: bool = False) -> list[tuple]:
    """``benchmarks.run`` harness entry: one CSV row per policy on the
    canonical drift trace (gates asserted inside)."""
    rows, gates, _ = run_frontier(SMOKE_TRACE if quick else DEFAULT_TRACE)
    out = []
    for name, r in rows.items():
        out.append((f"policy_frontier/{name}/cost_per_query",
                    round(r["cost_per_query"], 8), "$ at paper pricing"))
        out.append((f"policy_frontier/{name}/quality_proxy",
                    round(r["quality_proxy"], 3), "hardness-aware F1"))
    out.append(("policy_frontier/cascade_cost_delta",
                round(gates["cascade_cost_delta"], 8),
                "cascade - threshold, $/query (gated < 0)"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short CI trace (same gates, much faster)")
    ap.add_argument("--trace", default=None,
                    help="canonical trace name (overrides --smoke choice)")
    ap.add_argument("--json", default=None,
                    help="structured-output path ('' disables; default: "
                    "repo-root BENCH_policy_frontier.json for full "
                    "default runs)")
    args = ap.parse_args()

    trace_name = args.trace or (SMOKE_TRACE if args.smoke else DEFAULT_TRACE)
    print(f"trace: {trace_name}")
    rows, gates, meta = run_frontier(trace_name)

    if args.json is not None:
        json_path = pathlib.Path(args.json) if args.json else None
    elif trace_name == DEFAULT_TRACE:
        json_path = DEFAULT_JSON     # full default run: track it
    else:
        json_path = None
    if json_path is not None:
        payload = _sanitize({
            "bench": "policy_frontier",
            "trace": meta["trace"],
            "hardness": {"hard_quantile": HARD_QUANTILE,
                         "label_flip": LABEL_FLIP,
                         "self_score_err": SELF_SCORE_ERR,
                         "miss_penalty": MISS_PENALTY,
                         "no_rag_penalty": NO_RAG_PENALTY,
                         "p_needs_big": meta["p_needs_big"],
                         "seed": HARDNESS_SEED},
            "frontier": list(rows.values()),
            "gates": gates,
        })
        json_path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
        print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
