"""Kernel microbenchmarks: correctness re-check + v5e roofline model.

No TPU in this container, so wall-clock numbers are CPU-interpret
timings (reported for completeness but NOT the score); the meaningful
output is the modeled v5e time per kernel = max(flops/197T, bytes/819G)
and the arithmetic intensity, plus allclose deltas vs each ref.py oracle.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _model_time(flops: float, bytes_: float) -> float:
    return max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)


def bench_flash_attention() -> list[tuple]:
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    B, H, KV, S, D = 1, 4, 2, 128, 64
    q = jax.random.normal(jax.random.key(0), (B, H, S, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, KV, S, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, KV, S, D), jnp.float32)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    err = float(jnp.abs(out - attention_ref(q, k, v)).max())
    # production shape: internlm2 prefill_32k per device
    bp, hp, sp, dp = 2, 3, 32768, 128
    flops = 4 * bp * hp * sp * sp * dp / 2      # causal half
    bytes_ = 2 * bp * hp * sp * dp * 2 * 3      # q,k,v + out, bf16
    t = _model_time(flops, bytes_)
    return [("kernel/flash_attention/maxerr", err, "vs ref.py"),
            ("kernel/flash_attention/v5e_model_ms", t * 1e3,
             f"AI={flops/bytes_:.0f} flop/B (compute-bound)")]


def bench_decode_attention() -> list[tuple]:
    from repro.kernels.decode_attention.kernel import decode_attention
    from repro.kernels.decode_attention.ref import decode_ref
    B, H, KV, S, D = 2, 8, 4, 256, 32
    q = jax.random.normal(jax.random.key(0), (B, H, D), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (B, KV, S, D), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (B, KV, S, D), jnp.float32)
    out = decode_attention(q, k, v, jnp.int32(201), block_k=64, interpret=True)
    err = float(jnp.abs(out - decode_ref(q, k, v, jnp.int32(201))).max())
    # production: decode_32k per device (b=8 local, kv=8, s=32768, d=128)
    bp, kvp, sp, dp, g = 8, 8, 32768, 128, 6
    bytes_ = bp * kvp * sp * dp * 2 * 2         # K+V read, bf16
    flops = 4 * bp * kvp * g * sp * dp
    t = _model_time(flops, bytes_)
    return [("kernel/decode_attention/maxerr", err, "vs ref.py"),
            ("kernel/decode_attention/v5e_model_ms", t * 1e3,
             f"AI={flops/bytes_:.1f} flop/B (memory-bound; xG from GQA)")]


def bench_triple_score() -> list[tuple]:
    from repro.kernels.triple_score.kernel import triple_score
    from repro.kernels.triple_score.ref import triple_score_ref
    N, Dt, Dq, H, Q = 512, 114, 32, 128, 4
    key = jax.random.key(0)
    ks = jax.random.split(key, 7)
    args = (jax.random.normal(ks[0], (N, Dt)), jax.random.normal(ks[1], (Q, Dq)),
            jax.random.normal(ks[2], (Dt, H)) * 0.1,
            jax.random.normal(ks[3], (Dq, H)) * 0.1,
            jax.random.normal(ks[4], (H,)) * 0.1,
            jax.random.normal(ks[5], (H, 1)) * 0.1, jnp.zeros((1,)))
    out = triple_score(*args, tile=128, interpret=True)
    err = float(jnp.abs(out - triple_score_ref(*args)).max())
    # production: 1M candidate triples x 1 query, H=1024
    n, dt, h = 1_000_000, 1156, 1024
    flops = 2 * n * dt * h + 2 * n * h
    bytes_ = n * dt * 2 + n * 4
    t = _model_time(flops, bytes_)
    return [("kernel/triple_score/maxerr", err, "vs ref.py"),
            ("kernel/triple_score/v5e_model_ms", t * 1e3,
             f"AI={flops/bytes_:.0f} flop/B")]


def bench_skew_metrics() -> list[tuple]:
    from repro.kernels.skew_metrics.kernel import skew_metrics
    from repro.kernels.skew_metrics.ref import skew_metrics_ref
    scores = jnp.sort(jax.random.uniform(jax.random.key(0), (32, 100)),
                      axis=1)[:, ::-1]
    out = skew_metrics(scores, interpret=True)
    ref = skew_metrics_ref(scores)
    err = float(jnp.abs(out - ref).max())
    # production: 4096-request batch x K=100; one pass
    bytes_ = 4096 * 100 * 4 * 2
    t = _model_time(bytes_ * 6, bytes_)  # ~6 flops/elem, memory-bound
    return [("kernel/skew_metrics/maxerr", err, "vs ref.py (4 metrics fused)"),
            ("kernel/skew_metrics/v5e_model_us", t * 1e6, "router fast path")]


def bench_segment_reduce() -> list[tuple]:
    from repro.kernels.segment_reduce.kernel import segment_sum_sorted
    from repro.kernels.segment_reduce.ref import segment_sum_sorted_ref
    B, nnz, D = 16, 8, 32
    rows = jax.random.normal(jax.random.key(0), (B * nnz, D))
    seg = jnp.repeat(jnp.arange(B), nnz)
    out = segment_sum_sorted(rows, seg, B, nnz, seg_tile=8, interpret=True)
    err = float(jnp.abs(out - segment_sum_sorted_ref(rows, seg, B)).max())
    # production: 65536-batch embedding bag, nnz=16, dim=128
    b, nz, d = 65536, 16, 128
    bytes_ = b * nz * d * 4 + b * d * 4
    flops = 2 * b * nz * d
    t = _model_time(flops, bytes_)
    return [("kernel/segment_reduce/maxerr", err, "vs ref.py"),
            ("kernel/segment_reduce/v5e_model_ms", t * 1e3,
             f"AI={flops/bytes_:.2f} flop/B (bandwidth-bound)")]


def run_all() -> list[tuple]:
    rows = []
    for fn in [bench_flash_attention, bench_decode_attention,
               bench_triple_score, bench_skew_metrics, bench_segment_reduce]:
        t0 = time.monotonic()
        rows.extend(fn())
        rows.append((f"{fn.__name__}/wall_s", time.monotonic() - t0,
                     "CPU interpret (not a perf number)"))
    return rows
