"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
result JSONs, or roofline the serving-side routing program.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
  PYTHONPATH=src python -m benchmarks.roofline_report --routing

``--routing`` compiles the fused retrieve-to-decision program
(`repro.core.router.route_retrieved`: Pallas/XLA triple scoring ->
device top-k -> skew metrics -> threshold decision, ONE jitted
computation) at canonical serving shapes and rooflines it from
``cost_analysis()`` + the loop-aware HLO re-derivation — the same
pipeline the dry-run records go through — so the decision program's
bottleneck (memory, at these shapes: the [B, N, Dt] feature read
dwarfs the MLP FLOPs) is tracked with the same constants as the
training cells.
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "dryrun_results"

# canonical serving shapes: (batch, padded candidates per query)
ROUTING_SHAPES = ((8, 512), (64, 512), (256, 512))


def load(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compile | peak GiB/dev | "
             "collective ops | status |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | FAIL: {r.get('error', '?')[:60]} |")
            continue
        peak = r["memory"]["peak_device_bytes"] / 2 ** 30
        nc = r["collectives"]["n_ops"]
        flag = "ok" if peak <= 16 else "ok (>16 GiB, see notes)"
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                     f"{r['compile_s']}s | {peak:.2f} | {nc} | {flag} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
             "MODEL_FLOPS | useful ratio | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl.get('model_flops', 0):.2e} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('mfu_upper_bound', 0):.3f} |")
    return "\n".join(lines)


def routing_record(batch: int, n_cand: int) -> dict:
    """Compile the fused retrieve-to-decision program at one shape and
    return a dry-run-style record (cost / collectives / roofline)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.router import RouterConfig, route_retrieved
    from repro.launch import hlo_cost
    from repro.launch.roofline import roofline_terms
    from repro.retrieval.scorer import ScorerConfig, init_scorer

    cfg = ScorerConfig()
    params = init_scorer(jax.random.PRNGKey(0), cfg)
    config = RouterConfig(metric="entropy", thresholds=(6.0,))

    def fn(feats, qemb, ncand):
        r = route_retrieved(feats, qemb, params, config, n_cand=ncand)
        return r.indices, r.probs, r.tiers, r.difficulty

    args = (jnp.zeros((batch, n_cand, cfg.d_triple), jnp.float32),
            jnp.zeros((batch, cfg.d_query), jnp.float32),
            jnp.full((batch,), n_cand, jnp.int32))
    rec: dict = {"arch": "route_retrieved",
                 "shape": f"B{batch}xN{n_cand}", "mesh": "single",
                 "n_devices": 1}
    t0 = time.monotonic()
    compiled = jax.jit(fn).lower(*args).compile()
    rec["compile_s"] = round(time.monotonic() - t0, 2)
    rec["ok"] = True
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {"peak_device_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)}
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):        # older jax: one dict per device
        ca = ca[0] if ca else {}
    lc = hlo_cost.analyze(compiled.as_text())
    rec["cost"] = {"flops": lc["flops"],
                   "bytes_accessed": lc["bytes_accessed"],
                   "transcendentals": float(ca.get("transcendentals", 0.0))}
    rec["collectives"] = {"counts": lc["collective_counts"],
                          "bytes": lc["collective_bytes"],
                          "total_bytes": lc["collective_total_bytes"],
                          "n_ops": lc["collective_n_ops"]}
    rec["roofline"] = roofline_terms(rec)
    return rec


def routing_roofline() -> list[dict]:
    recs = [routing_record(b, n) for b, n in ROUTING_SHAPES]
    print("## Roofline (fused retrieve-to-decision program)\n")
    print(roofline_table(recs))
    return recs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--routing", action="store_true",
                    help="compile + roofline the fused retrieve-to-"
                    "decision serving program instead of rendering the "
                    "dry-run tables")
    args = ap.parse_args()
    if args.routing:
        routing_roofline()
        return
    recs = load(args.mesh)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table([r for r in recs if r.get("mesh") == "single"]))


if __name__ == "__main__":
    main()
