"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
result JSONs.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "dryrun_results"


def load(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compile | peak GiB/dev | "
             "collective ops | status |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | FAIL: {r.get('error', '?')[:60]} |")
            continue
        peak = r["memory"]["peak_device_bytes"] / 2 ** 30
        nc = r["collectives"]["n_ops"]
        flag = "ok" if peak <= 16 else "ok (>16 GiB, see notes)"
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                     f"{r['compile_s']}s | {peak:.2f} | {nc} | {flag} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
             "MODEL_FLOPS | useful ratio | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl.get('model_flops', 0):.2e} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('mfu_upper_bound', 0):.3f} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()
    recs = load(args.mesh)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table([r for r in recs if r.get("mesh") == "single"]))


if __name__ == "__main__":
    main()
