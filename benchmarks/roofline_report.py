"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run
result JSONs, or roofline the serving-side routing program.

  PYTHONPATH=src python -m benchmarks.roofline_report [--mesh single]
  PYTHONPATH=src python -m benchmarks.roofline_report --routing

``--routing`` compiles the fused retrieve-to-decision program
(`repro.core.router.route_retrieved`: Pallas/XLA triple scoring ->
device top-k -> skew metrics -> threshold decision, ONE jitted
computation) at canonical serving shapes and rooflines it from
``cost_analysis()`` + the loop-aware HLO re-derivation — the same
pipeline the dry-run records go through — so the decision program's
bottleneck (memory, at these shapes: the [B, N, Dt] feature read
dwarfs the MLP FLOPs) is tracked with the same constants as the
training cells.
"""

from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path(__file__).parent / "dryrun_results"

# canonical serving shapes: (batch, padded candidates per query)
ROUTING_SHAPES = ((8, 512), (64, 512), (256, 512))


def load(mesh: str | None = None) -> list[dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh is None or r.get("mesh") == mesh:
            recs.append(r)
    return recs


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compile | peak GiB/dev | "
             "collective ops | status |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - "
                         f"| - | FAIL: {r.get('error', '?')[:60]} |")
            continue
        peak = r["memory"]["peak_device_bytes"] / 2 ** 30
        nc = r["collectives"]["n_ops"]
        flag = "ok" if peak <= 16 else "ok (>16 GiB, see notes)"
        lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                     f"{r['compile_s']}s | {peak:.2f} | {nc} | {flag} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
             "MODEL_FLOPS | useful ratio | MFU bound |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if not r.get("ok") or "roofline" not in r:
            continue                # measured-only records (select_depths)
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl.get('model_flops', 0):.2e} | "
            f"{rl.get('useful_flops_ratio', 0):.3f} | "
            f"{rl.get('mfu_upper_bound', 0):.3f} |")
    return "\n".join(lines)


def routing_record(batch: int, n_cand: int) -> dict:
    """Compile the fused retrieve-to-decision program at one shape and
    return a dry-run-style record (cost / collectives / roofline)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.core.router import RouterConfig, route_retrieved
    from repro.launch import hlo_cost
    from repro.launch.roofline import roofline_terms
    from repro.retrieval.scorer import ScorerConfig, init_scorer

    cfg = ScorerConfig()
    params = init_scorer(jax.random.PRNGKey(0), cfg)
    config = RouterConfig(metric="entropy", thresholds=(6.0,))

    def fn(feats, qemb, ncand):
        r = route_retrieved(feats, qemb, params, config, n_cand=ncand)
        return r.indices, r.probs, r.tiers, r.difficulty

    args = (jnp.zeros((batch, n_cand, cfg.d_triple), jnp.float32),
            jnp.zeros((batch, cfg.d_query), jnp.float32),
            jnp.full((batch,), n_cand, jnp.int32))
    rec: dict = {"arch": "route_retrieved",
                 "shape": f"B{batch}xN{n_cand}", "mesh": "single",
                 "n_devices": 1}
    t0 = time.monotonic()
    compiled = jax.jit(fn).lower(*args).compile()
    rec["compile_s"] = round(time.monotonic() - t0, 2)
    rec["ok"] = True
    ma = compiled.memory_analysis()
    if ma is not None:
        rec["memory"] = {"peak_device_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)}
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):        # older jax: one dict per device
        ca = ca[0] if ca else {}
    lc = hlo_cost.analyze(compiled.as_text())
    rec["cost"] = {"flops": lc["flops"],
                   "bytes_accessed": lc["bytes_accessed"],
                   "transcendentals": float(ca.get("transcendentals", 0.0))}
    rec["collectives"] = {"counts": lc["collective_counts"],
                          "bytes": lc["collective_bytes"],
                          "total_bytes": lc["collective_total_bytes"],
                          "n_ops": lc["collective_n_ops"]}
    rec["roofline"] = roofline_terms(rec)
    # MEASURED wall time next to the modeled terms: profile the program
    # we just compiled (no second compile) — block_until_ready best-of,
    # via the obs plane's profiling hook.
    from repro.obs import profile_program
    prof = profile_program(fn, args, name="route_retrieved",
                           shape=rec["shape"], iters=5, compiled=compiled)
    rec["measured"] = prof.to_dict()
    return rec


def select_depths_record(batch: int) -> dict:
    """Profile the jitted depth-selection program (`core.router.
    select_depths` — the adaptive_depth policy's second routed axis)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.router import select_depths
    from repro.obs import profile_program

    rng = np.random.default_rng(0)
    args = (jnp.asarray(rng.uniform(0, 8, batch).astype(np.float32)),
            jnp.asarray([4.0, 6.0], jnp.float32),
            jnp.asarray([25, 50, 100], jnp.int32))
    prof = profile_program(lambda d, c, o: select_depths(d, c, o), args,
                           name="select_depths", shape=f"B{batch}",
                           iters=5)
    return {"arch": "select_depths", "shape": f"B{batch}", "ok": True,
            "measured": prof.to_dict()}


def measured_table(recs: list[dict]) -> str:
    lines = ["| program | shape | compile (s) | wall (s) | GFLOP/s | "
             "GiB/s |",
             "|---|---|---|---|---|---|"]
    for r in recs:
        m = r.get("measured")
        if not m:
            continue
        lines.append(
            f"| {m['name']} | {m['shape']} | {m['compile_s']:.2f} | "
            f"{m['wall_s']:.3e} | {m['achieved_gflops']:.2f} | "
            f"{m['achieved_gbps'] / 1.073741824:.2f} |")
    return "\n".join(lines)


def routing_roofline(shapes=ROUTING_SHAPES) -> list[dict]:
    recs = [routing_record(b, n) for b, n in shapes]
    recs.append(select_depths_record(batch=shapes[-1][0]))
    print("## Roofline (fused retrieve-to-decision program)\n")
    print(roofline_table(recs))
    print("\n## Measured (block_until_ready best-of, this host)\n")
    print(measured_table(recs))
    return recs


def csv_rows(quick: bool = False) -> list[tuple]:
    """``benchmarks.run`` harness entry: measured + modeled numbers for
    the serving device programs (one shape when ``quick``)."""
    shapes = ROUTING_SHAPES[:1] if quick else ROUTING_SHAPES
    rows: list[tuple] = []
    for rec in routing_roofline(shapes):
        m = rec.get("measured") or {}
        tag = f"roofline/{rec['arch']}/{rec['shape']}"
        if m:
            rows.append((f"{tag}/wall_s", round(m["wall_s"], 6),
                         "measured block_until_ready best-of"))
            rows.append((f"{tag}/achieved_gbps",
                         round(m["achieved_gbps"], 3),
                         "HLO bytes_accessed / measured wall"))
        rl = rec.get("roofline")
        if rl:
            rows.append((f"{tag}/bound", rl["dominant"],
                         "modeled bottleneck (compute/memory/collective)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--routing", action="store_true",
                    help="compile + roofline the fused retrieve-to-"
                    "decision serving program instead of rendering the "
                    "dry-run tables")
    args = ap.parse_args()
    if args.routing:
        routing_roofline()
        return
    recs = load(args.mesh)
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table([r for r in recs if r.get("mesh") == "single"]))


if __name__ == "__main__":
    main()
