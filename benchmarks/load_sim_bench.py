"""Load-simulation benchmark: admission control on vs. off, same trace.

Replays a canonical seeded workload trace (bursty arrivals + score-skew
drift + a large-tier replica failure; see
``repro.serving.loadgen.workload.CANONICAL_TRACES``) through the tuned
canonical serving setup twice — once with the admission controller
(cost-budget feedback + SLO-aware tier-spill) and once without (exactly
today's routing) — and reports the SLO-attainment / $-per-query /
quality-proxy trade the controller buys.

Acceptance gates (asserted on every run, smoke included):

* baseline reproduces pre-admission behavior bit-for-bit: zero spills
  and executed tier mix == dispatcher decisions;
* admission keeps realized $/query inside the configured budget (and the
  expensive-tier executed share inside the share that budget implies);
* admission IMPROVES simulated SLO attainment over the baseline.

Full runs (default trace, no --smoke) also write structured JSON to
``BENCH_load_sim.json`` at the repo root — the load-serving trajectory
tracked across PRs (``--json`` overrides the path, ``--json ''``
disables writing; smoke runs don't touch the tracked file unless asked).

  PYTHONPATH=src python -m benchmarks.load_sim_bench [--smoke] [--trace NAME]
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

from repro.serving.loadgen import canonical_load_runner, canonical_trace

DEFAULT_TRACE = "bursty_drift_saturation"
SMOKE_TRACE = "smoke"
BUDGET_TOL = 1.05       # realized $/query may exceed budget by <= 5%
SHARE_TOL = 0.02        # executed share vs the budget-implied ceiling
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_load_sim.json"


def _sanitize(x):
    """nan/inf -> None so the tracked JSON stays strictly parseable."""
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, dict):
        return {k: _sanitize(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_sanitize(v) for v in x]
    return x


def run_pair(trace_name: str, record_every: int) -> dict:
    trace = canonical_trace(trace_name)
    out = {}
    for label, with_admission in (("baseline", False), ("admission", True)):
        runner = canonical_load_runner(with_admission, trace,
                                       record_every=record_every)
        t0 = time.perf_counter()
        report = runner.run(trace)
        wall = time.perf_counter() - t0
        out[label] = {"wall_s": wall, "report": report,
                      "runner": runner}
        s = report.summary
        print(f"{label:9s}: slo_attainment={s['slo_attainment']:.4f}  "
              f"$/query={s['cost_per_query']:.6f}  "
              f"quality={s['quality_proxy']:.2f}  "
              f"top_share={s['expensive_share_executed']:.3f}  "
              f"spilled={s['n_spilled']}  "
              f"recals={s['n_recalibrations']}  wall={wall:.1f}s")
    return out


def check_gates(pair: dict) -> dict:
    base = pair["baseline"]["report"].summary
    adm = pair["admission"]["report"].summary
    runner = pair["admission"]["runner"]
    spec = runner.session.spec
    budget = spec.admission.cost_budget_per_query

    # -- baseline is bit-for-bit today's routing ------------------------------
    assert base["n_spilled"] == 0, \
        f"baseline spilled {base['n_spilled']} requests with admission off"
    decisions = {str(t): c for t, c in
                 runner_decisions(pair["baseline"]["runner"]).items()}
    assert decisions == base["tier_counts_executed"], (
        f"baseline executed mix {base['tier_counts_executed']} diverged "
        f"from dispatcher decisions {decisions}")

    # -- budget invariant ------------------------------------------------------
    assert adm["cost_per_query"] <= budget * BUDGET_TOL, (
        f"admission run spent ${adm['cost_per_query']:.6f}/query against a "
        f"${budget:.6f} budget (tolerance x{BUDGET_TOL})")
    cost_model = spec.cost_model()
    models = spec.models()
    c_low, c_top = (cost_model.request_cost(models[0]),
                    cost_model.request_cost(models[-1]))
    implied_share = (budget * BUDGET_TOL - c_low) / (c_top - c_low)
    assert adm["expensive_share_executed"] <= implied_share + SHARE_TOL, (
        f"executed expensive share {adm['expensive_share_executed']:.3f} "
        f"exceeds the budget-implied ceiling {implied_share:.3f}")

    # -- SLO invariant ---------------------------------------------------------
    assert adm["slo_attainment"] > base["slo_attainment"], (
        f"admission did not improve SLO attainment: "
        f"{adm['slo_attainment']:.4f} vs baseline "
        f"{base['slo_attainment']:.4f}")

    gates = {
        "budget": budget,
        "budget_tol": BUDGET_TOL,
        "implied_top_share_ceiling": implied_share + SHARE_TOL,
        "slo_attainment_delta": (adm["slo_attainment"]
                                 - base["slo_attainment"]),
        "cost_per_query_delta": (adm["cost_per_query"]
                                 - base["cost_per_query"]),
        "quality_proxy_delta": (adm["quality_proxy"]
                                - base["quality_proxy"]),
        "passed": True,
    }
    print(f"gates PASSED: slo +{gates['slo_attainment_delta']:.4f}, "
          f"cost {gates['cost_per_query_delta']:+.6f} $/query "
          f"(budget ${budget:.6f}), quality "
          f"{gates['quality_proxy_delta']:+.2f}")
    return gates


def runner_decisions(runner) -> dict:
    return {int(t): int(c)
            for t, c in runner.session.stats.tier_counts.items()}


def csv_rows(quick: bool = True) -> list[tuple]:
    """``benchmarks.run`` harness entry: the admission-on/off pair on
    the smoke trace (full canonical trace when ``quick=False``), gates
    asserted inside."""
    pair = run_pair(SMOKE_TRACE if quick else DEFAULT_TRACE,
                    record_every=5)
    gates = check_gates(pair)
    rows: list[tuple] = []
    for label in ("baseline", "admission"):
        s = pair[label]["report"].summary
        tag = f"load_sim/{label}"
        rows.append((f"{tag}/slo_attainment", round(s["slo_attainment"], 4),
                     "completed within SLO / arrivals"))
        rows.append((f"{tag}/cost_per_query",
                     round(s["cost_per_query"], 8),
                     "$ over the executed tier mix"))
        rows.append((f"{tag}/n_spilled", s["n_spilled"],
                     "admission tier-spill demotions"))
    rows.append(("load_sim/slo_attainment_delta",
                 round(gates["slo_attainment_delta"], 4),
                 "admission - baseline (gated > 0)"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short CI trace (same gates, ~4x faster)")
    ap.add_argument("--trace", default=None,
                    help="canonical trace name (overrides --smoke choice)")
    ap.add_argument("--json", default=None,
                    help="structured-output path ('' disables; default: "
                    "repo-root BENCH_load_sim.json for full default runs)")
    ap.add_argument("--record-every", type=int, default=5,
                    help="telemetry-row thinning for the JSON trajectory")
    args = ap.parse_args()

    trace_name = args.trace or (SMOKE_TRACE if args.smoke else DEFAULT_TRACE)
    print(f"trace: {trace_name}")
    pair = run_pair(trace_name, record_every=args.record_every)
    gates = check_gates(pair)

    if args.json is not None:
        json_path = pathlib.Path(args.json) if args.json else None
    elif trace_name == DEFAULT_TRACE:
        json_path = DEFAULT_JSON     # full default run: track it
    else:
        json_path = None
    if json_path is not None:
        payload = _sanitize({
            "bench": "load_sim",
            "trace": pair["baseline"]["report"].trace,
            "gates": gates,
            "baseline": {
                "wall_s": pair["baseline"]["wall_s"],
                "summary": pair["baseline"]["report"].summary,
                "trajectory": pair["baseline"]["report"].steps,
            },
            "admission": {
                "wall_s": pair["admission"]["wall_s"],
                "summary": pair["admission"]["report"].summary,
                "trajectory": pair["admission"]["report"].steps,
            },
        })
        json_path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
        print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
