"""Routing fast-path benchmark: per-request XLA oracle vs fused batched
kernel dispatch.

The paper's pitch is that routing costs ~0.001x of a learned router; this
bench pins the serving-side realization. Two paths over identical traffic:

  oracle/per-request : the seed serving path — one `skewness.difficulty`
                       jit call + threshold compare PER REQUEST.
  kernel/batched     : the `repro.api` difficulty backend
                       (``--backend auto`` resolves to the fused Pallas
                       kernel; interpret mode off-TPU) — ONE pass for the
                       whole batch, all four metrics, column-select +
                       compare.

Sweeps B in {1, 64, 1024} x K in {50, 100, 200} (``--smoke``: a 30-second
subset) and prints ``name,value,derived`` CSV rows like benchmarks/run.py.
``--out`` appends the rows to a CSV; full default-config runs also write
structured JSON to ``BENCH_routing_fastpath.json`` at the repo root —
the perf trajectory tracked across PRs (``--json`` overrides the path;
smoke / non-default sweeps don't touch the tracked file unless asked).

Acceptance gate (asserted when the full grid runs): batched-kernel
dispatch throughput >= 5x the per-request oracle at B=1024, K=100.

  PYTHONPATH=src python -m benchmarks.routing_fastpath_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import make_backend, resolve_backend_name
from repro.core import skewness
from repro.core.router import RouterConfig, route_from_difficulty

FULL_GRID = {"B": (1, 64, 1024), "K": (50, 100, 200)}
SMOKE_GRID = {"B": (1, 64), "K": (50,)}
GATE_SHAPE = (1024, 100)  # B, K of the acceptance assertion
GATE_SPEEDUP = 5.0
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_routing_fastpath.json"


def _desc_scores(rng, b, k) -> np.ndarray:
    return np.sort(rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                   axis=1)[:, ::-1].copy()


def _time_best(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_shape(b: int, k: int, config: RouterConfig, backend,
                iters: int = 3, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    scores = _desc_scores(rng, b, k)
    thresholds = jnp.asarray(config.thresholds)

    # -- per-request oracle path (seed dispatch loop) ------------------------
    rows = [jnp.asarray(scores[i][None]) for i in range(b)]

    def per_request():
        out = []
        for row in rows:
            diff = skewness.difficulty(row, metric=config.metric,
                                       p=config.cumulative_p)
            out.append(route_from_difficulty(diff, thresholds))
        jax.block_until_ready(out)
        return out

    # -- batched backend path ------------------------------------------------
    batch = jnp.asarray(scores)

    def batched():
        res = backend.route_batch(batch, config)
        jax.block_until_ready(res.tiers)
        return res

    oracle_tiers = np.concatenate([np.asarray(t) for t in per_request()])
    kernel_tiers = np.asarray(batched().tiers)  # also warms both jits
    if not np.array_equal(oracle_tiers, kernel_tiers):
        raise AssertionError(f"path disagreement at B={b} K={k}")

    t_oracle = _time_best(per_request, iters)
    t_kernel = _time_best(batched, iters)
    return {
        "B": b, "K": k,
        "oracle_s": t_oracle, "kernel_s": t_kernel,
        "oracle_qps": b / t_oracle, "kernel_qps": b / t_kernel,
        "speedup": t_oracle / t_kernel,
    }


def run(grid: dict, iters: int = 3, metric: str = "entropy",
        backend_name: str = "auto") -> tuple[list[tuple], dict]:
    """Returns (csv_rows, results keyed by (B, K))."""
    config = RouterConfig(metric=metric, thresholds=(5.0,))
    backend = make_backend(backend_name)
    rows: list[tuple] = []
    results: dict = {}
    for k in grid["K"]:
        for b in grid["B"]:
            r = bench_shape(b, k, config, backend, iters=iters)
            results[(b, k)] = r
            tag = f"fastpath/B{b}_K{k}"
            rows.append((f"{tag}/oracle_qps", round(r["oracle_qps"], 1),
                         "per-request XLA oracle dispatch"))
            rows.append((f"{tag}/kernel_qps", round(r["kernel_qps"], 1),
                         f"fused batched dispatch ({backend.name} backend)"))
            rows.append((f"{tag}/speedup", round(r["speedup"], 2),
                         "kernel_qps / oracle_qps"))
    return rows, results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no acceptance gate)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--metric", default="entropy",
                    choices=["area", "cumulative", "entropy", "gini"])
    ap.add_argument("--backend", default="auto",
                    help="repro.api difficulty backend for the batched "
                         "path (auto | pallas | oracle | registered name)")
    ap.add_argument("--out", default=None,
                    help="append CSV rows to this file (perf trajectory)")
    ap.add_argument("--json", default=None,
                    help="write structured results JSON here ('' disables); "
                         "defaults to BENCH_routing_fastpath.json at the "
                         "repo root for full default-config runs only, so "
                         "smoke / non-default sweeps never clobber the "
                         "tracked perf trajectory")
    args = ap.parse_args()

    json_path = args.json
    if json_path is None:
        trajectory_run = (not args.smoke and args.metric == "entropy"
                          and args.backend == "auto"
                          and args.iters == ap.get_default("iters"))
        json_path = str(DEFAULT_JSON) if trajectory_run else ""

    grid = SMOKE_GRID if args.smoke else FULL_GRID
    t0 = time.monotonic()
    rows, results = run(grid, iters=args.iters, metric=args.metric,
                        backend_name=args.backend)
    wall = time.monotonic() - t0
    rows.append(("fastpath/wall_s", round(wall, 1), "total bench wall time"))

    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        with open(args.out, "a") as f:
            for name, value, derived in rows:
                f.write(f"{name},{value},{derived}\n")

    gate = None
    if GATE_SHAPE in results:
        speedup = results[GATE_SHAPE]["speedup"]
        gate = {"shape": list(GATE_SHAPE),
                "required_speedup": GATE_SPEEDUP,
                "speedup": round(speedup, 2),
                "passed": speedup >= GATE_SPEEDUP}

    if json_path:
        from repro.api.backends import default_interpret
        payload = {
            "bench": "routing_fastpath",
            "metric": args.metric,
            "backend": {
                "requested": args.backend,
                "resolved": resolve_backend_name(args.backend),
                "interpret": default_interpret(),
                "jax_backend": jax.default_backend(),
            },
            "grid": {"B": list(grid["B"]), "K": list(grid["K"])},
            "results": [results[(b, k)]
                        for k in grid["K"] for b in grid["B"]],
            "gate": gate,
            "smoke": args.smoke,
            "iters": args.iters,
            "wall_s": round(wall, 1),
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {json_path}")

    if gate is not None:
        assert gate["passed"], (
            f"batched kernel dispatch only {gate['speedup']:.1f}x the "
            f"per-request oracle at B={GATE_SHAPE[0]} K={GATE_SHAPE[1]} "
            f"(acceptance: >= {GATE_SPEEDUP}x)")
        print(f"ACCEPT: batched fast path {gate['speedup']:.1f}x "
              f"per-request oracle at B={GATE_SHAPE[0]}, K={GATE_SHAPE[1]}")


if __name__ == "__main__":
    main()
