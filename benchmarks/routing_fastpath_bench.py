"""Routing fast-path benchmark: per-request XLA oracle vs the `repro.api`
difficulty backends — now including the END-TO-END retrieve-to-decision
path.

The paper's pitch is that routing costs ~0.001x of a learned router; this
bench pins the serving-side realization. Two sections:

metric path (scores already retrieved)
  oracle/per-request : the seed serving path — one `skewness.difficulty`
                       jit call + threshold compare PER REQUEST.
  backend/batched    : the `repro.api` difficulty backend (``--backend
                       auto`` = the batch-size crossover: single-program
                       XLA oracle below ``crossover_batch``, fused Pallas
                       kernels above; interpret mode off-TPU) — ONE
                       device program for the whole batch.

end-to-end (candidate features in, tier decisions out)
  staged/per-request : the pre-fusion flow per request — XLA scoring,
                       scores back to host, numpy top-k, re-enter the
                       device for skew metrics, threshold compare.
  fused/batched      : `route_retrieved` — scoring -> top-k -> skew ->
                       decision as ONE jitted program, scores never
                       leave HBM.

Sweeps B in {1, 64, 1024} x K in {50, 100, 200} for the metric path and
B in {1, 16, 64} (N=256 candidates, K=100) end-to-end (``--smoke``: a
30-second subset) and prints ``name,value,derived`` CSV rows like
benchmarks/run.py. ``--out`` appends the rows to a CSV; full
default-config runs also write structured JSON to
``BENCH_routing_fastpath.json`` at the repo root — the perf trajectory
tracked across PRs (``--json`` overrides the path; smoke / non-default
sweeps don't touch the tracked file unless asked).

Acceptance gates (asserted when the full grid runs with the default
``auto`` backend):

* PER CELL, both sections: speedup >= 1.0 at EVERY (B, K) — the batched
  path must never lose to per-request dispatch, including B=1 (the
  regression this gate exists to catch; cells are annotated with the
  interpret mode they measured).
* headline: batched dispatch >= 5x the per-request oracle at
  B=1024, K=100.
* observability: a session with metrics + tracing ENABLED must route
  within ``OBS_OVERHEAD_MAX_RATIO`` (5%) of an obs-less session at the
  headline shape — the plane's batch-granular design is a perf contract,
  not an aspiration.

  PYTHONPATH=src python -m benchmarks.routing_fastpath_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import make_backend, resolve_backend_name
from repro.core import skewness
from repro.core.router import (RouterConfig, route_from_difficulty,
                               route_retrieved_staged)
from repro.retrieval.scorer import ScorerConfig, init_scorer, kernel_weights

FULL_GRID = {"B": (1, 64, 1024), "K": (50, 100, 200)}
SMOKE_GRID = {"B": (1, 64), "K": (50,)}
E2E_FULL = {"B": (1, 16, 64), "N": 256, "K": 100}
E2E_SMOKE = {"B": (1, 16), "N": 128, "K": 50}
GATE_SHAPE = (1024, 100)  # B, K of the headline acceptance assertion
GATE_SPEEDUP = 5.0
PER_CELL_SPEEDUP = 1.0    # every cell, both sections: never lose to
                          # per-request dispatch (the B=1 regression gate)
# Observability gate: a session with metrics + tracing ENABLED must
# dispatch within this factor of an obs-less session at the headline
# shape — the plane is batch-granular by design, so turning it on may
# not tax the fast path.
OBS_OVERHEAD_MAX_RATIO = 1.05
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_routing_fastpath.json"


def _desc_scores(rng, b, k) -> np.ndarray:
    return np.sort(rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                   axis=1)[:, ::-1].copy()


def _time_best_pair(fn_a, fn_b, iters: int) -> tuple[float, float]:
    """Best-of timing with the two sides INTERLEAVED (a, b, a, b, ...).
    Timing one side fully and then the other lets seconds-scale load
    drift land entirely on one side and flip a per-cell gate; alternating
    exposes both sides to the same noise windows while best-of still
    picks each side's quietest slot."""
    best_a = best_b = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def _cell_iters(b: int, iters: int) -> int:
    """Small batches time in microseconds — take more best-of samples so
    the per-cell >= 1.0 gate measures the path, not scheduler noise."""
    return iters if b >= 64 else max(iters, 30)


def _picked_path(backend, b: int) -> str:
    return backend.pick(b).name if hasattr(backend, "pick") else backend.name


def bench_shape(b: int, k: int, config: RouterConfig, backend,
                iters: int = 3, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    scores = _desc_scores(rng, b, k)
    thresholds = jnp.asarray(config.thresholds)

    # -- per-request oracle path (seed dispatch loop) ------------------------
    rows = [jnp.asarray(scores[i][None]) for i in range(b)]

    def per_request():
        out = []
        for row in rows:
            diff = skewness.difficulty(row, metric=config.metric,
                                       p=config.cumulative_p)
            out.append(route_from_difficulty(diff, thresholds))
        jax.block_until_ready(out)
        return out

    # -- batched backend path ------------------------------------------------
    batch = jnp.asarray(scores)

    def batched():
        res = backend.route_batch(batch, config)
        jax.block_until_ready(res.tiers)
        return res

    oracle_tiers = np.concatenate([np.asarray(t) for t in per_request()])
    kernel_tiers = np.asarray(batched().tiers)  # also warms both jits
    if not np.array_equal(oracle_tiers, kernel_tiers):
        raise AssertionError(f"path disagreement at B={b} K={k}")

    it = _cell_iters(b, iters)
    t_oracle, t_kernel = _time_best_pair(per_request, batched, it)
    return {
        "B": b, "K": k,
        "oracle_s": t_oracle, "kernel_s": t_kernel,
        "oracle_qps": b / t_oracle, "kernel_qps": b / t_kernel,
        "speedup": t_oracle / t_kernel,
        "path": _picked_path(backend, b),
        "interpret": bool(getattr(backend, "effective_interpret",
                                  lambda: jax.default_backend() != "tpu")()),
    }


def bench_e2e_shape(b: int, n: int, k: int, config: RouterConfig, backend,
                    params, d_triple: int, d_query: int,
                    iters: int = 3, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(b, n, d_triple)).astype(np.float32) * 0.3
    qemb = rng.normal(size=(b, d_query)).astype(np.float32)
    weights = kernel_weights(params)
    thresholds = jnp.asarray(config.thresholds)

    # -- staged per-request path (the pre-fusion production flow) ------------
    from repro.kernels.triple_score.ref import triple_score_ref
    score_one = jax.jit(lambda f, q: triple_score_ref(f, q[None], *weights)[0])

    def staged():
        tiers = []
        for i in range(b):
            logits = np.asarray(score_one(feats[i], qemb[i]))   # host hop
            order = np.argsort(-logits)[:k]                     # host top-k
            probs = 1.0 / (1.0 + np.exp(-logits[order]))
            diff = skewness.difficulty(jnp.asarray(probs[None]),  # re-enter
                                       metric=config.metric,
                                       p=config.cumulative_p)
            tiers.append(route_from_difficulty(diff, thresholds))
        jax.block_until_ready(tiers)
        return np.concatenate([np.asarray(t) for t in tiers])

    # -- fused device program ------------------------------------------------
    jf, jq = jnp.asarray(feats), jnp.asarray(qemb)

    def fused():
        res = backend.route_retrieved(jf, jq, params, config)
        jax.block_until_ready(res.tiers)
        return res

    staged_tiers = staged()
    fused_tiers = np.asarray(fused().tiers)  # warms the jits
    if not np.array_equal(staged_tiers, fused_tiers):
        raise AssertionError(f"end-to-end path disagreement at B={b} N={n}")

    it = _cell_iters(b, iters)
    t_staged, t_fused = _time_best_pair(staged, fused, it)
    return {
        "B": b, "N": n, "K": k,
        "staged_s": t_staged, "fused_s": t_fused,
        "staged_qps": b / t_staged, "fused_qps": b / t_fused,
        "speedup": t_staged / t_fused,
        "path": _picked_path(backend, b),
        "interpret": bool(getattr(backend, "effective_interpret",
                                  lambda: jax.default_backend() != "tpu")()),
    }


def bench_obs_overhead(b: int, k: int, metric: str = "entropy",
                       iters: int = 3, seed: int = 0) -> dict:
    """Full ``session.route`` with the observability plane enabled vs the
    NULL_OBS default, interleaved best-of — the events/instruments are
    batch-granular, so enabling them must stay within
    ``OBS_OVERHEAD_MAX_RATIO`` of disabled at the headline shape."""
    from repro.api import RouteSpec, build
    from repro.obs import Observability
    rng = np.random.default_rng(seed)
    scores = _desc_scores(rng, b, k)
    spec = RouteSpec(metric=metric, thresholds=(5.0,), top_k=k,
                     tier_names=("qwen7b", "qwen72b"))
    s_off = build(spec)
    s_on = build(spec, obs=Observability())

    def off():
        return s_off.route(scores)

    def on():
        return s_on.route(scores)

    if not np.array_equal(np.asarray(off().tiers),
                          np.asarray(on().tiers)):   # also warms both jits
        raise AssertionError(f"obs-on routing diverged at B={b} K={k}")
    # A 5% gate on a ~6ms call needs a deeper best-of than the speedup
    # cells (which clear by 10-70x): sub-gate noise would flake it.
    it = max(_cell_iters(b, iters), 15)
    t_off, t_on = _time_best_pair(off, on, it)
    ratio = t_on / t_off
    return {
        "B": b, "K": k,
        "obs_off_s": t_off, "obs_on_s": t_on,
        "ratio": round(ratio, 4),
        "max_ratio": OBS_OVERHEAD_MAX_RATIO,
        "n_events": len(s_on.obs.tracer),
        "passed": ratio <= OBS_OVERHEAD_MAX_RATIO,
    }


def run(grid: dict, iters: int = 3, metric: str = "entropy",
        backend_name: str = "auto") -> tuple[list[tuple], dict]:
    """Metric-path sweep. Returns (csv_rows, results keyed by (B, K))."""
    config = RouterConfig(metric=metric, thresholds=(5.0,))
    backend = make_backend(backend_name)
    rows: list[tuple] = []
    results: dict = {}
    for k in grid["K"]:
        for b in grid["B"]:
            r = bench_shape(b, k, config, backend, iters=iters)
            results[(b, k)] = r
            tag = f"fastpath/B{b}_K{k}"
            rows.append((f"{tag}/oracle_qps", round(r["oracle_qps"], 1),
                         "per-request XLA oracle dispatch"))
            rows.append((f"{tag}/kernel_qps", round(r["kernel_qps"], 1),
                         f"fused batched dispatch ({backend.name} backend, "
                         f"{r['path']} path)"))
            rows.append((f"{tag}/speedup", round(r["speedup"], 2),
                         "kernel_qps / oracle_qps"))
    return rows, results


def run_e2e(e2e: dict, iters: int = 3, metric: str = "entropy",
            backend_name: str = "auto",
            seed: int = 0) -> tuple[list[tuple], dict]:
    """End-to-end sweep (retrieval scoring -> decision)."""
    n, k = e2e["N"], e2e["K"]
    config = RouterConfig(metric=metric, thresholds=(5.0,), top_k=k)
    backend = make_backend(backend_name)
    cfg = ScorerConfig()
    params = init_scorer(jax.random.key(seed), cfg)
    rows: list[tuple] = []
    results: dict = {}
    for b in e2e["B"]:
        r = bench_e2e_shape(b, n, k, config, backend, params,
                            cfg.d_triple, cfg.d_query, iters=iters)
        results[(b, k)] = r
        tag = f"fastpath_e2e/B{b}_N{n}_K{k}"
        rows.append((f"{tag}/staged_qps", round(r["staged_qps"], 1),
                     "per-request staged host path (score/top-k/skew)"))
        rows.append((f"{tag}/fused_qps", round(r["fused_qps"], 1),
                     f"one-program retrieve-to-decision ({backend.name} "
                     f"backend, {r['path']} path)"))
        rows.append((f"{tag}/speedup", round(r["speedup"], 2),
                     "fused_qps / staged_qps"))
    return rows, results


def _per_cell_gate(results: dict, section: str) -> list[dict]:
    """Every measured cell must clear PER_CELL_SPEEDUP — a regression in
    ANY cell (the seed silently recorded B=1 losses) fails the bench
    instead of just being written to JSON."""
    cells = []
    for r in results.values():
        cells.append({
            "section": section,
            "B": r["B"], "K": r["K"],
            "speedup": round(r["speedup"], 2),
            "required_speedup": PER_CELL_SPEEDUP,
            "interpret": r["interpret"],
            "path": r["path"],
            "passed": r["speedup"] >= PER_CELL_SPEEDUP,
        })
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (no acceptance gates)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--metric", default="entropy",
                    choices=["area", "cumulative", "entropy", "gini"])
    ap.add_argument("--backend", default="auto",
                    help="repro.api difficulty backend for the batched "
                         "path (auto | fused | pallas | oracle | "
                         "registered name)")
    ap.add_argument("--skip-e2e", action="store_true",
                    help="metric-path section only")
    ap.add_argument("--out", default=None,
                    help="append CSV rows to this file (perf trajectory)")
    ap.add_argument("--json", default=None,
                    help="write structured results JSON here ('' disables); "
                         "defaults to BENCH_routing_fastpath.json at the "
                         "repo root for full default-config runs only, so "
                         "smoke / non-default sweeps never clobber the "
                         "tracked perf trajectory")
    args = ap.parse_args()

    json_path = args.json
    if json_path is None:
        trajectory_run = (not args.smoke and args.metric == "entropy"
                          and args.backend == "auto" and not args.skip_e2e
                          and args.iters == ap.get_default("iters"))
        json_path = str(DEFAULT_JSON) if trajectory_run else ""

    grid = SMOKE_GRID if args.smoke else FULL_GRID
    e2e_grid = E2E_SMOKE if args.smoke else E2E_FULL
    t0 = time.monotonic()
    rows, results = run(grid, iters=args.iters, metric=args.metric,
                        backend_name=args.backend)
    e2e_results: dict = {}
    if not args.skip_e2e:
        e2e_rows, e2e_results = run_e2e(e2e_grid, iters=args.iters,
                                        metric=args.metric,
                                        backend_name=args.backend)
        rows.extend(e2e_rows)
    obs_overhead = None
    if not args.smoke:
        gb, gk = GATE_SHAPE
        obs_overhead = bench_obs_overhead(gb, gk, metric=args.metric,
                                          iters=args.iters)
        tag = f"fastpath_obs/B{gb}_K{gk}"
        rows.append((f"{tag}/ratio", obs_overhead["ratio"],
                     "obs-enabled session.route / obs-off (gate <= "
                     f"{OBS_OVERHEAD_MAX_RATIO})"))
        rows.append((f"{tag}/obs_on_qps",
                     round(gb / obs_overhead["obs_on_s"], 1),
                     "full route() with metrics+tracing enabled"))
    wall = time.monotonic() - t0
    rows.append(("fastpath/wall_s", round(wall, 1), "total bench wall time"))

    for name, value, derived in rows:
        print(f"{name},{value},{derived}")

    if args.out:
        with open(args.out, "a") as f:
            for name, value, derived in rows:
                f.write(f"{name},{value},{derived}\n")

    gate = None
    if GATE_SHAPE in results:
        speedup = results[GATE_SHAPE]["speedup"]
        gate = {"shape": list(GATE_SHAPE),
                "required_speedup": GATE_SPEEDUP,
                "speedup": round(speedup, 2),
                "passed": speedup >= GATE_SPEEDUP}

    # per-cell gate: only meaningful (and only asserted) for the full grid
    # under the crossover-aware default backend
    cells = None
    if not args.smoke and args.backend == "auto":
        cells = (_per_cell_gate(results, "metric_path")
                 + _per_cell_gate(e2e_results, "end_to_end"))

    if json_path:
        backend = make_backend(args.backend)
        payload = {
            "bench": "routing_fastpath",
            "metric": args.metric,
            "backend": {
                "requested": args.backend,
                "resolved": resolve_backend_name(args.backend),
                "crossover_batch": getattr(backend, "crossover_batch", None),
                "interpret": bool(getattr(
                    backend, "effective_interpret",
                    lambda: jax.default_backend() != "tpu")()),
                "jax_backend": jax.default_backend(),
            },
            "grid": {"B": list(grid["B"]), "K": list(grid["K"])},
            "results": [results[(b, k)]
                        for k in grid["K"] for b in grid["B"]],
            "end_to_end": {
                "grid": {"B": list(e2e_grid["B"]), "N": e2e_grid["N"],
                         "K": e2e_grid["K"]},
                "results": [e2e_results[key] for key in sorted(e2e_results)],
            } if e2e_results else None,
            "gate": gate,
            "per_cell_gate": cells,
            "obs_overhead": obs_overhead,
            "smoke": args.smoke,
            "iters": args.iters,
            "wall_s": round(wall, 1),
        }
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"wrote {json_path}")

    if cells is not None:
        losing = [c for c in cells if not c["passed"]]
        assert not losing, (
            f"batched dispatch lost to per-request dispatch at "
            f"{[(c['section'], c['B'], c['K']) for c in losing]} "
            f"(per-cell acceptance: >= {PER_CELL_SPEEDUP}x; the auto "
            f"crossover exists precisely so B=1 never regresses)")
        print(f"ACCEPT: all {len(cells)} cells >= {PER_CELL_SPEEDUP}x "
              f"per-request dispatch (both sections)")
    if gate is not None:
        assert gate["passed"], (
            f"batched kernel dispatch only {gate['speedup']:.1f}x the "
            f"per-request oracle at B={GATE_SHAPE[0]} K={GATE_SHAPE[1]} "
            f"(acceptance: >= {GATE_SPEEDUP}x)")
        print(f"ACCEPT: batched fast path {gate['speedup']:.1f}x "
              f"per-request oracle at B={GATE_SHAPE[0]}, K={GATE_SHAPE[1]}")
    if obs_overhead is not None:
        assert obs_overhead["passed"], (
            f"observability-enabled dispatch is "
            f"{obs_overhead['ratio']:.3f}x the obs-off session at "
            f"B={GATE_SHAPE[0]} K={GATE_SHAPE[1]} (acceptance: <= "
            f"{OBS_OVERHEAD_MAX_RATIO}x — the plane must stay "
            f"batch-granular on the hot path)")
        print(f"ACCEPT: metrics+tracing overhead {obs_overhead['ratio']:.3f}x"
              f" (<= {OBS_OVERHEAD_MAX_RATIO}x) at "
              f"B={GATE_SHAPE[0]}, K={GATE_SHAPE[1]}")


if __name__ == "__main__":
    main()
