"""Shared KGQA routing experiment harness (paper §4 reconstruction).

Pipeline: synthetic KG + queries -> trained SubgraphRAG scorer -> top-K
score distributions -> skewness metrics -> routing sweeps, with LLM answer
quality supplied by a **calibrated oracle** (DESIGN §7.2): no 70B weights
exist here, so per-(model, dataset) Hit@1/F1 are matched to the paper's
Table 3 and decomposed over hop counts — larger models degrade less with
hops (the paper's premise: model scale buys multi-hop reasoning), and a
retrieval miss (gold edge outside top-K) slashes quality for every model
(RAG's dependence on retrieval, §2).

All routing math runs on REAL score distributions produced by the real
scorer over the real (synthetic) KG — only the generator's answer
correctness is modeled.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax.numpy as jnp

from repro.api import OracleBackend
from repro.core.cost import PAPER_QUALITY
from repro.core.router import difficulty_from_metrics
from repro.retrieval import scorer as sc
from repro.retrieval import synthetic

#: hop-degradation slope per model tier (larger model = flatter slope).
#: Slopes are set so that, after matching each model's AGGREGATE Hit@1 to
#: Table 3, the small tier meets or slightly beats the large tier on
#: 1-hop queries while losing decisively on multi-hop — the structure the
#: paper's routing results imply (Figs 5/6 show skew-routing EXCEEDING
#: all-large quality at ~0.5-0.7 call ratio, which is only possible if
#: the small model wins some easy queries).
HOP_SLOPES = {
    "qwen7b": 0.20, "qwen14b": 0.15, "qwen72b": 0.085,
    "llama8b": 0.19, "llama70b": 0.08,
}
RETRIEVAL_MISS_FACTOR = 0.25


@functools.lru_cache(maxsize=4)
def build_experiment(dataset: str = "cwq", n_queries: int = 600,
                     n_entities: int = 12_000, train_steps: int = 200,
                     seed: int = 0):
    """Build KG + scorer + per-query retrieval artifacts (cached)."""
    data = synthetic.make_dataset(dataset, n_queries=n_queries,
                                  n_entities=n_entities, seed=seed)
    cfg = sc.ScorerConfig(lr=2e-3)
    params = sc.train_scorer(data, cfg, n_steps=train_steps, seed=seed)
    records = []
    for q in data.queries:
        edges, probs = sc.retrieve(params, data.kg, data.entity_emb,
                                   data.relation_emb, q, cfg)
        if len(probs) < 10:
            continue
        gold_rank = next((i for i, e in enumerate(edges)
                          if e in q.gold_edges), None)
        records.append({
            "hops": q.hops,
            "scores": probs,
            "gold_rank": gold_rank,
            "answer_retrieved": gold_rank is not None,
        })
    return data, params, cfg, records


def _hop_quality(model: str, dataset: str, metric: str) -> dict[int, float]:
    """Per-hop accuracy such that the hop-mix-weighted mean matches the
    paper's Table 3 aggregate for (model, dataset)."""
    overall = PAPER_QUALITY[dataset][model][metric] / 100.0
    mix = synthetic.HOP_MIX[dataset]
    slope = HOP_SLOPES[model]
    # p(h) = base - slope*(h-1); solve base from the mix.
    mean_offset = sum(w * slope * (h - 1) for h, w in mix.items())
    base = overall + mean_offset
    return {h: float(np.clip(base - slope * (h - 1), 0.02, 0.98))
            for h in range(1, 5)}


def oracle_quality(records, model: str, dataset: str,
                   metric: str = "hit1") -> np.ndarray:
    """Expected per-query quality for one generator tier."""
    table = _hop_quality(model, dataset, metric)
    out = []
    for r in records:
        p = table[min(r["hops"], 4)]
        if not r["answer_retrieved"]:
            p *= RETRIEVAL_MISS_FACTOR
        elif r["gold_rank"] is not None and r["gold_rank"] > 20:
            p *= 0.7  # answer buried deep in the context
        out.append(p)
    return np.asarray(out)


def difficulty_matrix(records, p_cdf: float = 0.95) -> dict[str, np.ndarray]:
    """All four difficulty metrics for every record (larger = harder).

    Runs the `repro.api` oracle backend (XLA `core.skewness`, stacked in
    kernel column order) over the ragged score rows — the same path the
    serving session uses with ``backend="oracle"``."""
    pad_k = max(len(r["scores"]) for r in records)
    mat = np.zeros((len(records), pad_k), np.float32)
    n_valid = np.zeros(len(records), np.int32)
    for i, r in enumerate(records):
        k = len(r["scores"])
        mat[i, :k] = r["scores"]
        n_valid[i] = k
    raw = OracleBackend().metrics(jnp.asarray(mat), p_cdf=p_cdf,
                                  n_valid=jnp.asarray(n_valid))
    return {name: np.asarray(difficulty_from_metrics(raw, name))
            for name in ("area", "cumulative", "entropy", "gini")}


@dataclasses.dataclass
class RoutingCurve:
    metric: str
    ratios: np.ndarray
    quality: np.ndarray


def routing_curves(records, dataset: str, small: str, large: str,
                   quality_metric: str = "hit1", n_points: int = 11,
                   p_cdf: float = 0.95) -> dict[str, RoutingCurve]:
    """Paper Figs 5/6/8: quality vs large-LLM call ratio, per skew metric
    + random-mixing baseline + omniscient oracle."""
    qs = oracle_quality(records, small, dataset, quality_metric)
    ql = oracle_quality(records, large, dataset, quality_metric)
    diffs = difficulty_matrix(records, p_cdf)
    n = len(records)
    curves: dict[str, RoutingCurve] = {}
    fractions = np.linspace(0, 1, n_points)
    for name, d in diffs.items():
        order = np.argsort(-d, kind="stable")   # hardest first
        ratios, quality = [], []
        for f in fractions:
            cut = int(round(f * n))
            sel = np.zeros(n, bool)
            sel[order[:cut]] = True
            ratios.append(sel.mean())
            quality.append(float(np.where(sel, ql, qs).mean()))
        curves[name] = RoutingCurve(name, np.asarray(ratios), np.asarray(quality))
    # random mixing baseline (mean over shuffles)
    rng = np.random.default_rng(0)
    rand_q = []
    for f in fractions:
        cut = int(round(f * n))
        vals = []
        for _ in range(16):
            sel = np.zeros(n, bool)
            sel[rng.permutation(n)[:cut]] = True
            vals.append(float(np.where(sel, ql, qs).mean()))
        rand_q.append(float(np.mean(vals)))
    curves["random"] = RoutingCurve("random", fractions, np.asarray(rand_q))
    # omniscient oracle upper bound
    gain_order = np.argsort(-(ql - qs), kind="stable")
    oq = []
    for f in fractions:
        cut = int(round(f * n))
        sel = np.zeros(n, bool)
        sel[gain_order[:cut]] = True
        oq.append(float(np.where(sel, ql, qs).mean()))
    curves["oracle"] = RoutingCurve("oracle", fractions, np.asarray(oq))
    return curves


def call_ratio_at_parity(curve: RoutingCurve, target_quality: float) -> float:
    """Smallest large-call ratio whose quality >= target (paper's headline:
    ~0.5 at all-large parity)."""
    for r, q in zip(curve.ratios, curve.quality):
        if q >= target_quality - 1e-9:
            return float(r)
    return 1.0
