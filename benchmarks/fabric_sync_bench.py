"""Replica-fabric convergence benchmark: sync on vs. off, same fleet,
same biased traffic.

Replays a canonical drift trace through TWO identical fleets of routing
replicas. A sticky load balancer is simulated by sorting each step's
arrivals by their top retrieval score and handing each replica a
contiguous slice — replica 0 sees the easiest traffic, the last replica
the hardest, exactly the per-replica bias that makes independent
streaming calibration walk the fleet apart. One fleet exchanges
calibrator deltas through :class:`repro.serving.ReplicaFabric` every
``--sync-every`` steps; the other runs the identical sessions with no
exchange. Mid-run a cold replica joins BOTH fleets (bootstrapped from
replica 0's snapshot state-half in each, so the comparison isolates
ongoing sync, not initial state) and takes over a slice of traffic.

Convergence is measured on a fixed HOLDOUT batch drawn from the whole
trace's score distribution: a replica's "expensive-tier share" is the
fraction of holdout rows its current thresholds would send to the top
tier — i.e. how the replica would route *global* traffic, which is the
quantity per-slice calibration silently distorts.

Acceptance gates (asserted on every run, smoke included):

* the sync-enabled fleet ends with every replica's expensive-tier
  holdout share within ``SPREAD_GATE`` (2 percentage points) of every
  other's — including the mid-run cold joiner;
* the sync-disabled fleet ends measurably diverged: spread above
  ``SPREAD_GATE`` and above the sync fleet's;
* the cold replica converges (within ``SPREAD_GATE`` of the fleet mean)
  in at most ``COLD_ROUND_BOUND`` sync rounds after joining;
* sync bandwidth: the int8 delta compression beats raw f32 on the wire.

Full runs (default trace, no --smoke) also write structured JSON to
``BENCH_fabric_sync.json`` at the repo root — the fleet-consistency
trajectory tracked across PRs (``--json`` overrides the path, ``--json
''`` disables writing).

  PYTHONPATH=src python -m benchmarks.fabric_sync_bench [--smoke]
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import time

import numpy as np

from repro.api import CalibrationSpec, RouteSpec, build, make_backend
from repro.core.router import RouterConfig
from repro.serving import ReplicaFabric
from repro.serving.loadgen import canonical_trace, generate

DEFAULT_TRACE = "bursty_drift_saturation"
SMOKE_TRACE = "smoke"
N_REPLICAS = 3          # before the cold join
SYNC_EVERY = 10         # steps between fabric rounds
JOIN_AT_FRAC = 0.6      # cold replica joins at this fraction of the trace
SPREAD_GATE = 0.02      # max - min expensive-tier holdout share, 2 pp
COLD_ROUND_BOUND = 3    # sync rounds the cold joiner gets to converge in
HOLDOUT_ROWS = 2048
DEFAULT_JSON = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_fabric_sync.json"


def fleet_spec(trace) -> RouteSpec:
    """One policy for the whole fleet: entropy routing at the trace's
    retrieval depth, 70/30 streaming calibration."""
    return RouteSpec(
        metric="entropy", thresholds=(0.8 * math.log2(trace.top_k),),
        top_k=trace.top_k, tier_names=("qwen7b", "qwen72b"),
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.7, 0.3), window=512,
                                    min_samples=64, tolerance=0.08,
                                    cooldown=128))


def holdout_difficulty(trace, spec) -> np.ndarray:
    """Difficulty of a fixed global-traffic sample: every score row the
    trace emits, subsampled to HOLDOUT_ROWS with a fixed rng. Difficulty
    is threshold-independent, so this is computed exactly once."""
    rows = [step.scores for step in generate(trace) if step.n_arrivals]
    all_rows = np.concatenate(rows)
    rng = np.random.default_rng(0)
    pick = rng.choice(len(all_rows), min(HOLDOUT_ROWS, len(all_rows)),
                      replace=False)
    cfg = RouterConfig(metric=spec.metric, thresholds=spec.thresholds,
                       top_k=spec.top_k)
    res = make_backend("auto").route_batch(all_rows[pick], cfg)
    return np.asarray(res.difficulty)


def expensive_share(difficulty: np.ndarray, thresholds) -> float:
    """Fraction of the holdout a replica's thresholds send to the top
    tier (tier = #thresholds strictly below difficulty)."""
    return float(np.mean(difficulty > thresholds[-1]))


def slice_batches(scores: np.ndarray, n_slices: int) -> list[np.ndarray]:
    """The sticky load balancer: rows sorted easiest-first (spikiest top
    score) and split contiguously — slice i is replica i's biased view."""
    order = np.argsort(-scores[:, 0], kind="stable")
    return np.array_split(scores[order], n_slices)


def run_fleets(trace, spec, sync_every: int) -> dict:
    names = [f"r{i}" for i in range(N_REPLICAS)]
    fab = ReplicaFabric()
    for n in names:
        fab.add_replica(n, build(spec))
    nosync = {n: build(spec) for n in names}
    diff = holdout_difficulty(trace, spec)
    join_at = int(JOIN_AT_FRAC * trace.steps)

    shares = lambda sessions: {n: expensive_share(diff, s.thresholds)
                               for n, s in sessions.items()}
    trajectory: list[dict] = []
    cold_rounds_to_converge = None
    rounds_after_join = 0

    for step in generate(trace):
        if step.step == join_at:
            fab.add_replica("cold", build(spec), bootstrap_from="r0")
            cold = build(spec)
            cold.restore_state(json.loads(json.dumps(
                nosync["r0"].snapshot()["state"])))
            nosync["cold"] = cold
            names = names + ["cold"]
        if step.n_arrivals:
            for name, chunk in zip(names,
                                   slice_batches(step.scores, len(names))):
                if chunk.shape[0]:
                    fab.sessions[name].route(chunk)
                    nosync[name].route(chunk.copy())
        if step.step % sync_every == sync_every - 1 or \
                step.step == trace.steps - 1:
            fab.sync_round()
            sy, no = shares(fab.sessions), shares(nosync)
            trajectory.append({
                "step": step.step,
                "sync_shares": sy, "nosync_shares": no,
                "sync_spread": max(sy.values()) - min(sy.values()),
                "nosync_spread": max(no.values()) - min(no.values()),
            })
            if "cold" in sy:
                rounds_after_join += 1
                fleet_mean = np.mean([v for n, v in sy.items()
                                      if n != "cold"])
                if cold_rounds_to_converge is None and \
                        abs(sy["cold"] - fleet_mean) <= SPREAD_GATE:
                    cold_rounds_to_converge = rounds_after_join

    return {"fabric": fab, "nosync": nosync, "trajectory": trajectory,
            "cold_rounds_to_converge": cold_rounds_to_converge,
            "join_at": join_at}


def check_gates(out: dict) -> dict:
    final = out["trajectory"][-1]
    sync_spread = final["sync_spread"]
    nosync_spread = final["nosync_spread"]
    tel = out["fabric"].telemetry()

    assert sync_spread <= SPREAD_GATE, (
        f"sync fleet ended with expensive-share spread {sync_spread:.4f} "
        f"> {SPREAD_GATE} across replicas {final['sync_shares']}")
    assert nosync_spread > SPREAD_GATE, (
        f"sync-disabled fleet did not diverge: spread "
        f"{nosync_spread:.4f} <= {SPREAD_GATE} — the biased slices are "
        f"not biased enough to demonstrate anything")
    assert nosync_spread > sync_spread, (
        f"sync fleet ({sync_spread:.4f}) is no tighter than unsynced "
        f"({nosync_spread:.4f})")
    assert out["cold_rounds_to_converge"] is not None \
        and out["cold_rounds_to_converge"] <= COLD_ROUND_BOUND, (
        f"cold replica took {out['cold_rounds_to_converge']} sync rounds "
        f"to reach the fleet mean (bound: {COLD_ROUND_BOUND})")
    assert tel["bytes_sent"] < tel["bytes_sent_raw"], (
        f"delta compression lost to raw f32: {tel['bytes_sent']} vs "
        f"{tel['bytes_sent_raw']} bytes")

    gates = {
        "spread_gate": SPREAD_GATE,
        "sync_spread_final": sync_spread,
        "nosync_spread_final": nosync_spread,
        "cold_rounds_to_converge": out["cold_rounds_to_converge"],
        "cold_round_bound": COLD_ROUND_BOUND,
        "bytes_sent": tel["bytes_sent"],
        "bytes_sent_raw": tel["bytes_sent_raw"],
        "compression_ratio": tel["bytes_sent_raw"]
        / max(tel["bytes_sent"], 1),
        "passed": True,
    }
    print(f"gates PASSED: sync spread {sync_spread:.4f} vs unsynced "
          f"{nosync_spread:.4f} (gate {SPREAD_GATE}); cold converged in "
          f"{out['cold_rounds_to_converge']} round(s); wire "
          f"{tel['bytes_sent']}B vs {tel['bytes_sent_raw']}B raw "
          f"({gates['compression_ratio']:.2f}x)")
    return gates


def csv_rows(quick: bool = True) -> list[tuple]:
    """``benchmarks.run`` harness entry: fleet convergence on the smoke
    trace (full canonical trace when ``quick=False``), gates asserted
    inside."""
    trace = canonical_trace(SMOKE_TRACE if quick else DEFAULT_TRACE)
    out = run_fleets(trace, fleet_spec(trace), SYNC_EVERY)
    gates = check_gates(out)
    return [
        ("fabric_sync/sync_spread", round(gates["sync_spread_final"], 4),
         f"expensive-share spread, synced fleet (gate <= {SPREAD_GATE})"),
        ("fabric_sync/nosync_spread",
         round(gates["nosync_spread_final"], 4),
         "same fleet, no exchange (gated > sync_spread)"),
        ("fabric_sync/cold_rounds_to_converge",
         gates["cold_rounds_to_converge"],
         f"mid-run joiner (bound {COLD_ROUND_BOUND})"),
        ("fabric_sync/compression_ratio",
         round(gates["compression_ratio"], 2),
         "raw f32 wire bytes / int8 delta bytes"),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short CI trace (same gates, ~4x faster)")
    ap.add_argument("--trace", default=None,
                    help="canonical trace name (overrides --smoke choice)")
    ap.add_argument("--sync-every", type=int, default=SYNC_EVERY,
                    help="steps between fabric sync rounds")
    ap.add_argument("--json", default=None,
                    help="structured-output path ('' disables; default: "
                    "repo-root BENCH_fabric_sync.json for full default "
                    "runs)")
    args = ap.parse_args()

    trace_name = args.trace or (SMOKE_TRACE if args.smoke else DEFAULT_TRACE)
    trace = canonical_trace(trace_name)
    spec = fleet_spec(trace)
    print(f"trace: {trace_name}  replicas: {N_REPLICAS}+1 cold @ step "
          f"{int(JOIN_AT_FRAC * trace.steps)}  sync every "
          f"{args.sync_every} steps")
    t0 = time.perf_counter()
    out = run_fleets(trace, spec, args.sync_every)
    wall = time.perf_counter() - t0
    final = out["trajectory"][-1]
    for name in sorted(final["sync_shares"]):
        print(f"  {name:5s}: synced top-tier share "
              f"{final['sync_shares'][name]:.3f}  unsynced "
              f"{final['nosync_shares'][name]:.3f}")
    gates = check_gates(out)
    print(f"wall={wall:.1f}s")

    if args.json is not None:
        json_path = pathlib.Path(args.json) if args.json else None
    elif trace_name == DEFAULT_TRACE and args.sync_every == SYNC_EVERY:
        json_path = DEFAULT_JSON     # full default run: track it
    else:
        json_path = None
    if json_path is not None:
        payload = {
            "bench": "fabric_sync",
            "trace": trace.to_dict(),
            "spec": spec.to_dict(),
            "n_replicas": N_REPLICAS,
            "join_at": out["join_at"],
            "sync_every": args.sync_every,
            "gates": gates,
            "wall_s": wall,
            "final": final,
            "trajectory": out["trajectory"],
            "fabric_telemetry": out["fabric"].telemetry(),
        }
        json_path.write_text(json.dumps(payload, indent=1, sort_keys=True)
                             + "\n")
        print(f"wrote {json_path}")


if __name__ == "__main__":
    main()
