"""Routing-policy subsystem tests: the registry contract, per-policy
decision semantics, spec JSON round-trips, snapshot/restore and fabric
round-trips of every PolicySpec (cross-policy restore refuses loudly,
pre-policy envelopes restore unchanged), calibration convergence through
the hot-swap path, and the default-spec bit-for-bit parity guarantee
across every registered difficulty backend."""

import json

import numpy as np
import numpy.testing as npt
import pytest

from repro.api import (AdaptiveDepthPolicySpec, CalibrationSpec,
                       CascadePolicySpec, ModeSelectPolicySpec, RouteSpec,
                       SkewRouteSession, ThresholdPolicySpec,
                       available_policies, build, build_policy,
                       policy_fingerprint, policy_spec_from_dict)


def desc_scores(b, k=50, seed=0, skew=1.0):
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0.01, 1, (b, k)).astype(np.float32) ** skew
    return -np.sort(-raw, axis=1)


def mk_spec(**overrides):
    kw = dict(metric="entropy", thresholds=(6.0,), top_k=50,
              tier_names=("qwen7b", "qwen72b"),
              calibration=CalibrationSpec(policy="streaming",
                                          target_shares=(0.7, 0.3),
                                          window=256, min_samples=32,
                                          tolerance=0.08, cooldown=64))
    kw.update(overrides)
    return RouteSpec(**kw)


ALL_POLICY_SPECS = [
    ThresholdPolicySpec(),
    CascadePolicySpec(escalation_cutoffs=(6.2,),
                      escalation_quantiles=(0.8,),
                      self_score_cutoff=0.7),
    AdaptiveDepthPolicySpec(depth_options=(12, 25, 50),
                            depth_cutoffs=(5.5, 6.2),
                            depth_quantiles=(0.5, 0.8)),
    ModeSelectPolicySpec(modes=("no_rag", "kg_rag")),
]


def spec_for(policy_spec):
    return mk_spec(policy=policy_spec)


# -- registry -----------------------------------------------------------------

def test_registry_lists_all_strategies():
    assert set(available_policies()) >= {"threshold", "cascade",
                                         "adaptive_depth", "mode_select"}


def test_spec_from_dict_round_trips_and_rejects_unknowns():
    for ps in ALL_POLICY_SPECS:
        d = json.loads(json.dumps(ps.to_dict()))
        again = policy_spec_from_dict(d)
        assert again == ps
    with pytest.raises(ValueError, match="unknown routing policy"):
        policy_spec_from_dict({"kind": "nope"})
    with pytest.raises(ValueError, match="unknown"):
        policy_spec_from_dict({"kind": "cascade", "bogus_field": 1})


def test_build_policy_none_is_threshold():
    p = build_policy(None, n_tiers=2, tier_models=("qwen7b", "qwen72b"),
                     cost_model=mk_spec().cost_model())
    assert p.spec == ThresholdPolicySpec()


def test_route_spec_policy_validation():
    with pytest.raises(TypeError, match="PolicySpec"):
        mk_spec(policy="cascade")
    with pytest.raises(ValueError):
        # 2 tiers need exactly 1 escalation cutoff
        mk_spec(policy=CascadePolicySpec(escalation_cutoffs=(1.0, 2.0)))
    with pytest.raises(ValueError):
        # depth options must not exceed top_k
        mk_spec(policy=AdaptiveDepthPolicySpec(depth_options=(25, 999),
                                               depth_cutoffs=(6.0,)))
    with pytest.raises(ValueError):
        # one mode per tier
        mk_spec(policy=ModeSelectPolicySpec(modes=("kg_rag",)))


def test_route_spec_omits_policy_when_default():
    d = mk_spec().to_dict()
    assert "policy" not in d      # pre-policy payload compatibility
    spec = mk_spec(policy=CascadePolicySpec(escalation_cutoffs=(6.0,)))
    assert spec.to_dict()["policy"]["kind"] == "cascade"
    assert RouteSpec.from_json(spec.to_json()) == spec


def test_fingerprint_tracks_policy():
    fps = {policy_fingerprint(spec_for(ps)) for ps in ALL_POLICY_SPECS}
    assert len(fps) == len(ALL_POLICY_SPECS)
    # explicit threshold spec == default (both route bit-for-bit alike)
    assert policy_fingerprint(mk_spec()) != policy_fingerprint(
        spec_for(CascadePolicySpec(escalation_cutoffs=(6.0,))))


# -- decision semantics -------------------------------------------------------

def test_default_policy_is_bit_for_bit_pre_policy():
    scores = desc_scores(128, seed=1)
    plain, explicit = build(mk_spec()), build(
        spec_for(ThresholdPolicySpec()))
    rp, re = plain.route(scores), explicit.route(scores)
    npt.assert_array_equal(np.asarray(rp.tiers), np.asarray(re.tiers))
    assert rp.request_cost is None and re.request_cost is None
    assert rp.depths is None
    assert plain.stats.total_cost == explicit.stats.total_cost


def test_cascade_escalates_on_difficulty_or_self_score():
    session = build(spec_for(CascadePolicySpec(
        escalation_cutoffs=(6.0,), self_score_cutoff=0.8)))
    scores = desc_scores(64, seed=2)
    diff = np.asarray(session.route(scores).difficulty)
    # force one easy row to escalate on self-score alone
    ss = np.zeros(64, np.float32)
    easy = int(np.argmin(diff))
    ss[easy] = 0.99
    res = build(spec_for(CascadePolicySpec(
        escalation_cutoffs=(6.0,), self_score_cutoff=0.8))).route(
            scores, self_scores=ss)
    tiers = np.asarray(res.tiers)
    npt.assert_array_equal(tiers[easy], 1)
    npt.assert_array_equal(tiers[ss == 0], (diff[ss == 0] > 6.0))
    # escalated rows pay BOTH stages
    cm = session.spec.cost_model()
    c0, c1 = (cm.request_cost(m) for m in session.spec.models())
    cost = np.asarray(res.request_cost)
    npt.assert_allclose(cost[tiers == 1], c0 + c1)
    npt.assert_allclose(cost[tiers == 0], c0)


def test_adaptive_depth_truncates_and_prices_by_depth():
    session = build(spec_for(AdaptiveDepthPolicySpec(
        depth_options=(12, 25, 50), depth_cutoffs=(5.5, 6.2))))
    res = session.route(desc_scores(64, seed=3))
    depths = np.asarray(res.depths)
    assert set(np.unique(depths)) <= {12, 25, 50}
    diff = np.asarray(res.difficulty)
    npt.assert_array_equal(depths[diff <= 5.5], 12)
    npt.assert_array_equal(depths[diff > 6.2], 50)
    # deeper retrieval costs strictly more at the same tier
    cm = session.spec.cost_model()
    tiers = np.asarray(res.tiers)
    cost = np.asarray(res.request_cost)
    for t in np.unique(tiers):
        m = session.spec.models()[t]
        for d in np.unique(depths[tiers == t]):
            npt.assert_allclose(cost[(tiers == t) & (depths == d)],
                                cm.request_cost(m, n_triples=int(d)))


def test_mode_select_prices_modes_and_reports_topology():
    session = build(spec_for(ModeSelectPolicySpec(
        modes=("no_rag", "kg_rag"))))
    res = session.route(desc_scores(64, seed=4))
    tiers = np.asarray(res.tiers)
    cost = np.asarray(res.request_cost)
    cm = session.spec.cost_model()
    # the no-RAG tier prices the bare question, far below KG-RAG prompts
    if (tiers == 0).any() and (tiers == 1).any():
        assert cost[tiers == 0].max() < cost[tiers == 1].min()
    topo = session.policy.tier_topology()
    assert tuple(topo["modes"]) == ("no_rag", "kg_rag")
    assert len(topo["prompt_cost_per_request"]) == 2
    assert cm.request_cost("qwen72b") == pytest.approx(
        topo["prompt_cost_per_request"][1])


# -- snapshot round-trips -----------------------------------------------------

@pytest.mark.parametrize("ps", ALL_POLICY_SPECS,
                         ids=lambda p: p.kind)
def test_snapshot_restore_round_trips_every_policy(ps):
    spec = spec_for(ps)
    session = build(spec)
    session.route(desc_scores(96, seed=5),
                  self_scores=np.random.default_rng(5).uniform(0, 1, 96)
                  .astype(np.float32) if ps.kind == "cascade" else None)
    snap = json.loads(json.dumps(session.snapshot()))
    replica = SkewRouteSession.from_snapshot(snap)
    assert replica.policy.telemetry() == session.policy.telemetry()
    assert replica.policy.state_dict() == session.policy.state_dict()
    scores = desc_scores(32, seed=6)
    ra, rb = session.route(scores), replica.route(scores)
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rb.tiers))


def test_cross_policy_restore_refuses_loudly():
    casc = build(spec_for(CascadePolicySpec(escalation_cutoffs=(6.0,))))
    casc.route(desc_scores(64, seed=7))
    snap = casc.snapshot()
    depth = build(spec_for(AdaptiveDepthPolicySpec(
        depth_options=(25, 50), depth_cutoffs=(6.0,))))
    # envelope-level refusal: different spec entirely
    with pytest.raises(ValueError, match="different RouteSpec"):
        depth.restore(snap)
    # state-level refusal: a foreign policy_state block, even if someone
    # bypasses the envelope check
    with pytest.raises(ValueError, match="refusing cross-policy"):
        depth.policy.load_state_dict(snap["state"]["policy_state"])


def test_pre_policy_envelope_restores_under_default_policy():
    """A v2 envelope minted BEFORE the policy subsystem existed has no
    'policy_state' key (and no 'policy' in its spec dict): it must
    restore unchanged into a default-threshold session."""
    session = build(mk_spec())
    session.route(desc_scores(64, seed=8))
    snap = session.snapshot()
    assert "policy" not in snap["policy"]    # spec dict omits the key
    del snap["state"]["policy_state"]        # pre-policy envelope shape
    replica = build(mk_spec())
    replica.restore(snap)
    scores = desc_scores(32, seed=9)
    npt.assert_array_equal(np.asarray(session.route(scores).tiers),
                           np.asarray(replica.route(scores).tiers))


def test_stateful_policy_state_survives_snapshot():
    spec = spec_for(CascadePolicySpec(escalation_cutoffs=(6.0,),
                                      escalation_quantiles=(0.8,)))
    session = build(spec)
    session.route(desc_scores(200, seed=10))
    # trigger a hot-swap so the cutoff refits away from its spec value
    session.dispatcher.apply_config(session.dispatcher.router)
    assert session.policy.cutoffs != (6.0,)
    snap = session.snapshot()
    replica = SkewRouteSession.from_snapshot(snap)
    assert replica.policy.cutoffs == session.policy.cutoffs
    assert replica.policy.telemetry() == session.policy.telemetry()


# -- fabric round-trips -------------------------------------------------------

def fabric_pair(ps):
    from repro.distributed.replica_sync import SyncEndpoint
    s0, s1 = build(spec_for(ps)), build(spec_for(ps))
    return (s0, s1), (SyncEndpoint("r0", s0), SyncEndpoint("r1", s1))


@pytest.mark.parametrize("ps", ALL_POLICY_SPECS,
                         ids=lambda p: p.kind)
def test_fabric_round_trip_converges_every_policy(ps):
    """Identical policy specs: a publish/receive/merge round leaves both
    replicas on identical thresholds AND identical policy cutoffs."""
    (s0, s1), (e0, e1) = fabric_pair(ps)
    s0.route(desc_scores(200, seed=11, skew=0.6))
    s1.route(desc_scores(200, seed=12, skew=2.0))
    d0, d1 = e0.publish(), e1.publish()
    e0.receive(json.loads(json.dumps(d1)))
    e1.receive(json.loads(json.dumps(d0)))
    m0, m1 = e0.merge(apply=True), e1.merge(apply=True)
    assert m0.thresholds == m1.thresholds
    if hasattr(s0.policy, "cutoffs"):
        assert s0.policy.cutoffs == s1.policy.cutoffs


def test_fabric_refuses_mismatched_policy_specs():
    from repro.distributed.replica_sync import SyncEndpoint
    s0 = build(spec_for(CascadePolicySpec(escalation_cutoffs=(6.0,))))
    s1 = build(mk_spec())
    e0, e1 = SyncEndpoint("r0", s0), SyncEndpoint("r1", s1)
    s0.route(desc_scores(64, seed=13))
    with pytest.raises(ValueError, match="fingerprint"):
        e1.receive(e0.publish())


# -- calibration convergence through the hot-swap path ------------------------

def test_hot_swap_refits_policy_cutoffs_from_calibrator_window():
    spec = spec_for(CascadePolicySpec(escalation_cutoffs=(4.0,),
                                      escalation_quantiles=(0.8,)))
    session = build(spec)
    session.route(desc_scores(256, seed=14))
    session.dispatcher.apply_config(session.dispatcher.router)
    cal = session.calibrator
    want = float(np.asarray(cal.window.quantile(np.asarray([0.8])))[0])
    assert session.policy.cutoffs == pytest.approx((want,))


def test_quantile_free_cascade_never_refits():
    spec = spec_for(CascadePolicySpec(escalation_cutoffs=(6.0,)))
    session = build(spec)
    session.route(desc_scores(256, seed=15))
    session.dispatcher.apply_config(session.dispatcher.router)
    assert session.policy.cutoffs == (6.0,)    # static cutoffs stay put


# -- backend parity -----------------------------------------------------------

@pytest.mark.parametrize("backend", ["oracle", "auto", "fused", "sharded"])
def test_default_spec_routes_identically_across_backends(backend):
    """The acceptance guarantee: a default RouteSpec (no policy=) routes
    bit-for-bit identically under every registered backend."""
    scores = desc_scores(128, seed=16)
    ref = build(mk_spec(backend="auto")).route(scores)
    got = build(mk_spec(backend=backend)).route(scores)
    npt.assert_array_equal(np.asarray(ref.tiers), np.asarray(got.tiers))
    if backend == "oracle":
        # the NumPy reference matches the fused kernel to float rounding
        npt.assert_allclose(np.asarray(ref.metrics),
                            np.asarray(got.metrics), rtol=1e-5)
    else:
        npt.assert_array_equal(np.asarray(ref.metrics),
                               np.asarray(got.metrics))
    assert got.request_cost is None and got.depths is None
