"""Property-based testing shim.

Uses real `hypothesis` when installed; otherwise provides a functional
subset (seeded exhaustive-ish sampling with shrink-free reporting) so the
property tests still run in this offline container. Strategies cover what
the suite needs: integers, floats, sampled_from, lists, and numpy arrays.
"""

from __future__ import annotations

try:  # pragma: no cover - prefer the real library when available
    from hypothesis import HealthCheck
    from hypothesis import given as _hyp_given
    from hypothesis import settings as settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True

    def given(*args, **kwargs):
        """hypothesis.given with jit-friendly settings (no deadline —
        first examples pay XLA compilation)."""
        def deco(f):
            return settings(deadline=None, max_examples=15,
                            suppress_health_check=list(HealthCheck))(
                _hyp_given(*args, **kwargs)(f))
        return deco
except ImportError:  # offline fallback
    HAVE_HYPOTHESIS = False

    import functools
    import itertools

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def map(self, f):
            return _Strategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, max_tries: int = 100):
            def draw(rng):
                for _ in range(max_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too strict")
            return _Strategy(draw)

    class st:  # noqa: N801 - mimic hypothesis.strategies namespace
        @staticmethod
        def integers(min_value=0, max_value=100):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value=-1e3, max_value=1e3, allow_nan=False,
                   allow_infinity=False, width=64):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elem.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def arrays(shape_strategy, lo=-3.0, hi=3.0, dtype="float32"):
            def draw(rng):
                shape = shape_strategy.draw(rng) if hasattr(
                    shape_strategy, "draw") else shape_strategy
                return rng.uniform(lo, hi, shape).astype(dtype)
            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            return _Strategy(lambda rng: tuple(s.draw(rng)
                                               for s in strategies))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def settings(**_kwargs):  # noqa: D401 - no-op decorator factory
        def deco(f):
            return f
        return deco

    def given(*strategies, n_examples: int = 12, **kw_strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                for i in range(n_examples):
                    rng = np.random.default_rng(1000 + i)
                    drawn = [s.draw(rng) for s in strategies]
                    kdrawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    try:
                        f(*args, *drawn, **kdrawn, **kwargs)
                    except AssertionError as e:
                        raise AssertionError(
                            f"property failed on example {i}: args={drawn} "
                            f"kwargs={kdrawn}: {e}") from e
            return wrapper
        return deco
