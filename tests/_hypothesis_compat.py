"""Property-based testing shim.

Uses real `hypothesis` when installed; otherwise provides a functional
subset (seeded exhaustive-ish sampling with shrink-free reporting) so the
property tests still run in this offline container. Strategies cover what
the suite needs: integers, floats, sampled_from, lists, and numpy arrays.

The fallback implementation (``fallback_given`` / ``fallback_st``) is
defined unconditionally so the meta-tests can exercise it even when real
hypothesis is importable; ``given`` / ``st`` alias whichever path is
active.
"""

from __future__ import annotations

import functools
import inspect
import itertools  # noqa: F401 - kept for strategy authors

import numpy as np


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, f):
        return _Strategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, max_tries: int = 100):
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate too strict")
        return _Strategy(draw)


class fallback_st:  # noqa: N801 - mimic hypothesis.strategies namespace
    @staticmethod
    def integers(min_value=0, max_value=100):
        return _Strategy(lambda rng: int(rng.integers(min_value,
                                                      max_value + 1)))

    @staticmethod
    def floats(min_value=-1e3, max_value=1e3, allow_nan=False,
               allow_infinity=False, width=64):
        return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                       max_value)))

    @staticmethod
    def sampled_from(options):
        options = list(options)
        return _Strategy(lambda rng: options[int(rng.integers(0, len(options)))])

    @staticmethod
    def lists(elem, min_size=0, max_size=8):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.draw(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def arrays(shape_strategy, lo=-3.0, hi=3.0, dtype="float32"):
        def draw(rng):
            shape = shape_strategy.draw(rng) if hasattr(
                shape_strategy, "draw") else shape_strategy
            return rng.uniform(lo, hi, shape).astype(dtype)
        return _Strategy(draw)

    @staticmethod
    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng)
                                           for s in strategies))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))


def fallback_settings(**_kwargs):  # noqa: D401 - no-op decorator factory
    def deco(f):
        return f
    return deco


_POSITIONAL = (inspect.Parameter.POSITIONAL_ONLY,
               inspect.Parameter.POSITIONAL_OR_KEYWORD)


def fallback_given(*strategies, n_examples: int = 12, **kw_strategies):
    """Offline stand-in for ``hypothesis.given``.

    Follows hypothesis's convention: positional strategies fill the
    RIGHTMOST positional parameters of the wrapped function; keyword
    strategies fill their named parameters. Crucially the wrapper's
    ``__signature__`` drops the drawn parameters — ``functools.wraps``
    alone would make pytest look for fixtures named after them (the seed
    bug that broke ``test_int8_quantization_error_bound`` at collection).
    """
    def deco(f):
        sig = inspect.signature(f)
        params = list(sig.parameters.values())
        kw_names = set(kw_strategies)
        pos_names = [p.name for p in params
                     if p.kind in _POSITIONAL and p.name not in kw_names]
        n_pos = len(strategies)
        if n_pos > len(pos_names):
            raise TypeError(
                f"@given got {n_pos} positional strategies but "
                f"{f.__name__} has only {len(pos_names)} fillable params")
        drawn_names = pos_names[len(pos_names) - n_pos:] if n_pos else []
        missing = kw_names - set(sig.parameters)
        if missing:
            raise TypeError(f"@given keyword strategies {sorted(missing)} "
                            f"not parameters of {f.__name__}")
        remaining = [p for p in params
                     if p.name not in kw_names and p.name not in drawn_names]

        @functools.wraps(f)
        def wrapper(*args, **kwargs):
            for i in range(n_examples):
                rng = np.random.default_rng(1000 + i)
                drawn = {name: s.draw(rng)
                         for name, s in zip(drawn_names, strategies)}
                drawn.update({k: s.draw(rng)
                              for k, s in kw_strategies.items()})
                try:
                    f(*args, **drawn, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"property failed on example {i}: "
                        f"drawn={drawn}: {e}") from e
        # pytest inspects __signature__ for fixture injection: only the
        # NON-drawn parameters (real fixtures) may remain visible.
        wrapper.__signature__ = sig.replace(parameters=remaining)
        return wrapper
    return deco


try:  # pragma: no cover - prefer the real library when available
    from hypothesis import HealthCheck
    from hypothesis import given as _hyp_given
    from hypothesis import settings as settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True

    def given(*args, **kwargs):
        """hypothesis.given with jit-friendly settings (no deadline —
        first examples pay XLA compilation)."""
        def deco(f):
            return settings(deadline=None, max_examples=15,
                            suppress_health_check=list(HealthCheck))(
                _hyp_given(*args, **kwargs)(f))
        return deco
except ImportError:  # offline fallback
    HAVE_HYPOTHESIS = False
    st = fallback_st
    settings = fallback_settings
    given = fallback_given
