"""Pipelined serving-flow tests: dispatch -> per-tier micro-batch queues
-> tier runners, with telemetry and inline recalibration."""

import math
from collections import Counter

import numpy as np
import pytest

from repro.core.router import RouterConfig
from repro.serving.pipeline import ServingPipeline
from repro.serving.router_service import SkewRouteDispatcher
from repro.serving.scheduler import (MicroBatchQueue, Replica, Request,
                                     TierScheduler)


def desc_scores(rng, b, k=100):
    return np.sort(rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                   axis=1)[:, ::-1].copy()


# -- MicroBatchQueue ----------------------------------------------------------

def test_microbatch_queue_emits_full_batches_in_order():
    q = MicroBatchQueue(tier=0, batch_size=3)
    emitted = []
    for i in range(10):
        emitted.extend(q.push(i))
    assert emitted == [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    assert len(q) == 1 and q.n_pushed == 10 and q.n_batches == 3
    assert q.flush() == [9]
    assert q.flush() is None and len(q) == 0


def test_microbatch_queue_push_many_and_validation():
    with pytest.raises(ValueError):
        MicroBatchQueue(0, batch_size=0)
    q = MicroBatchQueue(0, batch_size=4)
    batches = q.push_many(range(9))
    assert batches == [[0, 1, 2, 3], [4, 5, 6, 7]]


# -- ServingPipeline ----------------------------------------------------------

def _mk_pipeline(rng, micro_batch=4, thresholds=None, calibrator=False):
    scores = desc_scores(rng, 64)
    if thresholds is None:
        from repro.core import skewness
        import jax.numpy as jnp
        diff = np.asarray(skewness.difficulty(jnp.asarray(scores),
                                              metric="entropy"))
        thresholds = (float(np.quantile(diff, 0.6)),)
    d = SkewRouteDispatcher(
        RouterConfig(metric="entropy", thresholds=thresholds),
        ["small", "large"])
    if calibrator:
        d.attach_calibrator([0.6, 0.4], window=128, min_samples=32,
                            tolerance=0.05, cooldown=64)
    ran = {0: [], 1: []}
    pipe = ServingPipeline(d, {t: (lambda t=t: (lambda b: ran[t].append(b)))()
                               for t in (0, 1)}, micro_batch=micro_batch)
    return pipe, d, ran, scores


def test_pipeline_routes_everything_exactly_once():
    rng = np.random.default_rng(0)
    pipe, d, ran, scores = _mk_pipeline(rng)
    res = pipe.submit(scores)
    pipe.flush()
    executed = sum(len(b) for bs in ran.values() for b in bs)
    assert executed == 64 == pipe.telemetry.n_executed
    assert pipe.telemetry.n_submitted == 64
    # every executed record went to the tier the dispatcher assigned
    for tier, batches in ran.items():
        for batch in batches:
            for rec in batch:
                assert rec.tier == tier
    stats = pipe.stats()
    assert stats["queue_depths"] == {0: 0, 1: 0}
    assert stats["tier_counts"][0] + stats["tier_counts"][1] == 64
    assert res.metrics.shape == (64, 4)


def test_pipeline_full_batches_before_flush():
    rng = np.random.default_rng(1)
    pipe, d, ran, scores = _mk_pipeline(rng, micro_batch=4)
    pipe.submit(scores)
    # only FULL micro-batches ran; the remainder sits in the queues
    assert all(len(b) == 4 for bs in ran.values() for b in bs)
    queued = sum(pipe.stats()["queue_depths"].values())
    assert pipe.telemetry.n_executed + queued == 64
    drained = pipe.flush()
    assert drained == queued
    assert pipe.telemetry.n_executed == 64


def test_pipeline_custom_payloads_and_mismatch():
    rng = np.random.default_rng(2)
    pipe, d, ran, scores = _mk_pipeline(rng, micro_batch=8)
    payloads = [f"req-{i}" for i in range(64)]
    pipe.submit(scores, payloads)
    pipe.flush()
    seen = sorted(p for bs in ran.values() for b in bs for p in b)
    assert seen == sorted(payloads)
    with pytest.raises(ValueError):
        pipe.submit(scores, payloads[:3])


def test_pipeline_missing_runner_rejected():
    rng = np.random.default_rng(3)
    d = SkewRouteDispatcher(RouterConfig(metric="gini", thresholds=(0.0,)),
                            ["small", "large"])
    with pytest.raises(ValueError, match="missing"):
        ServingPipeline(d, {0: lambda b: None})


def test_pipeline_counts_recalibrations():
    rng = np.random.default_rng(4)
    # thresholds far off target -> calibrator must fire during the stream
    pipe, d, ran, _ = _mk_pipeline(rng, thresholds=(0.0,), calibrator=True)
    for _ in range(4):
        pipe.submit(desc_scores(rng, 64))
    pipe.flush()
    assert d.stats.n_recalibrations >= 1
    assert pipe.telemetry.n_recalibrations == d.stats.n_recalibrations


def test_telemetry_restore_then_flush_executes_pending_exactly_once():
    """The PipelineTelemetry serialization contract: counters only, no
    queue payloads — so a restore over pending items is refused, and the
    sanctioned order (flush, then restore) leaves every pending item
    executed exactly once, never doubled nor dropped."""
    rng = np.random.default_rng(6)
    pipe, d, ran, scores = _mk_pipeline(rng, micro_batch=4)

    pipe.submit(scores[:10], payloads=[f"a{i}" for i in range(10)])
    assert pipe.pending() == 10 - pipe.telemetry.n_executed > 0
    # restoring over pending payloads would desync n_submitted from what
    # later flushes execute -> refused
    with pytest.raises(RuntimeError, match="pending"):
        pipe.load_telemetry(pipe.telemetry.state_dict())
    pipe.flush()
    assert pipe.telemetry.n_submitted == pipe.telemetry.n_executed == 10

    saved = pipe.telemetry.state_dict()
    # traffic past the save point, then rewind the counters to it
    pipe.submit(scores[10:20], payloads=[f"b{i}" for i in range(10)])
    pipe.flush()
    pipe.load_telemetry(saved)
    assert pipe.telemetry.state_dict() == saved
    assert pipe.executed == []       # batch history matches the counters

    # replaying the post-save traffic: counters land where the first
    # pass did, and no item was double- or zero-executed along the way
    pipe.submit(scores[10:20], payloads=[f"b{i}" for i in range(10)])
    pipe.flush()
    assert pipe.telemetry.n_submitted == pipe.telemetry.n_executed == 20
    counts = Counter(p for bs in ran.values() for b in bs for p in b)
    assert all(counts[f"a{i}"] == 1 for i in range(10))
    assert all(counts[f"b{i}"] == 2 for i in range(10))  # both passes ran


def test_telemetry_state_round_trips_and_reads_old_payloads():
    rng = np.random.default_rng(7)
    pipe, d, ran, scores = _mk_pipeline(rng)
    pipe.submit(scores)
    pipe.flush()
    state = pipe.telemetry.state_dict()
    pipe2, *_ = _mk_pipeline(np.random.default_rng(7))
    pipe2.load_telemetry(state)
    assert pipe2.telemetry.state_dict() == state
    # pre-admission snapshots carry no n_spilled key; they never spilled
    legacy = {k: v for k, v in state.items() if k != "n_spilled"}
    pipe2.load_telemetry(legacy)
    assert pipe2.telemetry.n_spilled == 0


# -- TierScheduler load probes ------------------------------------------------

def test_p99_latency_nan_below_min_samples_and_outside_horizon():
    pool = TierScheduler(0, [Replica(0, 0, speed=100.0)], batch_slots=8,
                         base_token_time=0.001)
    assert math.isnan(pool.p99_latency())          # zero completions
    for i in range(30):
        pool.submit(Request(i, 0, prompt_len=10, max_new=10,
                            deadline=99.0, submitted_at=0.0))
    t = 0.0
    while pool.pending or pool.inflight:
        t += 0.05
        pool.step(t)
    assert len(pool.done) == 30
    assert math.isfinite(pool.p99_latency())
    assert pool.queue_depth() == 0
    # still nan when the caller demands more samples than exist...
    assert math.isnan(pool.p99_latency(min_samples=31))
    assert math.isfinite(pool.p99_latency(min_samples=1))
    # ...or when nothing completed within the recency horizon: an idle
    # tier must read as NO latency pressure, not stale burst pressure
    pool.step(t + 1000.0)
    assert math.isnan(pool.p99_latency(horizon=10.0))
    assert math.isfinite(pool.p99_latency(horizon=1e6))
    # count-window path: a tiny window below the sample floor is nan too
    assert math.isnan(pool.latency_quantile(99, min_samples=20, window=5))


def test_pipeline_with_engine_bank():
    """Real LMEngines at toy scale: prompts flow through micro-batches
    into tier-appropriate generate() calls."""
    import jax.numpy as jnp
    from repro.models.layers import LMConfig
    from repro.serving.engine import EngineBank, make_engine
    rng = np.random.default_rng(5)
    bank = EngineBank({
        0: make_engine(LMConfig(name="s", n_layers=1, d_model=32, n_heads=2,
                                n_kv_heads=1, head_dim=16, d_ff=64,
                                vocab=128, dtype=jnp.float32)),
        1: make_engine(LMConfig(name="l", n_layers=2, d_model=32, n_heads=2,
                                n_kv_heads=1, head_dim=16, d_ff=64,
                                vocab=128, dtype=jnp.float32)),
    }, max_new=4)
    d = SkewRouteDispatcher(RouterConfig(metric="entropy",
                                         thresholds=(6.0,)),
                            ["small", "large"])
    pipe = ServingPipeline(d, bank.runners(), micro_batch=4)
    scores = desc_scores(rng, 8)
    prompts = [rng.integers(1, 128, rng.integers(3, 9)).astype(np.int32)
               for _ in range(8)]
    pipe.submit(scores, prompts)
    pipe.flush()
    assert pipe.telemetry.n_executed == 8
    for b in pipe.executed:
        assert b.result.tokens.shape[0] == b.size
        assert b.result.tokens.shape[1] == 4
