"""Retrieval substrate tests: KG store, sampler, scorer training."""

import numpy as np
import pytest

from repro.retrieval import synthetic
from repro.retrieval.kg import KnowledgeGraph
from repro.retrieval.sampler import sample_subgraph


@pytest.fixture(scope="module")
def small_kg():
    kg, ent, rel = synthetic.make_kg(n_entities=2000, n_relations=40, seed=7)
    return kg, ent, rel


def test_kg_csr_consistency(small_kg):
    kg, _, _ = small_kg
    for node in [0, 10, 500]:
        for ei in kg.out_edges(node):
            assert kg.heads[ei] == node


def test_khop_and_distances(small_kg):
    kg, _, _ = small_kg
    seed = int(np.argmax(np.diff(kg.offsets)))  # high-degree node
    edges = kg.khop_edges(seed, hops=2, max_edges=500)
    assert len(edges) > 0
    dist = kg.distances_from(seed, max_hops=3)
    assert dist[seed] == 0
    for ei in kg.out_edges(seed):
        assert dist[int(kg.tails[ei])] <= 1


def test_sampler_static_shapes(small_kg):
    kg, _, _ = small_kg
    seeds = np.arange(16)
    sub = sample_subgraph(kg, seeds, fanouts=(5, 3), n_nodes_max=512,
                          n_edges_max=1024, seed=0)
    assert sub.node_ids.shape == (512,)
    assert sub.src.shape == (1024,) and sub.dst.shape == (1024,)
    # padded edges point at the dummy slot
    assert (sub.src[sub.src != sub.n_valid_nodes] < sub.n_valid_nodes).all()
    assert sub.seed_mask[:16].all() and not sub.seed_mask[16:].any()


def test_query_hop_mix():
    data = synthetic.make_dataset("webqsp", n_queries=200, n_entities=3000,
                                  seed=1)
    hops = np.asarray([q.hops for q in data.queries])
    assert set(hops) <= {1, 2}
    assert 0.4 < (hops == 1).mean() < 0.9


def test_scorer_beats_untrained():
    import jax
    from repro.retrieval import scorer as sc
    data = synthetic.make_dataset("cwq", n_queries=80, n_entities=3000, seed=2)
    cfg = sc.ScorerConfig(lr=2e-3)
    trained = sc.train_scorer(data, cfg, n_steps=80, seed=2)
    untrained = sc.init_scorer(jax.random.key(99), cfg)

    def mean_rank(params):
        ranks = []
        for q in data.queries[:40]:
            edges, _ = sc.retrieve(params, data.kg, data.entity_emb,
                                   data.relation_emb, q, cfg)
            g = next((i for i, e in enumerate(edges) if e in q.gold_edges),
                     len(edges))
            ranks.append(g)
        return np.mean(ranks)

    assert mean_rank(trained) < 0.5 * mean_rank(untrained)
