"""Snapshot envelope compat matrix (ISSUE satellite): the versioned
policy/state envelope, v1-flat backward compatibility behind a warn-once
shim, state-only restore with loud policy-mismatch refusal, and
from_snapshot over both layouts."""

import dataclasses
import json
import warnings

import numpy as np
import pytest

from repro.api import (ENVELOPE_VERSION, CalibrationSpec, RouteSpec,
                       SkewRouteSession, build, policy_fingerprint)
from repro.serving import _deprecation


def mk_spec(**overrides):
    kw = dict(metric="entropy", thresholds=(6.0,), top_k=50,
              tier_names=("qwen7b", "qwen72b"),
              calibration=CalibrationSpec(policy="streaming",
                                          target_shares=(0.7, 0.3),
                                          window=256, min_samples=32,
                                          tolerance=0.08, cooldown=64))
    kw.update(overrides)
    return RouteSpec(**kw)


def routed_session(spec=None, n=128, seed=0):
    session = build(spec or mk_spec())
    rng = np.random.default_rng(seed)
    scores = -np.sort(-rng.uniform(0.01, 1, (n, 50)).astype(np.float32),
                      axis=1)
    session.route(scores)
    return session


def flat_v1_of(envelope: dict) -> dict:
    """The legacy layout, reconstructed from an envelope: spec + state
    keys inline (exactly what pre-envelope snapshot() used to emit)."""
    flat = {"schema_version": 1, "spec": envelope["policy"]}
    flat.update({k: v for k, v in envelope["state"].items()
                 if k != "policy_fingerprint"})
    return flat


# -- the envelope contract ----------------------------------------------------

def test_snapshot_is_a_versioned_policy_state_envelope():
    session = routed_session()
    snap = session.snapshot()
    assert snap["envelope_version"] == ENVELOPE_VERSION == 2
    assert snap["policy"] == session.spec.to_dict()
    state = snap["state"]
    assert state["policy_fingerprint"] == policy_fingerprint(session.spec)
    for key in ("thresholds", "next_id", "stats", "calibrator"):
        assert key in state
    # pure JSON all the way down
    assert json.loads(json.dumps(snap)) == snap


def test_envelope_to_envelope_restore_is_bit_exact():
    session = routed_session()
    snap = json.loads(json.dumps(session.snapshot()))
    replica = build(session.spec)
    replica.restore(snap)
    assert replica.snapshot() == session.snapshot()
    assert replica.thresholds == session.thresholds


# -- v1 flat backward compat --------------------------------------------------

def test_flat_v1_restores_behind_a_warn_once_shim():
    session = routed_session()
    flat = json.loads(json.dumps(flat_v1_of(session.snapshot())))
    _deprecation.reset()
    replica = build(session.spec)
    with pytest.warns(DeprecationWarning, match="flat v1"):
        replica.restore(flat)
    assert replica.snapshot() == session.snapshot()
    # warn-ONCE: a second flat restore is silent
    replica2 = build(session.spec)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        replica2.restore(flat)
    assert replica2.thresholds == session.thresholds


def test_from_snapshot_accepts_both_layouts():
    session = routed_session()
    env = json.loads(json.dumps(session.snapshot()))
    flat = flat_v1_of(env)
    _deprecation.reset()
    with pytest.warns(DeprecationWarning, match="flat v1"):
        from_flat = SkewRouteSession.from_snapshot(flat)
    from_env = SkewRouteSession.from_snapshot(env)
    assert from_env.spec == from_flat.spec == session.spec
    assert from_env.thresholds == from_flat.thresholds \
        == session.thresholds
    with pytest.raises(ValueError, match="no policy half"):
        SkewRouteSession.from_snapshot({"schema_version": 1})


# -- refusal matrix -----------------------------------------------------------

def test_restore_rejects_unknown_versions_and_foreign_policies():
    session = routed_session()
    snap = session.snapshot()
    with pytest.raises(ValueError, match="envelope_version"):
        session.restore(dict(snap, envelope_version=99))
    foreign = mk_spec(thresholds=(3.0,))
    with pytest.raises(ValueError, match="different RouteSpec"):
        build(foreign).restore(snap)
    flat = flat_v1_of(snap)
    _deprecation.reset()
    with pytest.raises(ValueError, match="different\\s+RouteSpec"):
        build(foreign).restore(flat)
    with pytest.raises(ValueError, match="schema_version"):
        session.restore({"schema_version": 7, "spec": snap["policy"]})


def test_restore_state_ships_the_state_half_between_same_policy_peers():
    session = routed_session()
    state = json.loads(json.dumps(session.snapshot()["state"]))
    peer = build(session.spec)
    peer.restore_state(state)
    assert peer.thresholds == session.thresholds
    assert peer.snapshot() == session.snapshot()


def test_restore_state_rejects_policy_mismatch_loudly():
    session = routed_session()
    state = session.snapshot()["state"]
    other = build(mk_spec(thresholds=(3.0,)))
    with pytest.raises(ValueError, match="policy_fingerprint"):
        other.restore_state(state)
    # ...including state minted before fingerprints existed
    unstamped = {k: v for k, v in state.items()
                 if k != "policy_fingerprint"}
    with pytest.raises(ValueError, match="policy_fingerprint"):
        other.restore_state(unstamped)


def test_fingerprint_tracks_policy_not_state():
    spec = mk_spec()
    assert policy_fingerprint(spec) == policy_fingerprint(mk_spec())
    tightened = dataclasses.replace(spec, thresholds=(7.0,))
    assert policy_fingerprint(spec) != policy_fingerprint(tightened)
