"""Per-kernel shape/dtype sweeps vs ref.py oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, st


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kv,s,d,bq,bk", [
    (1, 2, 1, 32, 16, 16, 16),
    (2, 4, 2, 64, 32, 16, 32),
    (1, 8, 8, 128, 64, 64, 64),   # MHA
])
def test_flash_attention_sweep(b, h, kv, s, d, bq, bk, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    q = jax.random.normal(jax.random.key(0), (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.key(1), (b, kv, s, d), dtype)
    v = jax.random.normal(jax.random.key(2), (b, kv, s, d), dtype)
    out = flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    ref = attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("kv_len", [1, 33, 96, 128])
def test_decode_attention_sweep(kv_len, dtype):
    from repro.kernels.decode_attention.kernel import decode_attention
    from repro.kernels.decode_attention.ref import decode_ref
    B, H, KV, S, D = 2, 8, 4, 128, 32
    q = jax.random.normal(jax.random.key(0), (B, H, D), dtype)
    k = jax.random.normal(jax.random.key(1), (B, KV, S, D), dtype)
    v = jax.random.normal(jax.random.key(2), (B, KV, S, D), dtype)
    out = decode_attention(q, k, v, jnp.int32(kv_len), block_k=32,
                           interpret=True)
    ref = decode_ref(q, k, v, jnp.int32(kv_len))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(np.float32),
                               ref.astype(np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,dt,dq,h,q", [(128, 20, 8, 32, 1),
                                         (256, 36, 24, 64, 3),
                                         (512, 114, 32, 128, 2)])
def test_triple_score_sweep(n, dt, dq, h, q):
    from repro.kernels.triple_score.kernel import triple_score
    from repro.kernels.triple_score.ref import triple_score_ref
    ks = jax.random.split(jax.random.key(0), 7)
    args = (jax.random.normal(ks[0], (n, dt)),
            jax.random.normal(ks[1], (q, dq)),
            jax.random.normal(ks[2], (dt, h)) * 0.2,
            jax.random.normal(ks[3], (dq, h)) * 0.2,
            jax.random.normal(ks[4], (h,)) * 0.1,
            jax.random.normal(ks[5], (h, 1)) * 0.2,
            jax.random.normal(ks[6], (1,)))
    out = triple_score(*args, tile=64, interpret=True)
    np.testing.assert_allclose(out, triple_score_ref(*args),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 30), st.integers(5, 100), st.integers(0, 100))
def test_skew_metrics_property(rows, k, seed):
    from repro.kernels.skew_metrics.kernel import skew_metrics
    from repro.kernels.skew_metrics.ref import skew_metrics_ref
    rng = np.random.default_rng(seed)
    scores = np.sort(rng.uniform(0.01, 1, (rows, k)).astype(np.float32),
                     axis=1)[:, ::-1]
    out = skew_metrics(jnp.asarray(scores), interpret=True)
    ref = skew_metrics_ref(jnp.asarray(scores))
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b,nnz,d,tile", [(8, 4, 16, 4), (16, 8, 32, 8),
                                          (32, 2, 64, 8)])
def test_segment_reduce_sweep(b, nnz, d, tile):
    from repro.kernels.segment_reduce.kernel import segment_sum_sorted
    from repro.kernels.segment_reduce.ref import segment_sum_sorted_ref
    rows = jax.random.normal(jax.random.key(0), (b * nnz, d))
    seg = jnp.repeat(jnp.arange(b), nnz)
    out = segment_sum_sorted(rows, seg, b, nnz, seg_tile=tile, interpret=True)
    ref = segment_sum_sorted_ref(rows, seg, b)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_embedding_bag_fused_vs_model_embedding_bag():
    from repro.kernels.segment_reduce.ops import embedding_bag_fused
    from repro.models.recsys import embedding_bag
    table = jax.random.normal(jax.random.key(0), (64, 8))
    ids = jax.random.randint(jax.random.key(1), (8, 4), -1, 64)
    a = embedding_bag_fused(table, ids, 8)
    b = embedding_bag(table, ids)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
