"""Meta-tests for the property-testing shim (tests/_hypothesis_compat.py).

The seed bug: the offline ``given`` fallback preserved the wrapped
function's signature via ``functools.wraps``, so pytest treated drawn
strategy parameters as fixtures and failed at collection. These tests pin
the fix — drawn parameters must vanish from the wrapper's signature —
under the fallback path unconditionally, and sanity-check whichever path
(real hypothesis or fallback) is active.
"""

import inspect

import pytest

from tests._hypothesis_compat import (HAVE_HYPOTHESIS, fallback_given,
                                      fallback_st, given, st)


# -- fallback path (always exercised, even when hypothesis is installed) ------

def test_fallback_signature_drops_positional_drawn_params():
    @fallback_given(fallback_st.integers(0, 5), fallback_st.integers(0, 5))
    def prop(a, b):
        assert 0 <= a <= 5 and 0 <= b <= 5

    assert list(inspect.signature(prop).parameters) == []
    prop()  # runs all examples with no outside arguments


def test_fallback_signature_keeps_fixture_params():
    """Fixtures precede drawn params (hypothesis fills from the right)."""
    @fallback_given(fallback_st.integers(0, 5))
    def prop(fixture_like, n):
        assert fixture_like == "ctx" and 0 <= n <= 5

    assert list(inspect.signature(prop).parameters) == ["fixture_like"]
    prop("ctx")


def test_fallback_keyword_strategies():
    @fallback_given(n=fallback_st.integers(1, 3))
    def prop(n):
        assert 1 <= n <= 3

    assert list(inspect.signature(prop).parameters) == []
    prop()


def test_fallback_failure_reports_drawn_example():
    @fallback_given(fallback_st.integers(10, 20), n_examples=3)
    def prop(n):
        assert n < 0, "always fails"

    with pytest.raises(AssertionError, match="drawn="):
        prop()


def test_fallback_rejects_too_many_strategies():
    with pytest.raises(TypeError):
        @fallback_given(fallback_st.integers(), fallback_st.integers())
        def prop(only_one):
            pass


def test_fallback_rejects_unknown_keyword_strategy():
    with pytest.raises(TypeError):
        @fallback_given(bogus=fallback_st.integers())
        def prop(n):
            pass


def test_fallback_is_deterministic():
    seen_a, seen_b = [], []

    @fallback_given(fallback_st.integers(0, 10_000), n_examples=5)
    def prop_a(n):
        seen_a.append(n)

    @fallback_given(fallback_st.integers(0, 10_000), n_examples=5)
    def prop_b(n):
        seen_b.append(n)

    prop_a()
    prop_b()
    assert seen_a == seen_b and len(seen_a) == 5


# -- active path (real hypothesis when installed, fallback otherwise) ---------

@given(st.integers(0, 100))
def test_active_given_collects_and_runs(n):
    """This test existing AT ALL is the regression check: under the seed
    shim, pytest failed to collect any positional-@given test ("fixture
    'n' not found")."""
    assert 0 <= n <= 100


def test_active_path_reports_which_backend():
    # Not an assertion of environment — just pins that the flag and the
    # aliases agree so future refactors keep them consistent.
    if HAVE_HYPOTHESIS:
        assert given is not fallback_given
    else:
        assert given is fallback_given and st is fallback_st
