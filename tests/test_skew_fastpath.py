"""Fused fast-path tests: Pallas skew_metrics vs the XLA oracle.

Property-based parity (random batches, K incl. non-multiples of 128,
ragged masks, constant and power-law score vectors) at atol 1e-5, golden
values pinning the paper's Figure-3 anchors, metric range invariants, and
the batched routing entry (`route_all_metrics`) against the per-request
oracle path. Everything runs in interpret mode (CPU container).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import skewness as sk
from repro.core.router import (RouterConfig, difficulty_from_metrics, route,
                               route_all_metrics)
from repro.kernels.skew_metrics import ops
from tests._hypothesis_compat import given, st

ATOL = 1e-5  # acceptance bar: kernel-vs-oracle parity across all metrics

# Figure-3 anchor generators: exponents solved so the K=100 area metric
# lands exactly on the paper's printed values (1.07 power-law, 65.65 flat).
FIG3_POWERLAW_ALPHA = 4.195657
FIG3_FLAT_BETA = 0.430239


def fig3_powerlaw(k=100):
    return (1.0 / np.arange(1, k + 1) ** FIG3_POWERLAW_ALPHA).astype(
        np.float32)


def fig3_flat(k=100):
    return ((1.0 - np.arange(k) / k) ** FIG3_FLAT_BETA).astype(np.float32)


def desc_scores(rng, b, k, lo=0.01, hi=1.0):
    return np.sort(rng.uniform(lo, hi, (b, k)).astype(np.float32),
                   axis=1)[:, ::-1].copy()


def kernel_vs_oracle(scores, n_valid=None, p_cdf=0.95):
    s = jnp.asarray(scores)
    nv = None if n_valid is None else jnp.asarray(n_valid)
    out = ops.skew_metrics(s, p_cdf=p_cdf, n_valid=nv, interpret=True)
    mask = None if n_valid is None else ops.mask_from_n_valid(
        nv, scores.shape[1])
    ref = ops.skew_metrics_ref(s, p_cdf=p_cdf, mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)
    return np.asarray(out)


# -- property parity ----------------------------------------------------------

@given(st.integers(1, 24), st.integers(5, 200), st.integers(0, 10_000))
def test_parity_random_batches(rows, k, seed):
    """Dense descending batches, K deliberately spanning non-multiples of
    128 (the kernel's lane padding)."""
    rng = np.random.default_rng(seed)
    kernel_vs_oracle(desc_scores(rng, rows, k))


@given(st.integers(2, 16), st.integers(10, 180), st.integers(0, 10_000))
def test_parity_ragged_masks(rows, k, seed):
    """Per-row n_valid (kernel) == prefix mask (oracle)."""
    rng = np.random.default_rng(seed)
    scores = desc_scores(rng, rows, k, lo=-0.5, hi=1.0)  # logits: negatives
    n_valid = rng.integers(1, k + 1, rows).astype(np.int32)
    kernel_vs_oracle(scores, n_valid=n_valid)


@given(st.floats(-2.0, 2.0), st.integers(2, 128))
def test_parity_constant_vectors(value, k):
    """Constant scores: area 0, uniform probs — both paths must agree on
    the degenerate normalizations."""
    scores = np.full((3, k), np.float32(value))
    out = kernel_vs_oracle(scores)
    np.testing.assert_allclose(out[:, 0], 0.0, atol=ATOL)          # area
    if value > 0:  # uniform distribution => max entropy
        np.testing.assert_allclose(out[:, 2], np.log2(k), atol=1e-4)
        np.testing.assert_allclose(out[:, 3], 0.0, atol=1e-4)      # gini


@given(st.floats(0.5, 5.0), st.integers(20, 160), st.integers(0, 100))
def test_parity_powerlaw_vectors(alpha, k, seed):
    rng = np.random.default_rng(seed)
    base = 1.0 / np.arange(1, k + 1) ** alpha
    batch = np.stack([base * s for s in rng.uniform(0.5, 2.0, 4)]).astype(
        np.float32)
    kernel_vs_oracle(batch)


@given(st.sampled_from([0.5, 0.8, 0.9, 0.95, 0.99]), st.integers(0, 1000))
def test_parity_cumulative_p_sweep(p_cdf, seed):
    """cumulative-k is the integer-valued metric (paper Fig 9 sweeps P);
    parity must hold exactly across P, not just at the 0.95 default."""
    rng = np.random.default_rng(seed)
    kernel_vs_oracle(desc_scores(rng, 8, 100), p_cdf=p_cdf)


def test_parity_lane_boundary_shapes():
    """K exactly at / around the 128-lane tile edge."""
    rng = np.random.default_rng(7)
    for k in [127, 128, 129, 255, 256]:
        kernel_vs_oracle(desc_scores(rng, 5, k))


# -- golden values (paper Figure 3) -------------------------------------------

def test_figure3_area_anchors():
    """Paper Fig 3c/3d: area 1.07 (power-law example) vs 65.65 (flat) at
    K=100 — pinned on both the oracle and the fused kernel."""
    batch = jnp.asarray(np.stack([fig3_powerlaw(), fig3_flat()]))
    oracle_area = np.asarray(sk.area_metric(batch))
    kernel_area = np.asarray(ops.skew_metrics(batch, interpret=True))[:, 0]
    for area in (oracle_area, kernel_area):
        np.testing.assert_allclose(area, [1.07, 65.65], atol=5e-3)


def test_figure3_direction_on_all_metrics():
    """The same two Figure-3 vectors must separate on every difficulty
    metric (flat = hard > power-law = easy)."""
    batch = jnp.asarray(np.stack([fig3_powerlaw(), fig3_flat()]))
    metrics = np.asarray(ops.skew_metrics(batch, interpret=True))
    for name in ops.METRIC_COLUMNS:
        diff = np.asarray(difficulty_from_metrics(jnp.asarray(metrics), name))
        assert diff[1] > diff[0], name


# -- range invariants ---------------------------------------------------------

@given(st.integers(2, 150), st.integers(0, 10_000))
def test_metric_ranges_kernel(k, seed):
    """entropy in [0, log2 K], gini in [0, 1 - 1/K], cumulative in [1, K],
    area in [0, K] — on the KERNEL output (the oracle variant lives in
    test_skewness.py)."""
    rng = np.random.default_rng(seed)
    out = np.asarray(ops.skew_metrics(jnp.asarray(desc_scores(rng, 4, k)),
                                      interpret=True))
    tol = 1e-4
    assert (out[:, 0] >= -tol).all() and (out[:, 0] <= k + tol).all()
    assert (out[:, 1] >= 1).all() and (out[:, 1] <= k).all()
    assert (out[:, 2] >= -tol).all()
    assert (out[:, 2] <= np.log2(k) + tol).all()
    assert (out[:, 3] >= -tol).all()
    assert (out[:, 3] <= 1.0 - 1.0 / k + tol).all()


def test_gini_upper_bound_attained():
    onehot = np.zeros((1, 64), np.float32)
    onehot[0, 0] = 1.0
    out = np.asarray(ops.skew_metrics(jnp.asarray(onehot), interpret=True))
    np.testing.assert_allclose(out[0, 3], 1.0 - 1.0 / 64, atol=1e-6)


# -- batched routing entry ----------------------------------------------------

@given(st.sampled_from(["area", "cumulative", "entropy", "gini"]),
       st.integers(0, 1000))
def test_route_all_metrics_matches_oracle_route(metric, seed):
    rng = np.random.default_rng(seed)
    scores = desc_scores(rng, 40, 100)
    diff = sk.difficulty(jnp.asarray(scores), metric=metric)
    thetas = tuple(np.quantile(np.asarray(diff), [0.5, 0.8]))
    cfg = RouterConfig(metric=metric, thresholds=thetas)
    oracle_tiers = np.asarray(route(jnp.asarray(scores), cfg))
    res = route_all_metrics(jnp.asarray(scores), cfg, interpret=True)
    np.testing.assert_array_equal(np.asarray(res.tiers), oracle_tiers)
    np.testing.assert_allclose(np.asarray(res.difficulty), np.asarray(diff),
                               atol=ATOL)
    assert res.metrics.shape == (40, 4)


def test_difficulty_from_metrics_rejects_unknown():
    with pytest.raises(ValueError, match="unknown metric"):
        difficulty_from_metrics(jnp.zeros((2, 4)), "nope")


def test_dispatcher_batch_matches_oracle_and_buckets():
    """dispatch_batch (fused, bucket-padded) == oracle route decisions,
    independent of batch size bucketing."""
    from repro.serving.router_service import SkewRouteDispatcher
    rng = np.random.default_rng(3)
    scores = desc_scores(rng, 50, 100)
    diff = sk.difficulty(jnp.asarray(scores), metric="gini")
    cfg = RouterConfig(metric="gini",
                       thresholds=(float(np.quantile(np.asarray(diff), 0.7)),))
    oracle_tiers = np.asarray(route(jnp.asarray(scores), cfg))
    d = SkewRouteDispatcher(cfg, ["small", "large"])
    np.testing.assert_array_equal(d.dispatch_batch(scores), oracle_tiers)
    # odd sub-batch sizes exercise different pad buckets
    got = np.concatenate([d.dispatch_batch(scores[:7]),
                          d.dispatch_batch(scores[7:19]),
                          d.dispatch_batch(scores[19:])])
    np.testing.assert_array_equal(got, oracle_tiers)
    assert d.stats.n_requests == 100
    # per-request path agrees with the batch path
    rec = d.dispatch(scores[0])
    assert rec.tier == int(oracle_tiers[0])


def test_n_valid_zero_clamps_to_one():
    """Pinned edge semantics: n_valid=0 is clamped to 1 (one degenerate
    entry, no NaNs) — it does NOT match the oracle's all-false mask,
    which reports cumulative_k = 0 (documented in kernel.py)."""
    scores = np.zeros((2, 64), np.float32)
    out = np.asarray(ops.skew_metrics(jnp.asarray(scores),
                                      n_valid=jnp.asarray([0, 0]),
                                      interpret=True))
    assert np.isfinite(out).all()
    one = np.asarray(ops.skew_metrics(jnp.asarray(scores),
                                      n_valid=jnp.asarray([1, 1]),
                                      interpret=True))
    np.testing.assert_array_equal(out, one)


def test_dispatcher_ragged_n_valid():
    from repro.serving.router_service import SkewRouteDispatcher
    rng = np.random.default_rng(4)
    k = 100
    scores = desc_scores(rng, 16, k)
    n_valid = rng.integers(5, k + 1, 16).astype(np.int32)
    cfg = RouterConfig(metric="entropy", thresholds=(5.0,))
    d = SkewRouteDispatcher(cfg, ["small", "large"])
    tiers = d.dispatch_batch(scores, n_valid=n_valid)
    mask = np.arange(k)[None, :] < n_valid[:, None]
    expected = np.asarray(route(jnp.asarray(scores), cfg,
                                mask=jnp.asarray(mask)))
    np.testing.assert_array_equal(tiers, expected)
