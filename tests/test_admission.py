"""Admission-controller tests: spec validation, the budget tighten/relax
loop, hysteresis tier-spill, nan-safe load probes, serializable state,
and the session-level integration (snapshot/restore, spec plumbing)."""

import json
import math

import numpy as np
import pytest

from repro.core.cost import CostModel
from repro.core.router import RouterConfig
from repro.core.streaming_calibrate import StreamingCalibrator
from repro.serving.admission import AdmissionController, AdmissionSpec

TIER_MODELS = ("qwen7b", "qwen72b")


def mk_controller(spec, window_vals=None, shares=(0.7, 0.3)):
    cal = StreamingCalibrator(
        RouterConfig(metric="entropy", thresholds=(0.7,)), list(shares),
        window=256, min_samples=32, tolerance=0.05, cooldown=64)
    if window_vals is not None:
        cal.window.push(np.asarray(window_vals, np.float32))
    return AdmissionController(cal, CostModel(), TIER_MODELS, spec), cal


def uniform_window(n=256):
    """A [0, 1] difficulty grid: window quantiles are exact by design."""
    return np.linspace(0.0, 1.0, n)


# -- AdmissionSpec ------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="cost_budget_per_query"):
        AdmissionSpec(cost_budget_per_query=0.0)
    with pytest.raises(ValueError, match="hysteresis"):
        AdmissionSpec(spill_on=0.5, spill_off=0.5)
    with pytest.raises(ValueError, match="hysteresis"):
        AdmissionSpec(spill_on=0.5, spill_off=0.8)
    with pytest.raises(ValueError, match="spill_margin"):
        AdmissionSpec(spill_margin=1.0)
    with pytest.raises(ValueError, match="p99_slo"):
        AdmissionSpec(p99_slo=-1.0)
    with pytest.raises(ValueError, match="control_interval"):
        AdmissionSpec(control_interval=0)
    with pytest.raises(ValueError, match="pressure_beta"):
        AdmissionSpec(pressure_beta=0.0)


def test_spec_json_round_trip_and_unknown_fields():
    spec = AdmissionSpec(cost_budget_per_query=3e-4, p99_slo=1.0,
                         queue_depth_slo=24, spill_off=0.5)
    assert AdmissionSpec.from_dict(json.loads(json.dumps(spec.to_dict()))) \
        == spec
    with pytest.raises(ValueError, match="unknown AdmissionSpec fields"):
        AdmissionSpec.from_dict({"burst_budget": 1.0})


# -- controller construction --------------------------------------------------

def test_controller_requires_calibrator_and_matching_models():
    with pytest.raises(ValueError, match="calibrator"):
        AdmissionController(None, CostModel(), TIER_MODELS, AdmissionSpec())
    cal = StreamingCalibrator(RouterConfig(metric="entropy",
                                           thresholds=(0.7,)),
                              [0.7, 0.3], window=64, min_samples=16)
    with pytest.raises(ValueError, match="tier models"):
        AdmissionController(cal, CostModel(),
                            ("qwen7b", "qwen14b", "qwen72b"),
                            AdmissionSpec())


def test_controller_budget_requires_priced_tiers():
    cal = StreamingCalibrator(RouterConfig(metric="entropy",
                                           thresholds=(0.7,)),
                              [0.7, 0.3], window=64, min_samples=16)
    with pytest.raises(ValueError, match="no cost_per_mtok"):
        AdmissionController(cal, CostModel(), ("mystery7b", "qwen72b"),
                            AdmissionSpec(cost_budget_per_query=1e-4))
    # without a budget the same unpriced tier is fine ($0 stand-in)
    AdmissionController(cal, CostModel(), ("mystery7b", "qwen72b"),
                        AdmissionSpec())


# -- spill loop ---------------------------------------------------------------

def spill_spec(**kw):
    """Spill-only knobs: min_top_share pinned at the 0.3 baseline so the
    quantile loop cannot move shares under the test."""
    kw.setdefault("queue_depth_slo", 10)
    kw.setdefault("spill_on", 1.0)
    kw.setdefault("spill_off", 0.5)
    kw.setdefault("spill_margin", 0.10)
    kw.setdefault("pressure_beta", 1.0)   # pressure == raw, deterministic
    kw.setdefault("min_top_share", 0.3)
    return AdmissionSpec(**kw)


def test_spill_engages_and_demotes_only_the_marginal_band():
    ctrl, _ = mk_controller(spill_spec(), uniform_window())
    ctrl.observe_tier_load(1, queue_depth=20)   # raw pressure 2.0
    assert ctrl.control_step() is None          # shares pinned: spill only
    assert ctrl.spill_active
    # cut = 1 - 0.3 = 0.7; band = quantile(0.8) of the uniform grid
    assert ctrl.marginal_cutoff() == pytest.approx(0.8, abs=0.01)
    tiers = np.array([0, 1, 1, 1])
    diff = np.array([0.10, 0.75, 0.95, 0.79])
    out, spilled = ctrl.apply(tiers, diff)
    # marginal top-tier calls (0.75, 0.79) demote one tier; the genuinely
    # hard 0.95 keeps the big model; the cheap-tier call is untouched
    assert out.tolist() == [0, 0, 1, 0] and spilled == 2
    assert tiers.tolist() == [0, 1, 1, 1]   # caller's array not mutated
    assert ctrl.n_spilled == 2


def test_spill_hysteresis_is_sticky_between_watermarks():
    ctrl, _ = mk_controller(spill_spec(), uniform_window())
    ctrl.observe_tier_load(1, queue_depth=20)
    ctrl.control_step()
    assert ctrl.spill_active
    ctrl.observe_tier_load(1, queue_depth=7)    # 0.7: between watermarks
    ctrl.control_step()
    assert ctrl.spill_active                    # still ON
    ctrl.observe_tier_load(1, queue_depth=2)    # 0.2 <= spill_off
    ctrl.control_step()
    assert not ctrl.spill_active
    ctrl.observe_tier_load(1, queue_depth=7)    # between watermarks again
    ctrl.control_step()
    assert not ctrl.spill_active                # ...and stays OFF
    kinds = [e["kind"] for e in ctrl.events]
    assert kinds == ["spill_on", "spill_off"]


def test_no_spill_when_budgets_and_load_are_slack():
    ctrl, _ = mk_controller(spill_spec(cost_budget_per_query=1.0),
                            uniform_window())
    ctrl.observe_tier_load(1, queue_depth=0)
    for _ in range(5):
        assert ctrl.control_step() is None
    tiers = np.array([1, 1, 0, 1])
    out, spilled = ctrl.apply(tiers, np.array([0.71, 0.75, 0.1, 0.99]))
    assert spilled == 0 and out is tiers        # untouched, not even copied
    assert not ctrl.spill_active and ctrl.n_spilled == 0


def test_nan_p99_is_no_signal_not_pressure():
    ctrl, _ = mk_controller(spill_spec(p99_slo=1.0), uniform_window())
    ctrl.observe_tier_load(1, queue_depth=0, p99_latency=float("nan"))
    ctrl.control_step()
    assert ctrl.pressure == 0.0 and not ctrl.spill_active
    # a real p99 breach IS pressure
    ctrl.observe_tier_load(1, queue_depth=0, p99_latency=3.0)
    ctrl.control_step()
    assert ctrl.pressure == pytest.approx(3.0)
    assert ctrl.spill_active


# -- budget loop --------------------------------------------------------------

def budget_spec(budget=2e-4, **kw):
    kw.setdefault("cost_budget_per_query", budget)
    kw.setdefault("control_interval", 1)
    kw.setdefault("pressure_beta", 1.0)
    kw.setdefault("tighten_step", 0.05)
    kw.setdefault("relax_step", 0.05)
    return AdmissionSpec(**kw)


def test_over_budget_tightens_and_slack_relaxes_to_baseline():
    ctrl, cal = mk_controller(budget_spec(), uniform_window())
    theta0 = cal.config.thresholds[0]
    # an all-expensive batch drives the $/query EWMA far over budget
    ctrl.apply(np.ones(64, np.int64), np.full(64, 0.9))
    cfg = ctrl.control_step()
    assert ctrl.n_tighten == 1 and ctrl.shares[1] == pytest.approx(0.25)
    assert cal.target_shares == ctrl.shares     # drift loop now aims here
    assert cfg is not None and cfg.thresholds[0] > theta0  # stricter cut
    # cheap traffic brings the EWMA under budget -> relax, capped at the
    # spec baseline
    ctrl.apply(np.zeros(64, np.int64), np.full(64, 0.1))
    cfg = ctrl.control_step()
    assert ctrl.n_relax == 1 and ctrl.shares[1] == pytest.approx(0.30)
    assert cfg.thresholds[0] == pytest.approx(theta0, abs=0.02)
    # already at baseline: nothing further to relax
    ctrl.apply(np.zeros(64, np.int64), np.full(64, 0.1))
    assert ctrl.control_step() is None and ctrl.n_relax == 1


def test_tighten_respects_min_top_share_floor():
    ctrl, _ = mk_controller(budget_spec(min_top_share=0.10),
                            uniform_window())
    for _ in range(20):
        ctrl.apply(np.ones(64, np.int64), np.full(64, 0.9))
        ctrl.control_step()
    assert ctrl.shares[1] == pytest.approx(0.10)
    assert math.isclose(sum(ctrl.shares), 1.0)


def test_control_actions_wait_for_a_populated_window():
    ctrl, _ = mk_controller(budget_spec())     # empty calibrator window
    ctrl.apply(np.ones(64, np.int64), np.full(64, 0.9))
    assert ctrl.control_step() is None
    assert ctrl.n_tighten == 0 and ctrl.shares == (0.7, 0.3)
    assert math.isnan(ctrl.marginal_cutoff())


def test_control_interval_rate_limits_quantile_actions():
    ctrl, _ = mk_controller(budget_spec(control_interval=128),
                            uniform_window())
    for _ in range(4):                         # 256 requests, all expensive
        ctrl.apply(np.ones(64, np.int64), np.full(64, 0.9))
        ctrl.control_step()
    assert ctrl.n_tighten <= 256 // 128 + 1


# -- serializable state -------------------------------------------------------

def test_state_dict_json_round_trips_bit_exactly():
    ctrl, _ = mk_controller(spill_spec(cost_budget_per_query=2e-4),
                            uniform_window())
    ctrl.observe_tier_load(0, 3, p99_latency=0.4)
    ctrl.observe_tier_load(1, 20, p99_latency=float("nan"))
    ctrl.control_step()
    ctrl.apply(np.array([1, 1, 0, 1]), np.array([0.75, 0.95, 0.1, 0.79]))
    state = json.loads(json.dumps(ctrl.state_dict()))
    ctrl2, cal2 = mk_controller(spill_spec(cost_budget_per_query=2e-4),
                                uniform_window())
    ctrl2.load_state_dict(state)
    assert ctrl2.state_dict() == ctrl.state_dict()
    assert ctrl2.spill_active and ctrl2.n_spilled == ctrl.n_spilled
    assert cal2.target_shares == ctrl.shares


def test_load_state_dict_rejects_tier_mismatch():
    ctrl, _ = mk_controller(spill_spec(), uniform_window())
    state = ctrl.state_dict()
    state["shares"] = [0.5, 0.3, 0.2]
    with pytest.raises(ValueError, match="tier"):
        ctrl.load_state_dict(state)


# -- session / spec integration ----------------------------------------------

def desc_scores(rng, b, k=50, alpha_lo=0.2, alpha_hi=2.5):
    alphas = rng.uniform(alpha_lo, alpha_hi, b)
    base = 1.0 / np.arange(1, k + 1)[None, :] ** alphas[:, None]
    noise = rng.uniform(0.95, 1.05, (b, k))
    return np.sort((base * noise).astype(np.float32), axis=1)[:, ::-1].copy()


def mk_route_spec(admission=None):
    from repro.api import CalibrationSpec, RouteSpec
    return RouteSpec(
        metric="entropy", thresholds=(6.0,), top_k=50,
        tier_names=TIER_MODELS,
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.7, 0.3), window=256,
                                    min_samples=32, tolerance=0.08,
                                    cooldown=64),
        admission=admission)


def test_route_spec_admission_field_round_trips_and_validates():
    from repro.api import CalibrationSpec, RouteSpec
    adm = AdmissionSpec(cost_budget_per_query=3e-4, p99_slo=1.0)
    spec = mk_route_spec(adm)
    again = RouteSpec.from_dict(json.loads(spec.to_json()))
    assert again == spec and again.admission == adm
    assert RouteSpec.from_dict(json.loads(mk_route_spec().to_json())) \
        .admission is None
    with pytest.raises(ValueError, match="streaming"):
        RouteSpec(metric="entropy", thresholds=(6.0,), top_k=50,
                  tier_names=TIER_MODELS,
                  calibration=CalibrationSpec(policy="static"),
                  admission=adm)


def test_session_admission_requires_runners_and_probe_requires_admission():
    from repro.api import build
    with pytest.raises(ValueError, match="runners"):
        build(mk_route_spec(AdmissionSpec()))
    plain = build(mk_route_spec(), runners={0: list, 1: list})
    with pytest.raises(RuntimeError, match="no admission controller"):
        plain.observe_tier_load(1, 5)


def test_session_snapshot_restore_round_trips_admission_state():
    from repro.api import SkewRouteSession, build
    adm = AdmissionSpec(cost_budget_per_query=2e-4, p99_slo=1.0,
                        queue_depth_slo=8, spill_off=0.5,
                        control_interval=32, pressure_beta=1.0)
    spec = mk_route_spec(adm)
    rng = np.random.default_rng(0)
    runners = {0: list, 1: list}
    session = build(spec, runners=runners)
    for _ in range(4):                  # populate the calibrator window
        session.submit(desc_scores(rng, 64))
    session.observe_tier_load(1, queue_depth=40)   # saturate -> spill
    session.submit(desc_scores(rng, 64))
    session.flush()
    assert session.admission.spill_active
    assert session.telemetry()["admission"]["n_seen"] == 320

    snap = json.loads(json.dumps(session.snapshot()))
    replica = SkewRouteSession.from_snapshot(snap, runners={0: list, 1: list})
    assert replica.admission.state_dict() == session.admission.state_dict()
    assert replica.admission.spill_active
    assert replica.thresholds == session.thresholds
    assert replica.calibrator.target_shares == session.admission.shares
    assert replica.pipeline.telemetry.state_dict() \
        == session.pipeline.telemetry.state_dict()
    # and the replica keeps routing from that exact state
    replica.submit(desc_scores(np.random.default_rng(1), 32))
    replica.flush()
    assert replica.admission.n_seen == session.admission.n_seen + 32


def test_pipeline_admission_requires_attached_calibrator():
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.router_service import SkewRouteDispatcher
    d = SkewRouteDispatcher(RouterConfig(metric="entropy",
                                         thresholds=(6.0,)),
                            list(TIER_MODELS))  # no calibrator attached
    ctrl, _ = mk_controller(AdmissionSpec())
    with pytest.raises(ValueError, match="calibrator"):
        ServingPipeline(d, {0: list, 1: list}, admission=ctrl)


# -- >=3-tier cascade spill ---------------------------------------------------

TIER3_MODELS = ("qwen7b", "qwen14b", "qwen72b")


def mk_controller3(spec, window_vals=None, shares=(0.5, 0.3, 0.2)):
    cal = StreamingCalibrator(
        RouterConfig(metric="entropy", thresholds=(0.4, 0.7)), list(shares),
        window=256, min_samples=32, tolerance=0.05, cooldown=64)
    if window_vals is not None:
        cal.window.push(np.asarray(window_vals, np.float32))
    return AdmissionController(cal, CostModel(), TIER3_MODELS, spec), cal


def test_cascade_spills_past_a_saturated_middle_tier():
    ctrl, _ = mk_controller3(spill_spec(), uniform_window())
    ctrl.observe_tier_load(2, queue_depth=20)   # top saturated
    ctrl.observe_tier_load(1, queue_depth=20)   # ...and the next one too
    ctrl.control_step()
    assert ctrl.tier_spill == {1: True, 2: True}
    assert ctrl.spill_target() == 0             # skip the saturated middle
    tiers = np.array([2, 2, 1, 0])
    # cut = 1 - 0.2 = 0.8; marginal band = (0.8, 0.9] quantiles
    out, spilled = ctrl.apply(tiers, np.array([0.85, 0.99, 0.5, 0.1]))
    assert out.tolist() == [0, 2, 1, 0] and spilled == 1
    # middle tier recovers -> demotions land one tier down again
    ctrl.observe_tier_load(1, queue_depth=2)    # 0.2 <= spill_off
    ctrl.control_step()
    assert ctrl.tier_spill == {1: False, 2: True}
    assert ctrl.spill_target() == 1
    out, spilled = ctrl.apply(np.array([2]), np.array([0.85]))
    assert out.tolist() == [1] and spilled == 1


def test_cascade_is_bounded_at_tier_zero():
    ctrl, _ = mk_controller3(spill_spec(), uniform_window())
    for t in (1, 2):
        ctrl.observe_tier_load(t, queue_depth=50)
    ctrl.control_step()
    assert ctrl.spill_target() == 0             # never negative
    # spill_on/off events carry the tier that toggled
    tiers = {e["tier"] for e in ctrl.events if e["kind"] == "spill_on"}
    assert tiers == {1, 2}


def test_cascade_hysteresis_is_per_tier():
    ctrl, _ = mk_controller3(spill_spec(), uniform_window())
    ctrl.observe_tier_load(2, queue_depth=20)
    ctrl.observe_tier_load(1, queue_depth=20)
    ctrl.control_step()
    # middle tier drops between watermarks: flag stays engaged (sticky)
    ctrl.observe_tier_load(1, queue_depth=7)
    ctrl.control_step()
    assert ctrl.tier_spill[1] and ctrl.spill_target() == 0
    # two-tier topologies are untouched by the cascade: top-1 is tier 0
    ctrl2, _ = mk_controller(spill_spec(), uniform_window())
    ctrl2.observe_tier_load(1, queue_depth=20)
    ctrl2.control_step()
    assert ctrl2.spill_target() == 0


def test_cascade_state_round_trips_and_loads_legacy_flat_state():
    ctrl, _ = mk_controller3(spill_spec(), uniform_window())
    ctrl.observe_tier_load(2, queue_depth=20)
    ctrl.observe_tier_load(1, queue_depth=20)
    ctrl.control_step()
    state = json.loads(json.dumps(ctrl.state_dict()))
    assert state["tier_spill"] == {"1": True, "2": True}
    assert state["spill_active"] is True        # flat pair still present
    ctrl2, _ = mk_controller3(spill_spec(), uniform_window())
    ctrl2.load_state_dict(state)
    assert ctrl2.state_dict() == ctrl.state_dict()
    assert ctrl2.spill_target() == 0
    # legacy flat state (no per-tier dicts): top pair maps through,
    # lower tiers default to calm
    legacy = {k: v for k, v in state.items()
              if k not in ("tier_pressure", "tier_spill")}
    ctrl3, _ = mk_controller3(spill_spec(), uniform_window())
    ctrl3.load_state_dict(legacy)
    assert ctrl3.spill_active and ctrl3.tier_spill == {1: False, 2: True}
    assert ctrl3.spill_target() == 1


def test_three_tier_loadgen_cascade_regression():
    """End-to-end 3-tier replay: with tiers 2 AND 1 starved of capacity,
    spilled requests must land on tier 0 instead of piling onto the
    equally-saturated middle tier."""
    from repro.api import CalibrationSpec, RouteSpec, build
    from repro.serving.loadgen import (LoadRunner, TraceSpec,
                                       make_pool_runners, make_pools)
    spec = RouteSpec(
        # cuts at the trace's ~40/75% entropy quantiles -> a real mix
        # lands on every tier (entropy tops out at log2(40) ~= 5.3)
        metric="entropy", thresholds=(3.1, 4.85), top_k=40,
        tier_names=TIER3_MODELS,
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.4, 0.35, 0.25),
                                    window=256, min_samples=48,
                                    tolerance=0.5, cooldown=10000),
        admission=AdmissionSpec(p99_slo=1.0, p99_horizon=5.0,
                                queue_depth_slo=4, spill_on=1.0,
                                spill_off=0.3, spill_margin=0.25,
                                pressure_beta=1.0, min_top_share=0.25))
    # tier 0 has real headroom; tiers 1 and 2 are walls
    pools = make_pools({0: [4.0] * 8, 1: [0.05], 2: [0.05]},
                       batch_slots={0: 32, 1: 2, 2: 2},
                       base_token_time=8e-5)
    session = build(spec, runners=make_pool_runners(pools))
    trace = TraceSpec(name="cascade3", steps=60, seed=11, base_rate=30.0,
                      top_k=40)
    report = LoadRunner(session, pools, slo_latency=1.0).run(trace)
    adm = session.admission
    assert adm.tier_spill[2] or adm.tier_spill[1]
    assert adm.n_spilled > 0
    executed = report.summary["tier_counts_executed"]
    decided = session.stats.tier_counts
    # the cascade drains spill into tier 0: it executes MORE than it was
    # decided, while the saturated tiers execute less
    assert executed.get("0", 0) > decided[0]


# -- p99 recency horizon (promoted into AdmissionSpec) ------------------------

def test_p99_horizon_validates_against_slo():
    with pytest.raises(ValueError, match="p99_horizon"):
        AdmissionSpec(p99_horizon=0.0)
    with pytest.raises(ValueError, match="p99_horizon"):
        AdmissionSpec(p99_slo=2.0, p99_horizon=1.0)
    spec = AdmissionSpec(p99_slo=1.0, p99_horizon=5.0)
    assert AdmissionSpec.from_dict(json.loads(json.dumps(
        spec.to_dict()))) == spec
    AdmissionSpec(p99_horizon=3.0)  # fine without an SLO to compare to


def test_load_runner_takes_horizon_from_the_policy():
    from repro.api import build
    from repro.serving.loadgen import (LoadRunner, make_pool_runners,
                                       make_pools)

    def runner_for(admission, **kw):
        pools = make_pools({0: [1.0], 1: [1.0]})
        session = build(mk_route_spec(admission),
                        runners=make_pool_runners(pools))
        return LoadRunner(session, pools, slo_latency=2.0, **kw)

    # spec horizon serializes with the policy and wins over the default
    spec_h = AdmissionSpec(p99_slo=2.0, p99_horizon=7.5)
    assert runner_for(spec_h).p99_horizon == 7.5
    # explicit ctor arg overrides (ad-hoc experiments)
    assert runner_for(spec_h, p99_horizon=9.0).p99_horizon == 9.0
    # no admission / unset horizon: the 5x-SLO default
    assert runner_for(None).p99_horizon == 10.0
    assert runner_for(AdmissionSpec(p99_slo=2.0)).p99_horizon == 10.0
