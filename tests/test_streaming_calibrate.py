"""Streaming calibrator tests: stationary convergence to the batch
quantile, drift-triggered hot-swap with share recovery, windowing
mechanics, and the dispatcher integration."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import skewness as sk
from repro.core.calibrate import calibrate_threshold
from repro.core.router import RouterConfig, route_from_difficulty
from repro.core.streaming_calibrate import SlidingWindow, StreamingCalibrator


def desc_scores(rng, b, k=100, alpha_lo=0.2, alpha_hi=2.5):
    """Synthetic retrieval batches: per-row power-law decay with a random
    exponent — flat rows (small alpha) are 'hard', spiky rows 'easy'."""
    alphas = rng.uniform(alpha_lo, alpha_hi, b)
    base = 1.0 / np.arange(1, k + 1)[None, :] ** alphas[:, None]
    noise = rng.uniform(0.95, 1.05, (b, k))
    return np.sort((base * noise).astype(np.float32), axis=1)[:, ::-1].copy()


# -- SlidingWindow ------------------------------------------------------------

def test_window_wraparound_keeps_last_capacity_samples():
    w = SlidingWindow(16)
    stream = np.arange(100, dtype=np.float32)
    for i in range(0, 100, 7):  # odd batch size forces mid-buffer wraps
        w.push(stream[i:i + 7])
    assert len(w) == 16 and w.total_seen == 100
    np.testing.assert_array_equal(np.sort(w.values()), stream[-16:])
    assert float(w.quantile(0.5)) == np.quantile(stream[-16:], 0.5)


def test_window_oversized_batch_keeps_tail():
    w = SlidingWindow(8)
    w.push(np.arange(50, dtype=np.float32))
    np.testing.assert_array_equal(np.sort(w.values()), np.arange(42, 50))


def test_window_validation():
    with pytest.raises(ValueError):
        SlidingWindow(1)
    with pytest.raises(ValueError):
        SlidingWindow(8).quantile(0.5)


# -- calibrator validation ----------------------------------------------------

def test_calibrator_validates_shares_and_tolerance():
    cfg = RouterConfig(metric="entropy", thresholds=(1.0,))
    with pytest.raises(ValueError):  # wrong arity
        StreamingCalibrator(cfg, [0.5, 0.3, 0.2])
    with pytest.raises(ValueError):  # doesn't sum to 1
        StreamingCalibrator(cfg, [0.5, 0.4])
    with pytest.raises(ValueError):
        StreamingCalibrator(cfg, [0.7, 0.3], tolerance=0.0)


# -- stationary convergence ---------------------------------------------------

def test_stationary_stream_converges_to_batch_quantile():
    """Feeding a stationary difficulty stream: the calibrator's fitted
    threshold equals calibrate_threshold's quantile on the same sample."""
    rng = np.random.default_rng(0)
    scores = desc_scores(rng, 600)
    diff = np.asarray(sk.difficulty(jnp.asarray(scores), metric="entropy"))
    target_large = 0.3
    cal = StreamingCalibrator(
        RouterConfig(metric="entropy", thresholds=(0.0,)),  # badly off
        [1.0 - target_large, target_large],
        window=512, min_samples=128, tolerance=0.05, cooldown=128)
    for i in range(0, 600, 32):
        cal.observe(diff[i:i + 32])
    assert cal.n_swaps >= 1
    theta_batch = calibrate_threshold(jnp.asarray(scores), target_large,
                                      metric="entropy")
    # same quantile rule, window-sized sample: agreement within the
    # sampling noise of a 512-window
    assert abs(cal.config.thresholds[0] - theta_batch) < 0.15
    shares = cal.observed_shares()
    assert abs(shares[1] - target_large) < 0.06


def test_no_swap_when_already_on_target():
    rng = np.random.default_rng(1)
    scores = desc_scores(rng, 400)
    diff = np.asarray(sk.difficulty(jnp.asarray(scores), metric="entropy"))
    theta = float(np.quantile(diff, 0.7))
    cal = StreamingCalibrator(RouterConfig(metric="entropy",
                                           thresholds=(theta,)),
                              [0.7, 0.3], window=256, min_samples=64,
                              tolerance=0.08)
    for i in range(0, 400, 32):
        assert cal.observe(diff[i:i + 32]) is None
    assert cal.n_swaps == 0


# -- drift --------------------------------------------------------------------

def test_drift_triggers_hotswap_and_recovers_shares():
    """Mid-stream distribution shift: the tier mix walks off target, a
    swap fires, and post-swap shares return to target on the new traffic."""
    rng = np.random.default_rng(2)
    easy_era = desc_scores(rng, 800, alpha_lo=1.2, alpha_hi=2.5)   # spiky
    hard_era = desc_scores(rng, 1600, alpha_lo=0.1, alpha_hi=0.9)  # flat
    d_easy = np.asarray(sk.difficulty(jnp.asarray(easy_era), metric="gini"))
    d_hard = np.asarray(sk.difficulty(jnp.asarray(hard_era), metric="gini"))

    target = (0.7, 0.3)
    theta0 = float(np.quantile(d_easy, target[0]))  # calibrated on era 1
    cal = StreamingCalibrator(RouterConfig(metric="gini",
                                           thresholds=(theta0,)),
                              target, window=512, min_samples=128,
                              tolerance=0.08, cooldown=256)
    for i in range(0, 800, 32):
        cal.observe(d_easy[i:i + 32])
    swaps_before_drift = cal.n_swaps

    # distribution shift: everything suddenly routes large under theta0
    pre_shares = route_from_difficulty(jnp.asarray(d_hard),
                                       jnp.asarray([theta0]))
    assert float(jnp.mean(pre_shares > 0)) > 0.6  # the drift is real

    for i in range(0, 1600, 32):
        cal.observe(d_hard[i:i + 32])
    assert cal.n_swaps > swaps_before_drift
    event = cal.events[-1]
    assert event.max_drift > 0.08
    # recovered: the window (now pure era-2 traffic) sits on target
    shares = cal.observed_shares()
    assert abs(shares[1] - target[1]) < 0.08


def test_cooldown_bounds_flapping():
    rng = np.random.default_rng(3)
    diff = rng.normal(0, 1, 4000).astype(np.float32)
    cal = StreamingCalibrator(RouterConfig(metric="entropy",
                                           thresholds=(100.0,)),  # way off
                              [0.5, 0.5], window=512, min_samples=64,
                              tolerance=0.02, cooldown=1000)
    for i in range(0, 4000, 16):
        cal.observe(diff[i:i + 16])
    assert cal.n_swaps <= 4  # ~1 per cooldown period, not per batch


def test_loadgen_drift_trace_converges_shares():
    """Same drift property driven by a seeded workload trace instead of
    hand-built eras: the loadgen score stream walks the mix off target
    mid-trace and the calibrator swaps back onto it — the trace spec IS
    the regression input (replayable from JSON anywhere)."""
    from repro.serving.loadgen import DriftSpec, TraceSpec, generate
    spec = TraceSpec(
        name="drift-regression", seed=3, steps=160, base_rate=24.0,
        top_k=100,
        drift=(DriftSpec(0, 1.2, 2.5), DriftSpec(60, 0.1, 0.9)))
    target = (0.7, 0.3)
    cal = StreamingCalibrator(
        RouterConfig(metric="entropy", thresholds=(0.0,)),
        target, window=512, min_samples=128, tolerance=0.08, cooldown=256)
    era2_shares = []
    for step in generate(spec):
        if step.n_arrivals == 0:
            continue
        diff = np.asarray(sk.difficulty(jnp.asarray(step.scores),
                                        metric="entropy"))
        cal.observe(diff)
        if step.step >= 120:     # well after the drift landed
            era2_shares.append(
                float((diff > cal.config.thresholds[0]).mean()))
    assert cal.n_swaps >= 2      # initial mis-calibration + the drift
    assert any(e.max_drift > 0.08 for e in cal.events)
    assert abs(np.mean(era2_shares) - target[1]) < 0.08


# -- three-tier fit -----------------------------------------------------------

def test_multi_tier_fit_matches_window_quantiles():
    rng = np.random.default_rng(4)
    diff = rng.uniform(0, 10, 1024).astype(np.float32)
    cal = StreamingCalibrator(
        RouterConfig(metric="area", thresholds=(1.0, 2.0)),
        [0.5, 0.3, 0.2], window=1024, min_samples=64)
    cal.window.push(diff)
    cfg = cal.fit_config()
    np.testing.assert_allclose(
        cfg.thresholds, np.quantile(diff, [0.5, 0.8]), rtol=1e-5)
    tiers = np.sum(diff[:, None] > np.asarray(cfg.thresholds)[None, :],
                   axis=1)
    np.testing.assert_allclose(
        [(tiers == t).mean() for t in range(3)], [0.5, 0.3, 0.2], atol=0.01)


# -- dispatcher integration ---------------------------------------------------

def test_dispatcher_hotswaps_under_drift():
    """End to end: dispatcher calibrated for a 30% large ratio keeps it
    through a traffic drift because the streaming calibrator swaps the
    thresholds inline."""
    from repro.serving.router_service import SkewRouteDispatcher
    rng = np.random.default_rng(5)
    easy = desc_scores(rng, 512, alpha_lo=1.2, alpha_hi=2.5)
    hard = desc_scores(rng, 1024, alpha_lo=0.1, alpha_hi=0.9)
    theta = calibrate_threshold(jnp.asarray(easy), 0.3, metric="entropy")
    d = SkewRouteDispatcher(RouterConfig(metric="entropy",
                                         thresholds=(theta,)),
                            ["small", "large"])
    d.attach_calibrator([0.7, 0.3], window=256, min_samples=64,
                        tolerance=0.08, cooldown=128)
    for i in range(0, 512, 64):
        d.dispatch_batch(easy[i:i + 64])
    for i in range(0, 1024, 64):
        d.dispatch_batch(hard[i:i + 64])
    assert d.stats.n_recalibrations >= 1
    # post-swap traffic routes on budget again
    tail = d.dispatch_batch(desc_scores(rng, 256, alpha_lo=0.1, alpha_hi=0.9))
    assert abs((tail == 1).mean() - 0.3) < 0.1
