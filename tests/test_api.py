"""`repro.api` surface tests: spec serialization, session equivalence,
snapshot/restore, backend registry, deprecation shims."""

import dataclasses
import json

import numpy as np
import pytest

from repro.api import (CalibrationSpec, OracleBackend, PallasBackend,
                       RouteSpec, SkewRouteSession, available_backends,
                       build, make_backend, register_backend)
from repro.api import backends as backends_mod
from repro.core import RouterConfig
from repro.serving import _deprecation
from repro.serving.pipeline import ServingPipeline
from repro.serving.router_service import SkewRouteDispatcher


def _desc_scores(b, k, seed=0):
    rng = np.random.default_rng(seed)
    return np.sort(rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                   axis=1)[:, ::-1].copy()


def _three_tier_spec(scores, backend="auto", **overrides):
    """Thresholds at the 50/80% difficulty quantiles -> non-trivial mix."""
    diff = np.asarray(OracleBackend().route_batch(
        scores, RouterConfig(metric="entropy", thresholds=(0.0,))).difficulty)
    t0, t1 = np.quantile(diff, [0.5, 0.8])
    return RouteSpec(metric="entropy", thresholds=(float(t0), float(t1)),
                     tier_names=("qwen7b", "qwen14b", "qwen72b"),
                     top_k=scores.shape[1], backend=backend, **overrides)


# -- RouteSpec serialization --------------------------------------------------

def test_spec_json_roundtrip_identity():
    from repro.api import CostSpec
    spec = RouteSpec(
        metric="cumulative", thresholds=(3.0, 7.5), cumulative_p=0.9,
        top_k=50, tier_names=("s", "m", "l"),
        tier_models=("qwen7b", "qwen14b", "qwen72b"),
        backend="oracle", micro_batch=16,
        cost=CostSpec(cost_per_mtok={"qwen7b": 0.5, "qwen14b": 1.0,
                                     "qwen72b": 5.0}),
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.5, 0.3, 0.2),
                                    window=512, min_samples=32,
                                    tolerance=0.1, cooldown=64))
    again = RouteSpec.from_json(spec.to_json())
    assert again == spec
    assert hash(again) == hash(spec)  # frozen policy values stay hashable
    assert again.cost_model().cost_per_mtok["qwen72b"] == 5.0
    # json payload is pure data (no Python reprs)
    payload = json.loads(spec.to_json())
    assert payload["schema_version"] == 1
    assert payload["calibration"]["target_shares"] == [0.5, 0.3, 0.2]


def test_spec_validation_inherits_router_checks():
    with pytest.raises(ValueError, match="unknown metric"):
        RouteSpec(metric="nope")
    with pytest.raises(ValueError, match="top_k must be >= 1"):
        RouteSpec(top_k=0)
    with pytest.raises(ValueError, match=r"cumulative_p must be in \(0, 1\]"):
        RouteSpec(cumulative_p=1.5)
    with pytest.raises(ValueError, match="ascending"):
        RouteSpec(thresholds=(2.0, 1.0), tier_names=("a", "b", "c"))


def test_spec_validation_spec_level():
    with pytest.raises(ValueError, match="tier_names"):
        RouteSpec(thresholds=(0.0,), tier_names=("only-one",))
    with pytest.raises(ValueError, match="tier_models"):
        RouteSpec(tier_models=("just-one",))
    with pytest.raises(ValueError, match="unknown difficulty backend"):
        RouteSpec(backend="quantum")
    with pytest.raises(ValueError, match="micro_batch"):
        RouteSpec(micro_batch=0)
    with pytest.raises(ValueError, match="target_shares"):
        CalibrationSpec(policy="streaming")
    with pytest.raises(ValueError, match="sum to 1"):
        CalibrationSpec(policy="streaming", target_shares=(0.9, 0.9))
    with pytest.raises(ValueError, match="unknown calibration policy"):
        CalibrationSpec(policy="sometimes")
    with pytest.raises(ValueError, match="window must be >= 2"):
        CalibrationSpec(window=1)
    with pytest.raises(ValueError, match="min_samples must be >= 2"):
        CalibrationSpec(min_samples=1)
    with pytest.raises(ValueError, match="can never be reached"):
        CalibrationSpec(window=64, min_samples=256)
    with pytest.raises(ValueError, match=r"tolerance must be in \(0, 1\)"):
        CalibrationSpec(tolerance=0.0)
    with pytest.raises(ValueError, match="cooldown must be >= 0"):
        CalibrationSpec(cooldown=-1)
    with pytest.raises(ValueError, match="calibration target_shares"):
        RouteSpec(calibration=CalibrationSpec(
            policy="streaming", target_shares=(0.5, 0.3, 0.2)))


def test_spec_from_dict_rejects_unknown_and_versioned():
    base = RouteSpec().to_dict()
    with pytest.raises(ValueError, match="schema_version"):
        RouteSpec.from_dict({**base, "schema_version": 99})
    with pytest.raises(ValueError, match="unknown RouteSpec fields"):
        RouteSpec.from_dict({**base, "surprise": 1})
    with pytest.raises(ValueError, match="unknown CalibrationSpec fields"):
        RouteSpec.from_dict(
            {**base, "calibration": {"policy": "static", "wat": 2}})


def test_router_config_validation_messages():
    with pytest.raises(ValueError, match="top_k must be >= 1, got 0"):
        RouterConfig(top_k=0)
    with pytest.raises(ValueError, match=r"cumulative_p must be in \(0, 1\], "
                                         r"got 0.0"):
        RouterConfig(cumulative_p=0.0)
    with pytest.raises(ValueError, match="got 1.5"):
        RouterConfig(cumulative_p=1.5)
    assert RouterConfig(cumulative_p=1.0).cumulative_p == 1.0  # closed top


# -- acceptance: json round-trip rebuilds an equivalent session ---------------

@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_roundtrip_session_equivalence_b1024(backend):
    scores = _desc_scores(1024, 100)
    spec = _three_tier_spec(scores, backend=backend)
    session = build(spec)
    rebuilt = build(RouteSpec.from_json(spec.to_json()))
    a = session.route(scores)
    b = rebuilt.route(scores)
    assert np.array_equal(a.tiers, b.tiers)
    np.testing.assert_array_equal(a.difficulty, b.difficulty)
    # the mix is non-trivial (all three tiers hit)
    assert len(set(a.tiers.tolist())) == 3


def test_backends_agree_on_tiers():
    scores = _desc_scores(256, 64, seed=3)
    n_valid = np.random.default_rng(4).integers(5, 64, 256).astype(np.int32)
    spec_o = _three_tier_spec(scores, backend="oracle")
    spec_p = dataclasses.replace(spec_o, backend="pallas")
    to = build(spec_o).route(scores, n_valid=n_valid)
    tp = build(spec_p).route(scores, n_valid=n_valid)
    assert np.array_equal(to.tiers, tp.tiers)


# -- satellite: single-request dispatch is the batched path -------------------

@pytest.mark.parametrize("backend", ["oracle", "pallas"])
def test_route_one_matches_batch(backend):
    scores = _desc_scores(16, 50, seed=1)
    spec = _three_tier_spec(scores, backend=backend)
    batch_tiers = build(spec).route(scores).tiers
    singles = build(spec)
    for i in range(scores.shape[0]):
        rec = singles.route_one(scores[i])
        assert rec.tier == int(batch_tiers[i])


def test_dispatcher_dispatch_delegates_to_batch(monkeypatch):
    spec = RouteSpec(metric="entropy", thresholds=(5.0,),
                     tier_names=("a", "b"), top_k=32)
    session = build(spec)
    calls = []
    orig = SkewRouteDispatcher.dispatch_batch

    def spy(self, *a, **kw):
        calls.append(kw)
        return orig(self, *a, **kw)

    monkeypatch.setattr(SkewRouteDispatcher, "dispatch_batch", spy)
    session.route_one(_desc_scores(1, 32)[0], n_valid=20)
    assert len(calls) == 1  # one entry point: no oracle/kernel divergence


# -- snapshot / restore -------------------------------------------------------

def _streaming_spec(k=32):
    return RouteSpec(
        metric="entropy", thresholds=(4.0,), tier_names=("small", "large"),
        top_k=k,
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.7, 0.3),
                                    window=256, min_samples=32,
                                    tolerance=0.02, cooldown=32))


def test_snapshot_restore_bitexact_and_json():
    k = 32
    session = build(_streaming_spec(k))
    rng = np.random.default_rng(7)
    for i in range(6):  # drifting traffic -> hot-swaps fire
        flat = rng.uniform(0.4 + 0.1 * i, 1, (64, k)).astype(np.float32)
        session.route(np.sort(flat, axis=1)[:, ::-1].copy())
    assert session.stats.n_recalibrations > 0
    assert session.thresholds != (4.0,)  # thresholds actually moved

    snap = json.loads(json.dumps(session.snapshot()))  # full json trip
    twin = build(_streaming_spec(k)).restore(snap)

    assert twin.thresholds == session.thresholds          # bit-exact floats
    assert twin.calibrator.config.thresholds == \
        session.calibrator.config.thresholds
    np.testing.assert_array_equal(twin.calibrator.window.values(),
                                  session.calibrator.window.values())
    assert twin.calibrator.window.total_seen == \
        session.calibrator.window.total_seen
    assert twin.calibrator.events == session.calibrator.events
    assert twin.stats.n_requests == session.stats.n_requests
    assert twin.stats.tier_counts == session.stats.tier_counts
    assert twin.stats.total_cost == session.stats.total_cost

    # the twin continues IDENTICALLY: same tiers, same swap decisions
    probe = np.sort(rng.uniform(0.95, 1, (64, k)).astype(np.float32),
                    axis=1)[:, ::-1].copy()
    ra, rb = session.route(probe), twin.route(probe)
    assert np.array_equal(ra.tiers, rb.tiers)
    assert ra.recalibrated == rb.recalibrated
    assert twin.thresholds == session.thresholds


def test_from_snapshot_classmethod():
    session = build(_streaming_spec())
    session.route(_desc_scores(64, 32, seed=9))
    snap = session.snapshot()
    twin = SkewRouteSession.from_snapshot(snap)
    assert twin.spec == session.spec
    assert twin.stats.n_requests == 64


def test_window_state_rejects_capacity_mismatch():
    from repro.core.streaming_calibrate import SlidingWindow
    src = SlidingWindow(8)
    src.push(np.arange(20, dtype=np.float32))  # wrapped: 8 live, 20 seen
    state = src.state_dict()
    bigger = SlidingWindow(64)  # min(20, 64) > 8 -> would read junk
    with pytest.raises(ValueError, match="window state mismatch"):
        bigger.load_state_dict(state)
    same = SlidingWindow(8)
    same.load_state_dict(state)
    np.testing.assert_array_equal(same.values(), src.values())


def test_restore_rejects_foreign_spec():
    session = build(_streaming_spec())
    snap = session.snapshot()
    other = build(dataclasses.replace(_streaming_spec(), metric="area"))
    with pytest.raises(ValueError, match="different +RouteSpec"):
        other.restore(snap)


def test_snapshot_refuses_pending_payloads():
    spec = RouteSpec(metric="entropy", thresholds=(0.0,),
                     tier_names=("a", "b"), top_k=16, micro_batch=8)
    session = build(spec, runners={0: list, 1: list})
    session.submit(_desc_scores(3, 16))  # 3 < micro_batch: stays queued
    with pytest.raises(RuntimeError, match="flush"):
        session.snapshot()
    session.flush()
    json.dumps(session.snapshot())  # serializable once drained


# -- backends registry --------------------------------------------------------

def test_backend_registry_and_auto():
    assert {"oracle", "pallas", "fused", "auto"} <= set(available_backends())
    auto = make_backend("auto")
    assert isinstance(auto, backends_mod.AutoBackend)
    assert auto.crossover_batch == backends_mod.DEFAULT_CROSSOVER_BATCH
    assert isinstance(make_backend("fused"), backends_mod.FusedBackend)
    with pytest.raises(ValueError, match="unknown difficulty backend"):
        make_backend("quantum")
    with pytest.raises(ValueError, match="invalid backend name"):
        register_backend("auto", PallasBackend)

    class EchoBackend(OracleBackend):
        name = "echo"

    register_backend("echo", EchoBackend)
    try:
        assert "echo" in available_backends()
        spec = RouteSpec(backend="echo", thresholds=(0.0,), top_k=8,
                         tier_names=("a", "b"))
        assert build(spec).backend.name == "echo"
    finally:
        backends_mod._REGISTRY.pop("echo", None)


# -- deprecation shims --------------------------------------------------------

def test_old_constructors_warn_once():
    _deprecation.reset()
    cfg = RouterConfig(metric="entropy", thresholds=(5.0,))
    with pytest.warns(DeprecationWarning, match="repro.api.build"):
        d = SkewRouteDispatcher(cfg, ["a", "b"])
    with pytest.warns(DeprecationWarning, match="repro.api.build"):
        ServingPipeline(d, {0: list, 1: list})
    # second constructions are silent (warn-once)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        d2 = SkewRouteDispatcher(cfg, ["a", "b"])
        ServingPipeline(d2, {0: list, 1: list})


def test_api_build_does_not_warn():
    _deprecation.reset()
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        build(RouteSpec(thresholds=(0.0,), tier_names=("a", "b")),
              runners={0: list, 1: list})
    _deprecation.reset()
