"""Workload-trace tests: spec validation + JSON round-trip, seeded
determinism, rate/drift/failure schedules, and end-to-end trace replay
through the canonical serving setup (exactly-once execution, baseline
bit-for-bit vs dispatcher decisions, spill under saturation)."""

import json

import numpy as np
import pytest

from repro.serving.loadgen import (CANONICAL_TRACES, BurstSpec, DriftSpec,
                                   FailureSpec, LoadRunner, TraceSpec,
                                   canonical_load_runner, canonical_trace,
                                   generate, make_pool_runners, make_pools)


# -- TraceSpec ----------------------------------------------------------------

def test_canonical_traces_json_round_trip():
    for name, spec in CANONICAL_TRACES.items():
        assert name == spec.name
        again = TraceSpec.from_json(spec.to_json())
        assert again == spec
    with pytest.raises(KeyError, match="unknown canonical trace"):
        canonical_trace("nope")


def test_trace_spec_validation():
    with pytest.raises(ValueError, match="steps"):
        TraceSpec(name="t", steps=0)
    with pytest.raises(ValueError, match="dt"):
        TraceSpec(name="t", steps=10, dt=0.0)
    with pytest.raises(ValueError, match="drift segment"):
        TraceSpec(name="t", steps=10, drift=())
    with pytest.raises(ValueError, match="begin at step 0"):
        TraceSpec(name="t", steps=10, drift=(DriftSpec(5, 0.5, 1.0),))
    with pytest.raises(ValueError, match="sorted"):
        TraceSpec(name="t", steps=10, drift=(DriftSpec(0, 0.5, 1.0),
                                             DriftSpec(8, 0.5, 1.0),
                                             DriftSpec(4, 0.5, 1.0)))
    with pytest.raises(ValueError, match="diurnal"):
        TraceSpec(name="t", steps=10, diurnal_amplitude=0.5)
    with pytest.raises(ValueError, match="multiplier"):
        BurstSpec(start=0, length=5, multiplier=0.0)
    with pytest.raises(ValueError, match="alpha"):
        DriftSpec(0, 0.0, 1.0)
    with pytest.raises(ValueError, match="down_at"):
        FailureSpec(tier=1, replica=0, down_at=7, up_at=7)
    with pytest.raises(ValueError, match="unknown TraceSpec fields"):
        TraceSpec.from_dict({"name": "t", "steps": 10, "surge": 2})


def test_rate_schedule_burst_and_diurnal():
    spec = TraceSpec(name="t", steps=100, base_rate=5.0,
                     bursts=(BurstSpec(start=20, length=10, multiplier=4.0),))
    assert spec.rate(19) == pytest.approx(5.0)
    assert spec.rate(20) == pytest.approx(20.0)
    assert spec.rate(29) == pytest.approx(20.0)
    assert spec.rate(30) == pytest.approx(5.0)
    tide = TraceSpec(name="t", steps=100, base_rate=5.0,
                     diurnal_amplitude=0.5, diurnal_period=100.0)
    assert tide.rate(25) == pytest.approx(7.5)   # sin peak
    assert tide.rate(75) == pytest.approx(2.5)   # sin trough
    assert tide.rate(0) == pytest.approx(5.0)


def test_drift_segment_lookup():
    spec = TraceSpec(name="t", steps=100,
                     drift=(DriftSpec(0, 1.0, 2.0), DriftSpec(40, 0.1, 0.5)))
    assert spec.drift_segment(0).alpha_lo == 1.0
    assert spec.drift_segment(39).alpha_lo == 1.0
    assert spec.drift_segment(40).alpha_lo == 0.1
    assert spec.drift_segment(99).alpha_lo == 0.1


# -- generate -----------------------------------------------------------------

def test_generate_is_deterministic_for_a_spec():
    spec = canonical_trace("smoke")
    a, b = list(generate(spec)), list(generate(spec))
    assert [s.n_arrivals for s in a] == [s.n_arrivals for s in b]
    for sa, sb in zip(a, b):
        np.testing.assert_array_equal(sa.scores, sb.scores)
        assert sa.events == sb.events and sa.time == sb.time
    # a different seed is a different trace
    other = list(generate(TraceSpec.from_dict(
        {**spec.to_dict(), "seed": spec.seed + 1})))
    assert [s.n_arrivals for s in a] != [s.n_arrivals for s in other]


def test_generate_scores_shape_and_order():
    spec = TraceSpec(name="t", steps=30, seed=1, base_rate=6.0, top_k=40,
                     max_batch=10)
    total = 0
    for step in generate(spec):
        assert step.scores.dtype == np.float32
        assert step.scores.shape[1] == 40 and step.n_arrivals <= 10
        assert np.all(np.diff(step.scores, axis=1) <= 0)  # descending rows
        total += step.n_arrivals
    assert total > 0


def test_drift_makes_score_rows_flatter():
    spec = TraceSpec(name="t", steps=100, seed=2, base_rate=20.0,
                     drift=(DriftSpec(0, 1.5, 2.5),     # spiky = easy
                            DriftSpec(50, 0.1, 0.4)))   # flat  = hard
    flat = {False: [], True: []}
    for step in generate(spec):
        if step.n_arrivals:
            flat[step.step >= 50].append(
                float((step.scores[:, -1] / step.scores[:, 0]).mean()))
    assert np.mean(flat[True]) > 5 * np.mean(flat[False])


def test_failure_events_fire_at_their_steps():
    spec = TraceSpec(name="t", steps=12, seed=3,
                     failures=(FailureSpec(tier=1, replica=0, down_at=3,
                                           up_at=7, speed=0.5),))
    by_step = {s.step: s.events for s in generate(spec) if s.events}
    assert sorted(by_step) == [3, 7]
    (down,), (up,) = by_step[3], by_step[7]
    assert (down.kind, down.tier, down.replica) == ("down", 1, 0)
    assert (up.kind, up.speed) == ("up", 0.5)


# -- trace replay through the serving stack -----------------------------------

REPLAY_TRACE = TraceSpec(
    name="replay", seed=5, steps=60, dt=0.05, top_k=50, base_rate=4.0,
    bursts=(BurstSpec(start=20, length=15, multiplier=3.0),),
    drift=(DriftSpec(0, 1.0, 2.5), DriftSpec(25, 0.2, 0.9)),
    failures=(FailureSpec(tier=1, replica=0, down_at=22, up_at=40,
                          speed=0.5),))


def test_baseline_replay_executes_exactly_once_bit_for_bit():
    runner = canonical_load_runner(with_admission=False, trace=REPLAY_TRACE)
    report = runner.run(REPLAY_TRACE)
    s = report.summary
    assert s["n_arrivals"] == s["n_completed"] > 0
    pipe = runner.session.pipeline.telemetry
    assert pipe.n_submitted == pipe.n_executed == s["n_arrivals"]
    # admission off: the executed mix IS the dispatcher's decisions
    assert s["n_spilled"] == 0
    decisions = {str(t): int(c)
                 for t, c in runner.session.stats.tier_counts.items()}
    assert decisions == s["tier_counts_executed"]
    assert "admission" not in s
    # the replica failure was actually driven into the pool
    kinds = [(f["kind"], f["tier"], f["replica"]) for f in s["failures"]]
    assert kinds == [("down", 1, 0), ("up", 1, 0)]
    # one telemetry row per step, serializable trajectory
    assert len(report.steps) == REPLAY_TRACE.steps
    assert "spill_active" not in report.steps[0]


def test_admission_replay_spills_under_saturation():
    trace = canonical_trace("smoke")
    runner = canonical_load_runner(with_admission=True, trace=trace)
    report = runner.run(trace)
    s = report.summary
    assert s["n_arrivals"] == s["n_completed"]
    # the smoke trace saturates the expensive tier: spill must engage...
    assert s["n_spilled"] > 0
    assert any(row["spill_active"] for row in report.steps)
    events = runner.session.admission.events
    assert any(e["kind"] == "spill_on" for e in events)
    # ...and the executed mix now sits BELOW the dispatcher's decisions
    assert s["expensive_share_executed"] < s["expensive_share_decision"]
    assert s["admission"]["n_seen"] == s["n_arrivals"]


def test_load_runner_validation():
    trace = REPLAY_TRACE
    runner = canonical_load_runner(False, trace)
    session = runner.session
    with pytest.raises(ValueError, match="routes tiers"):
        LoadRunner(session, {0: runner.pools[0]})
    with pytest.raises(ValueError, match="slo_latency"):
        LoadRunner(session, runner.pools, slo_latency=0.0)
    with pytest.raises(ValueError, match="record_every"):
        LoadRunner(session, runner.pools, record_every=0)
    with pytest.raises(ValueError, match="tier_quality"):
        LoadRunner(session, runner.pools, tier_quality=(1.0,))
    from repro.api import build
    no_pipeline = build(session.spec)
    with pytest.raises(ValueError, match="no pipeline"):
        LoadRunner(no_pipeline, runner.pools)


def test_make_pools_and_runners_wire_tiers():
    pools = make_pools({0: [1.0, 2.0], 1: [0.5]}, batch_slots={0: 4},
                       base_token_time=1e-4)
    assert sorted(pools) == [0, 1]
    assert pools[0].batch_slots == 4 and pools[1].batch_slots == 8
    assert pools[0].replicas[1].speed == 2.0
    runners = make_pool_runners(pools)
    from repro.serving.loadgen import SimRequest
    reqs = runners[1]([SimRequest(request_id=9, submitted_at=0.0,
                                  deadline=5.0)])
    assert len(reqs) == 1 and reqs[0].tier == 1
    assert pools[1].queue_depth() == 1
