"""Router + calibration tests (Algorithm 1 + the training-free property)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (RouterConfig, calibrate_multi_tier,
                        calibrate_threshold, route, route_from_difficulty)
from tests._hypothesis_compat import given, st


def test_router_config_validation():
    with pytest.raises(ValueError):
        RouterConfig(metric="nope")
    with pytest.raises(ValueError):
        RouterConfig(thresholds=(2.0, 1.0))
    assert RouterConfig(thresholds=(1.0, 2.0)).n_tiers == 3


@given(st.lists(st.floats(-5, 5), min_size=2, max_size=20),
       st.floats(-4, 4))
def test_threshold_monotonicity(diffs, theta):
    """Higher difficulty never routes to a smaller tier."""
    d = jnp.asarray(sorted(diffs), jnp.float32)
    tiers = np.asarray(route_from_difficulty(d, jnp.asarray([theta])))
    assert (np.diff(tiers) >= 0).all()


@given(st.integers(0, 500))
def test_calibration_hits_budget(seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.uniform(0.01, 1, (200, 50)).astype(np.float32))
    for target in [0.2, 0.5, 0.8]:
        theta = calibrate_threshold(scores, target, metric="entropy")
        cfg = RouterConfig(metric="entropy", thresholds=(theta,))
        ratio = float(jnp.mean(route(scores, cfg) > 0))
        assert abs(ratio - target) < 0.08, (target, ratio)


def test_multi_tier_calibration_shares():
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.uniform(0.01, 1, (300, 50)).astype(np.float32))
    cfg = calibrate_multi_tier(scores, [0.5, 0.3, 0.2], metric="gini")
    tiers = np.asarray(route(scores, cfg))
    shares = [(tiers == t).mean() for t in range(3)]
    np.testing.assert_allclose(shares, [0.5, 0.3, 0.2], atol=0.08)


def test_tier_boundaries_exact():
    d = jnp.asarray([0.0, 1.0, 2.0, 3.0])
    tiers = route_from_difficulty(d, jnp.asarray([1.0, 2.0]))
    assert list(np.asarray(tiers)) == [0, 0, 1, 2]  # <= threshold -> lower
