"""Replica sync fabric tests: wire-format round trips, the deterministic
weighted-quantile merge (bit-identical across replicas), delta
idempotency, policy fingerprint refusal, cold-join bootstrap via the
state half, and cooldown interplay with the local drift loop."""

import json

import numpy as np
import pytest

from repro.api import CalibrationSpec, RouteSpec, build, policy_fingerprint
from repro.distributed.replica_sync import (StateDelta, SyncEndpoint,
                                            delta_nbytes, weighted_quantile)
from repro.serving.fabric import ReplicaFabric


def fleet_spec(**cal_overrides):
    cal = dict(policy="streaming", target_shares=(0.7, 0.3), window=512,
               min_samples=64, tolerance=0.08, cooldown=128)
    cal.update(cal_overrides)
    return RouteSpec(metric="entropy", thresholds=(6.0,), top_k=100,
                     tier_names=("qwen7b", "qwen72b"),
                     calibration=CalibrationSpec(**cal))


def skewed_scores(rng, n, skew, k=100):
    """Descending score rows; skew>1 concentrates mass (harder mix)."""
    raw = rng.random((n, k)).astype(np.float32) ** skew
    return -np.sort(-raw, axis=1)


# -- weighted_quantile --------------------------------------------------------

def test_weighted_quantile_matches_numpy_on_equal_weights():
    """Midpoint positions vs numpy's type-7: agreement to O(1/n)."""
    rng = np.random.default_rng(0)
    v = rng.standard_normal(1001)
    qs = [0.1, 0.5, 0.9]
    got = weighted_quantile(v, np.ones_like(v), qs)
    want = np.quantile(v, qs)
    np.testing.assert_allclose(got, want, atol=5e-3)
    # and it is exactly reproducible call-to-call (the real contract)
    again = weighted_quantile(v.copy(), np.ones_like(v), qs)
    assert got.tolist() == again.tolist()


def test_weighted_quantile_weights_shift_the_cut():
    v = np.array([0.0, 1.0, 2.0, 3.0])
    light = weighted_quantile(v, np.array([1.0, 1, 1, 1]), [0.5])[0]
    heavy = weighted_quantile(v, np.array([1.0, 1, 1, 10]), [0.5])[0]
    assert heavy > light


def test_weighted_quantile_validation():
    with pytest.raises(ValueError, match="zero samples"):
        weighted_quantile(np.empty(0), np.empty(0), [0.5])
    with pytest.raises(ValueError, match="finite"):
        weighted_quantile(np.ones(3), np.array([1.0, np.nan, 1.0]), [0.5])
    # all-zero weights fall back to equal weighting, not an error
    assert weighted_quantile(np.array([1.0, 3.0]), np.zeros(2),
                             [0.5])[0] == pytest.approx(2.0)


# -- wire format --------------------------------------------------------------

def test_delta_json_round_trip_and_compression():
    session = build(fleet_spec())
    ep = SyncEndpoint("r0", session)
    rng = np.random.default_rng(1)
    session.route(skewed_scores(rng, 300, 1.0))
    payload = ep.publish()
    again = StateDelta.from_dict(json.loads(json.dumps(payload)))
    assert again.replica == "r0" and again.n_samples == 300
    # int8 block quantization: small absolute error on few-unit values
    win = session.calibrator.window
    np.testing.assert_allclose(again.samples(), win.recent(300),
                               atol=0.05)
    comp, raw = delta_nbytes(again)
    assert comp < raw


def test_endpoint_requires_streaming_calibrator():
    session = build(RouteSpec(metric="entropy", thresholds=(6.0,),
                              top_k=100, tier_names=("qwen7b", "qwen72b")))
    with pytest.raises(ValueError, match="streaming"):
        SyncEndpoint("r0", session)


def test_receive_refuses_foreign_policy_and_drops_stale():
    s0, s1 = build(fleet_spec()), build(fleet_spec())
    e0, e1 = SyncEndpoint("a", s0), SyncEndpoint("b", s1)
    rng = np.random.default_rng(2)
    s0.route(skewed_scores(rng, 200, 1.0))
    payload = e0.publish()
    e1.receive(payload)
    assert len(e1.buffers["a"]) == 200
    e1.receive(payload)                     # replay: dropped idempotently
    assert len(e1.buffers["a"]) == 200
    bad = dict(payload, policy_fingerprint="deadbeefdeadbeef")
    with pytest.raises(ValueError, match="policy fingerprint"):
        e1.receive(bad)


# -- the determinism contract (ISSUE satellite) -------------------------------

def test_identical_interleaved_traffic_gives_identical_merges():
    """Two independent fleets fed the same interleaved traffic stream
    end with IDENTICAL merged thresholds — the merge is a function of
    the payloads, not of replica-local float paths."""
    def run_fleet():
        fab = ReplicaFabric()
        a, b = build(fleet_spec()), build(fleet_spec())
        fab.add_replica("a", a)
        fab.add_replica("b", b)
        rng = np.random.default_rng(42)     # same stream both fleets
        for step in range(12):
            a.route(skewed_scores(rng, 48, 0.5))
            b.route(skewed_scores(rng, 48, 2.5))
            if step % 4 == 3:
                fab.sync_round()
        return a.thresholds, b.thresholds

    (a1, b1), (a2, b2) = run_fleet(), run_fleet()
    assert a1 == b1                 # within-fleet: merge is fleet-wide
    assert (a1, b1) == (a2, b2)     # across runs: fully deterministic


def test_merge_is_identical_across_replicas_every_round():
    fab = ReplicaFabric()
    sessions = {n: build(fleet_spec()) for n in ("a", "b", "c")}
    for n, s in sessions.items():
        fab.add_replica(n, s)
    rng = np.random.default_rng(3)
    for step in range(9):
        for i, s in enumerate(sessions.values()):
            s.route(skewed_scores(rng, 32, 0.5 + i))
        if step % 3 == 2:
            rep = fab.sync_round()
            ths = {tuple(r["thresholds"])
                   for r in rep["replicas"].values()}
            assert len(ths) == 1    # one fleet-wide threshold vector


# -- fabric membership / bootstrap --------------------------------------------

def test_cold_join_bootstraps_from_state_half_only():
    fab = ReplicaFabric()
    a = build(fleet_spec())
    fab.add_replica("a", a)
    rng = np.random.default_rng(4)
    a.route(skewed_scores(rng, 400, 2.0))
    fab.sync_round()
    cold = build(fleet_spec())
    assert cold.thresholds != a.thresholds
    ep = fab.add_replica("cold", cold, bootstrap_from="a")
    assert cold.thresholds == a.thresholds
    assert len(cold.calibrator.window) == len(a.calibrator.window)
    # inherited window is bootstrap, not publishable traffic
    assert ep._published_seen == cold.calibrator.window.total_seen
    payload = ep.publish()
    assert payload["n_samples"] == 0
    # ...but the source's replay-buffer view IS inherited, so the
    # joiner's first merge agrees with the fleet's immediately
    src = fab.endpoints["a"]
    assert ep.traffic["a"] == src.traffic["a"]
    assert len(ep.buffers["a"]) == len(src.buffers["a"])
    rep = fab.sync_round()
    ths = {tuple(r["thresholds"]) for r in rep["replicas"].values()}
    assert len(ths) == 1


def test_fabric_refuses_foreign_policy_member():
    fab = ReplicaFabric()
    fab.add_replica("a", build(fleet_spec()))
    other = build(fleet_spec(target_shares=(0.5, 0.5)))
    with pytest.raises(ValueError, match="polic"):
        fab.add_replica("b", other)
    with pytest.raises(ValueError, match="already joined"):
        fab.add_replica("a", build(fleet_spec()))
    with pytest.raises(ValueError, match="not a fleet member"):
        fab.add_replica("c", build(fleet_spec()), bootstrap_from="ghost")


def test_fingerprint_is_stable_across_json_round_trip():
    spec = fleet_spec()
    again = RouteSpec.from_json(spec.to_json())
    assert policy_fingerprint(spec) == policy_fingerprint(again)
    assert policy_fingerprint(spec) \
        != policy_fingerprint(fleet_spec(target_shares=(0.5, 0.5)))


# -- merge / drift-loop interplay ---------------------------------------------

def test_merge_rearms_drift_cooldown():
    """A merge counts as a swap: the local loop must not immediately
    refit from its biased window and undo the fleet's thresholds."""
    fab = ReplicaFabric()
    a, b = build(fleet_spec()), build(fleet_spec())
    fab.add_replica("a", a)
    fab.add_replica("b", b)
    rng = np.random.default_rng(5)
    a.route(skewed_scores(rng, 256, 0.3))
    b.route(skewed_scores(rng, 256, 3.0))
    fab.sync_round()
    merged = a.thresholds
    cal = a.calibrator
    assert cal._last_swap_at == cal.window.total_seen
    # one more biased batch within the cooldown: no local counter-swap
    a.route(skewed_scores(rng, 64, 0.3))
    assert a.thresholds == merged


def test_merge_waits_for_min_samples():
    fab = ReplicaFabric()
    a = build(fleet_spec())
    fab.add_replica("a", a)
    rng = np.random.default_rng(6)
    a.route(skewed_scores(rng, 16, 1.0))    # < min_samples=64
    rep = fab.sync_round()
    assert rep["replicas"]["a"]["merged"] is False
    assert a.thresholds == (6.0,)           # untouched
