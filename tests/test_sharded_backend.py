"""`sharded` backend tests: bit-for-bit parity with `auto` over both the
score-batch and retrieve-to-decision paths, per-shard bucket padding,
mesh construction, and registry/spec plumbing.

The tests adapt to whatever host mesh is live — 1 device in the normal
tier-1 run, 8 in the CI leg that sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must
precede jax import, so it cannot be toggled per-test here).
"""

import numpy as np
import numpy.testing as npt
import pytest

import jax

from repro.api import RouteSpec, available_backends, build, make_backend
from repro.api.sharded import (SHARD_BUCKETS, ShardedBackend,
                               make_dispatch_mesh)
from repro.core.router import RouterConfig
from repro.retrieval.scorer import ScorerConfig, init_scorer
from repro.serving.scheduler import bucket_size


def desc_scores(b, k, seed=0):
    rng = np.random.default_rng(seed)
    return -np.sort(-rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                    axis=1)


CFG = RouterConfig(metric="entropy", thresholds=(4.0,), top_k=100)


# -- registry / construction --------------------------------------------------

def test_sharded_is_registered_and_constructs_lazily():
    assert "sharded" in available_backends()
    backend = make_backend("sharded", crossover_batch=16)
    assert backend.name == "sharded"
    assert backend.crossover_batch == 16
    assert backend._mesh is None        # no device state touched yet


def test_dispatch_mesh_shapes_and_validation():
    n_dev = jax.local_device_count()
    mesh = make_dispatch_mesh()
    assert mesh.shape["data"] == n_dev and mesh.shape["model"] == 1
    with pytest.raises(ValueError, match="n_candidate"):
        make_dispatch_mesh(n_candidate=0)
    with pytest.raises(ValueError, match="devices"):
        make_dispatch_mesh(n_request=n_dev + 1)


def test_route_spec_sharded_round_trips():
    spec = RouteSpec(metric="entropy", thresholds=(4.0,),
                     tier_names=("qwen7b", "qwen72b"), backend="sharded")
    assert RouteSpec.from_json(spec.to_json()) == spec


# -- parity with auto ---------------------------------------------------------

@pytest.mark.parametrize("b", [1, 8, 37, 1024])
def test_batch_parity_bit_for_bit(b):
    """Both sides of the crossover, ragged, awkward batch sizes."""
    auto = make_backend("auto")
    shard = make_backend("sharded")
    k = 100
    scores = desc_scores(b, k, seed=b)
    nv = np.random.default_rng(b).integers(5, k + 1, b)
    ra = auto.route_batch(scores, CFG, n_valid=nv)
    rs = shard.route_batch(scores, CFG, n_valid=nv)
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rs.tiers))
    npt.assert_array_equal(np.asarray(ra.difficulty),
                           np.asarray(rs.difficulty))
    npt.assert_array_equal(np.asarray(ra.metrics), np.asarray(rs.metrics))


def test_batch_parity_dense_rows():
    auto, shard = make_backend("auto"), make_backend("sharded")
    scores = desc_scores(64, 50, seed=3)
    ra = auto.route_batch(scores, CFG)
    rs = shard.route_batch(scores, CFG)
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rs.tiers))
    npt.assert_array_equal(np.asarray(ra.metrics), np.asarray(rs.metrics))


@pytest.mark.parametrize("b", [4, 96])
def test_retrieved_parity_bit_for_bit(b):
    """The fused retrieve-to-decision program, sharded vs unsharded:
    indices, probs, tiers, metrics all exactly equal."""
    sc = ScorerConfig(d_emb=16, d_hidden=32)
    params = init_scorer(jax.random.PRNGKey(0), sc)
    rng = np.random.default_rng(b)
    n, k = 64, 32
    feats = rng.standard_normal((b, n, sc.d_triple)).astype(np.float32)
    qemb = rng.standard_normal((b, sc.d_query)).astype(np.float32)
    nc = rng.integers(k, n + 1, b)
    cfg = RouterConfig(metric="entropy", thresholds=(3.0,), top_k=k)
    ra = make_backend("auto").route_retrieved(feats, qemb, params, cfg,
                                              n_cand=nc)
    rs = make_backend("sharded").route_retrieved(feats, qemb, params, cfg,
                                                 n_cand=nc)
    npt.assert_array_equal(np.asarray(ra.indices), np.asarray(rs.indices))
    npt.assert_array_equal(np.asarray(ra.probs), np.asarray(rs.probs))
    npt.assert_array_equal(np.asarray(ra.n_valid), np.asarray(rs.n_valid))
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rs.tiers))
    npt.assert_array_equal(np.asarray(ra.metrics), np.asarray(rs.metrics))


def test_session_level_parity_and_snapshot():
    """A sharded session routes exactly like an auto session and its
    snapshot restores (the backend is policy; the mesh is not)."""
    scores = desc_scores(256, 100, seed=9)
    mk = lambda be: RouteSpec(metric="entropy", thresholds=(4.0,),
                              top_k=100, tier_names=("qwen7b", "qwen72b"),
                              backend=be)
    s_auto, s_shard = build(mk("auto")), build(mk("sharded"))
    ra, rs = s_auto.route(scores), s_shard.route(scores)
    assert [r.tier for r in ra.records] == [r.tier for r in rs.records]
    snap = s_shard.snapshot()
    from repro.api import SkewRouteSession
    replica = SkewRouteSession.from_snapshot(snap)
    assert replica.spec.backend == "sharded"
    rr = replica.route(scores)
    assert [r.tier for r in rr.records] == [r.tier for r in rs.records]


def test_cascade_policy_parity_under_sharded():
    """The cascade policy composes transparently with the sharded
    backend: decisions (and per-request escalation costs) match the
    auto backend bit-for-bit — the policy transforms the SAME [B, 4]
    metric matrix host-side regardless of how it was computed."""
    from repro.api import CascadePolicySpec
    scores = desc_scores(256, 100, seed=11)
    rng = np.random.default_rng(11)
    self_scores = rng.uniform(0, 1, 256).astype(np.float32)
    mk = lambda be: RouteSpec(
        metric="entropy", thresholds=(4.0,), top_k=100,
        tier_names=("qwen7b", "qwen72b"), backend=be,
        policy=CascadePolicySpec(escalation_cutoffs=(5.0,),
                                 self_score_cutoff=0.8))
    s_auto, s_shard = build(mk("auto")), build(mk("sharded"))
    ra = s_auto.route(scores, self_scores=self_scores)
    rs = s_shard.route(scores, self_scores=self_scores)
    npt.assert_array_equal(np.asarray(ra.tiers), np.asarray(rs.tiers))
    npt.assert_array_equal(np.asarray(ra.request_cost),
                           np.asarray(rs.request_cost))
    assert s_auto.policy.telemetry() == s_shard.policy.telemetry()


# -- padding math -------------------------------------------------------------

def test_per_shard_bucket_padding():
    backend = ShardedBackend()
    r = jax.local_device_count()
    for b in (1, 7, 64, 100, 1000):
        bpad = backend._pad_rows(b, r)
        assert bpad >= b and bpad % r == 0
        assert bpad // r == bucket_size(-(-b // r), SHARD_BUCKETS)


def test_padded_rows_do_not_leak_into_results():
    """B chosen so padding is non-trivial on any device count; the
    returned arrays are exactly B long."""
    backend = make_backend("sharded")
    b = 5
    res = backend.route_batch(desc_scores(b, 40, seed=1),
                              RouterConfig(metric="gini",
                                           thresholds=(0.5,), top_k=40))
    assert np.asarray(res.tiers).shape == (b,)
    assert np.asarray(res.metrics).shape == (b, 4)
