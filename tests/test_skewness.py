"""Skewness metric unit + property tests (paper §3.2/§3.3 math)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import skewness as sk
from tests._hypothesis_compat import given, st


def powerlaw(k=100, alpha=1.5):
    return (1.0 / np.arange(1, k + 1) ** alpha).astype(np.float32)


def flat(k=100):
    return (0.5 + 0.5 * np.exp(-np.arange(k) / 200.0)).astype(np.float32)


def test_paper_figure3_area_separation():
    """Fig 3c/3d: power-law area tiny, flat area large (paper: 1.07 vs 65.65)."""
    a_pow = float(sk.area_metric(jnp.asarray(powerlaw())[None])[0])
    a_flat = float(sk.area_metric(jnp.asarray(flat())[None])[0])
    assert a_pow < 5.0 < a_flat
    assert a_flat > 10 * a_pow


def test_direction_conventions():
    """All difficulty metrics must rank flat (hard) above power-law (easy)."""
    batch = jnp.asarray(np.stack([powerlaw(), flat()]))
    for name in sk.METRICS:
        d = sk.difficulty(batch, metric=name)
        assert float(d[1]) > float(d[0]), name


@given(st.integers(2, 60), st.integers(0, 10_000))
def test_metric_bounds(k, seed):
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.uniform(0.01, 1, (3, k)).astype(np.float32))
    assert jnp.all(sk.area_metric(s) >= 0) and jnp.all(sk.area_metric(s) <= k)
    assert jnp.all(sk.entropy_metric(s) >= -1e-4)
    assert jnp.all(sk.entropy_metric(s) <= np.log2(k) + 1e-4)
    g = sk.gini_metric(s)
    assert jnp.all(g >= 0) and jnp.all(g <= 1)
    ck = sk.cumulative_k(s)
    assert jnp.all(ck >= 1) and jnp.all(ck <= k)


@given(st.floats(0.5, 20.0), st.integers(0, 1000))
def test_scale_invariance(scale, seed):
    """Prob-normalized metrics are invariant to positive scaling."""
    rng = np.random.default_rng(seed)
    s = rng.uniform(0.01, 1, (2, 50)).astype(np.float32)
    a, b = jnp.asarray(s), jnp.asarray(s * scale)
    for fn in [sk.entropy_metric, sk.gini_metric, sk.area_metric]:
        np.testing.assert_allclose(fn(a), fn(b), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(sk.cumulative_k(a), sk.cumulative_k(b))


@given(st.integers(5, 40), st.integers(0, 1000))
def test_mask_matches_truncation(k, seed):
    """Masked ragged metrics == metrics on the truncated vector."""
    rng = np.random.default_rng(seed)
    full = rng.uniform(0.01, 1, (1, 64)).astype(np.float32)
    mask = np.zeros((1, 64), bool)
    mask[0, :k] = True
    trunc = jnp.asarray(full[:, :k])
    m = jnp.asarray(mask)
    f = jnp.asarray(full)
    np.testing.assert_allclose(sk.area_metric(f, m), sk.area_metric(trunc),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sk.entropy_metric(f, m),
                               sk.entropy_metric(trunc), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sk.gini_metric(f, m), sk.gini_metric(trunc),
                               rtol=1e-3, atol=1e-3)


def test_entropy_extremes():
    onehot = jnp.asarray(np.eye(1, 50, dtype=np.float32))
    uniform = jnp.ones((1, 50), jnp.float32)
    assert float(sk.entropy_metric(onehot)[0]) < 0.01
    np.testing.assert_allclose(sk.entropy_metric(uniform)[0], np.log2(50),
                               rtol=1e-4)
    assert float(sk.gini_metric(onehot)[0]) > 0.9
    assert float(sk.gini_metric(uniform)[0]) < 0.01


def test_gini_paper_formula_reference():
    """Cross-check against a literal transcription of the paper's formula."""
    rng = np.random.default_rng(0)
    s = np.sort(rng.uniform(0, 1, 100))
    k = len(s)
    ref = (k + 1 - 2 * sum((k - i + 1) * s[i - 1] for i in range(1, k + 1))
           / s.sum()) / k
    got = float(sk.gini_metric(jnp.asarray(s, jnp.float32)[None])[0])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
