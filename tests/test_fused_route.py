"""End-to-end fused routing tests: `route_retrieved` vs the staged host
reference, the auto backend's batch-size crossover, and call-time
interpret resolution across snapshot/restore.

Parity bar matches the kernel suite (atol 1e-5) and deliberately covers
the awkward shapes: ragged per-query candidate counts, K that is not a
multiple of the kernel's 128 tile, and all four skew metrics. The
Figure-3 anchors are pushed through the WHOLE fused program via an
identity-passthrough scorer so the paper's printed area values survive
score -> top-k -> sigmoid -> skew intact.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro.api.backends as backends_mod
from repro.api import (AutoBackend, FusedBackend, OracleBackend, RouteSpec,
                       build, make_backend)
from repro.core.router import (RouterConfig, route_retrieved,
                               route_retrieved_staged)
from tests.test_skew_fastpath import (FIG3_FLAT_BETA, FIG3_POWERLAW_ALPHA,
                                      fig3_flat, fig3_powerlaw)

ATOL = 1e-5

D_TRIPLE, D_QUERY, D_HIDDEN = 12, 8, 16


def _params(rng, dt=D_TRIPLE, dq=D_QUERY, h=D_HIDDEN):
    return {
        "w1_t": jnp.asarray(rng.normal(0, 0.3, (dt, h)).astype(np.float32)),
        "w1_q": jnp.asarray(rng.normal(0, 0.3, (dq, h)).astype(np.float32)),
        "b1": jnp.asarray(rng.normal(0, 0.1, (h,)).astype(np.float32)),
        "w2": jnp.asarray(rng.normal(0, 0.3, (h, 1)).astype(np.float32)),
        "b2": jnp.asarray(rng.normal(0, 0.1, (1,)).astype(np.float32)),
    }


def _batch(rng, b, n, dt=D_TRIPLE, dq=D_QUERY):
    feats = rng.normal(0, 1, (b, n, dt)).astype(np.float32)
    qemb = rng.normal(0, 1, (b, dq)).astype(np.float32)
    return jnp.asarray(feats), jnp.asarray(qemb)


def _assert_parity(fused, staged):
    np.testing.assert_array_equal(np.asarray(fused.tiers),
                                  np.asarray(staged.tiers))
    np.testing.assert_allclose(np.asarray(fused.metrics),
                               np.asarray(staged.metrics), atol=ATOL)
    np.testing.assert_allclose(np.asarray(fused.difficulty),
                               np.asarray(staged.difficulty), atol=ATOL)
    np.testing.assert_array_equal(np.asarray(fused.n_valid),
                                  np.asarray(staged.n_valid))
    # retrieval output parity on the valid prefix only (pad cols free)
    f_idx, s_idx = np.asarray(fused.indices), np.asarray(staged.indices)
    f_p, s_p = np.asarray(fused.probs), np.asarray(staged.probs)
    for i, nv in enumerate(np.asarray(fused.n_valid)):
        np.testing.assert_array_equal(f_idx[i, :nv], s_idx[i, :nv])
        np.testing.assert_allclose(f_p[i, :nv], s_p[i, :nv], atol=ATOL)


# -- fused vs staged parity ---------------------------------------------------

@pytest.mark.parametrize("metric", ["area", "cumulative", "entropy", "gini"])
@pytest.mark.parametrize("use_kernels", [True, False])
def test_fused_matches_staged_all_metrics(metric, use_kernels):
    """One program == four host stages, for every skew metric, with the
    Pallas kernels (interpret) AND the XLA refs traced into the chain."""
    rng = np.random.default_rng(hash(metric) % 2**31)
    feats, qemb = _batch(rng, b=6, n=64)
    params = _params(rng)
    config = RouterConfig(metric=metric, thresholds=(0.3, 3.0), top_k=32)
    fused = route_retrieved(feats, qemb, params, config,
                            interpret=True, use_kernels=use_kernels)
    staged = route_retrieved_staged(feats, qemb, params, config)
    _assert_parity(fused, staged)


def test_fused_matches_staged_ragged_and_odd_k():
    """Ragged n_cand (some rows shorter than K) and K=37 — not a multiple
    of the triple_score kernel's 128 tile, N not a multiple either."""
    rng = np.random.default_rng(7)
    feats, qemb = _batch(rng, b=8, n=50)
    params = _params(rng)
    n_cand = np.array([50, 3, 37, 12, 50, 1, 49, 25], np.int32)
    config = RouterConfig(metric="gini", thresholds=(0.5,), top_k=37)
    fused = route_retrieved(feats, qemb, params, config, n_cand=n_cand,
                            interpret=True, use_kernels=True)
    staged = route_retrieved_staged(feats, qemb, params, config,
                                    n_cand=n_cand)
    _assert_parity(fused, staged)
    assert np.asarray(fused.n_valid).tolist() == \
        np.minimum(n_cand, 37).tolist()


def test_fused_kernels_vs_oracle_chain():
    """The kernel-built program and the XLA-built program are the same
    function (this is what makes the crossover a pure perf policy)."""
    rng = np.random.default_rng(11)
    feats, qemb = _batch(rng, b=5, n=40)
    params = _params(rng)
    config = RouterConfig(metric="entropy", thresholds=(4.0,), top_k=16)
    a = route_retrieved(feats, qemb, params, config,
                        interpret=True, use_kernels=True)
    b = route_retrieved(feats, qemb, params, config,
                        interpret=True, use_kernels=False)
    np.testing.assert_array_equal(np.asarray(a.tiers), np.asarray(b.tiers))
    np.testing.assert_allclose(np.asarray(a.metrics),
                               np.asarray(b.metrics), atol=ATOL)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))


# -- Figure-3 anchors through the whole program -------------------------------

def _passthrough_params(dt):
    """A scorer whose output IS feature 0: relu(f0) - relu(-f0) = f0.
    Lets known probability vectors ride through score -> top-k ->
    sigmoid untouched (modulo float32 logit/sigmoid round-trip)."""
    w1_t = np.zeros((dt, 2), np.float32)
    w1_t[0, 0], w1_t[0, 1] = 1.0, -1.0
    return {
        "w1_t": jnp.asarray(w1_t),
        "w1_q": jnp.zeros((D_QUERY, 2), jnp.float32),
        "b1": jnp.zeros((2,), jnp.float32),
        "w2": jnp.asarray(np.array([[1.0], [-1.0]], np.float32)),
        "b2": jnp.zeros((1,), jnp.float32),
    }


@pytest.mark.parametrize("use_kernels", [True, False])
def test_fig3_anchors_through_fused_program(use_kernels):
    """Paper Figure-3 anchor vectors fed as logits: the fused program's
    area metric must land on the printed values (1.07 power-law easy,
    65.65 flat hard) after the full score->top-k->sigmoid->skew chain."""
    k = 100
    probs = np.stack([fig3_powerlaw(k), fig3_flat(k)])  # [2, 100] in (0,1]
    p = np.clip(probs, 1e-7, 1.0 - 1e-6)
    logits = np.log(p) - np.log1p(-p)                   # sigmoid^-1
    feats = np.zeros((2, k, D_TRIPLE), np.float32)
    feats[:, :, 0] = logits
    # shuffle candidate order: top-k must restore the descending vectors
    rng = np.random.default_rng(0)
    perm = rng.permutation(k)
    feats = feats[:, perm, :]
    qemb = np.zeros((2, D_QUERY), np.float32)
    config = RouterConfig(metric="area", thresholds=(10.0,), top_k=k)
    out = route_retrieved(jnp.asarray(feats), jnp.asarray(qemb),
                          _passthrough_params(D_TRIPLE), config,
                          interpret=True, use_kernels=use_kernels)
    area = np.asarray(out.metrics)[:, 0]
    np.testing.assert_allclose(area, [1.07, 65.65], atol=5e-3)
    # and the tiers split exactly as the paper reads Figure 3:
    # peaked scores -> easy (small model), flat scores -> hard
    assert np.asarray(out.tiers).tolist() == [0, 1]
    np.testing.assert_allclose(np.sort(np.asarray(out.probs)[0])[::-1],
                               p[0], atol=1e-4)


# -- auto backend crossover ---------------------------------------------------

def test_auto_crossover_pick_boundaries():
    auto = AutoBackend(crossover_batch=4)
    assert auto.pick(1) is auto.oracle
    assert auto.pick(3) is auto.oracle
    assert auto.pick(4) is auto.fused
    assert auto.pick(4096) is auto.fused
    assert isinstance(auto.oracle, OracleBackend)
    assert isinstance(auto.fused, FusedBackend)


def test_auto_routes_by_leading_dim():
    """route_batch/route_retrieved agree with an explicit pick() — and
    both sides of the crossover give the SAME answers."""
    rng = np.random.default_rng(3)
    scores = np.sort(rng.uniform(0.01, 1, (8, 20)).astype(np.float32),
                     axis=1)[:, ::-1].copy()
    config = RouterConfig(metric="gini", thresholds=(0.5,), top_k=20)
    below = AutoBackend(crossover_batch=100).route_batch(scores, config)
    above = AutoBackend(crossover_batch=2).route_batch(scores, config)
    np.testing.assert_array_equal(np.asarray(below.tiers),
                                  np.asarray(above.tiers))
    np.testing.assert_allclose(np.asarray(below.metrics),
                               np.asarray(above.metrics), atol=ATOL)


def test_auto_crossover_validation():
    with pytest.raises(ValueError, match="crossover_batch"):
        AutoBackend(crossover_batch=0)
    with pytest.raises(ValueError, match="crossover_batch"):
        RouteSpec(metric="gini", thresholds=(0.5,), tier_names=("a", "b"),
                  crossover_batch=0)


def test_crossover_rides_the_spec():
    spec = RouteSpec(metric="gini", thresholds=(0.5,), tier_names=("a", "b"),
                     backend="auto", crossover_batch=7)
    spec2 = RouteSpec.from_json(spec.to_json())
    assert spec2.crossover_batch == 7
    session = build(spec2)
    assert isinstance(session.backend, AutoBackend)
    assert session.backend.crossover_batch == 7
    # old payloads (no field) load with the default
    payload = json.loads(spec.to_json())
    del payload["crossover_batch"]
    old = RouteSpec.from_dict(payload)
    assert old.crossover_batch == backends_mod.DEFAULT_CROSSOVER_BATCH


# -- call-time interpret resolution across snapshot/restore -------------------

def test_restore_re_resolves_interpret(monkeypatch):
    """A snapshot taken on one host class (say TPU, interpret False) and
    restored on another (CPU) must NOT replay the donor's interpret mode:
    the spec/snapshot carry no interpret bit, and the restored backend
    re-resolves `default_interpret()` at every call."""
    spec = RouteSpec(metric="gini", thresholds=(0.5,), tier_names=("a", "b"),
                     backend="auto", top_k=16)
    session = build(spec)
    session.route(np.sort(
        np.random.default_rng(0).uniform(0.01, 1, (4, 16)).astype(
            np.float32), axis=1)[:, ::-1].copy())
    snap = json.loads(json.dumps(session.snapshot()))  # wire round-trip
    assert "interpret" not in json.dumps(snap)

    restored = build(RouteSpec.from_json(spec.to_json()))
    restored.restore(snap)
    assert restored.backend.interpret is None  # never baked in

    # flip what the "local device" claims to be: the restored backend
    # must follow, proving resolution happens at call time
    monkeypatch.setattr(backends_mod, "default_interpret", lambda: True)
    assert restored.backend.effective_interpret() is True
    monkeypatch.setattr(backends_mod, "default_interpret", lambda: False)
    assert restored.backend.effective_interpret() is False

    # an explicit override still wins over the device default
    assert make_backend("fused", interpret=True).effective_interpret() is True
