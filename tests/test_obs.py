"""Unified observability plane (ISSUE tentpole): metrics registry,
request tracing, exporters, device-program profiling — and the
acceptance criterion: ONE canonical LoadRunner replay yields a complete
per-request timeline (dispatch -> policy -> [spill] -> execute ->
complete) for EVERY request, verified by walking the JSONL export."""

import json

import numpy as np
import pytest

from repro.api import CalibrationSpec, RouteSpec, build
from repro.obs import (NULL_OBS, DEFAULT_TIME_BUCKETS, ManualClock,
                       MetricsRegistry, Observability, int_keyed,
                       prometheus_text, profile_program,
                       request_timelines, span_tree, str_keyed, to_jsonl)
from repro.serving.loadgen import canonical_load_runner, canonical_trace


def mk_spec(**overrides):
    kw = dict(metric="entropy", thresholds=(6.0,), top_k=50,
              tier_names=("qwen7b", "qwen72b"),
              calibration=CalibrationSpec(policy="streaming",
                                          target_shares=(0.7, 0.3),
                                          window=256, min_samples=32,
                                          tolerance=0.08, cooldown=64))
    kw.update(overrides)
    return RouteSpec(**kw)


def desc_scores(rng, b, k=50):
    return -np.sort(-rng.uniform(0.01, 1, (b, k)).astype(np.float32),
                    axis=1)


# -- registry -----------------------------------------------------------------

def test_registry_instruments_and_label_keying():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", tier="0")
    c.inc()
    c.inc(3)
    assert reg.value("requests_total", tier="0") == 4
    # same (name, labels) -> the same live instrument
    assert reg.counter("requests_total", tier="0") is c
    assert reg.counter("requests_total", tier="1") is not c
    g = reg.gauge("depth")
    g.set(7.5)
    g.inc(-0.5)
    assert reg.value("depth") == 7.0
    h = reg.histogram("lat", (0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    assert h.n == 3 and h.counts == [1, 1, 1]
    assert h.total == pytest.approx(5.55)


def test_registry_rejects_kind_clash_and_bad_buckets():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError):
        reg.gauge("x")
    with pytest.raises(ValueError):
        reg.histogram("h", (1.0, 1.0))          # not strictly increasing
    reg.histogram("h", (1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("h", (1.0, 3.0))          # bucket mismatch, same key


def test_registry_state_roundtrip_restores_in_place():
    reg = MetricsRegistry()
    c = reg.counter("n", tier="1")
    c.inc(5)
    h = reg.histogram("t", DEFAULT_TIME_BUCKETS)
    h.observe(0.01)
    state = json.loads(json.dumps(reg.state_dict()))

    reg2 = MetricsRegistry()
    c2 = reg2.counter("n", tier="1")          # instrument cached pre-load
    reg2.counter("other").inc(9)              # not in the snapshot
    reg2.load_state_dict(state)
    assert c2.value == 5                      # live handle sees the load
    assert reg2.value("other") == 0           # unseen metrics reset
    # the loaded subset round-trips exactly
    by_key = {(s["name"], tuple(sorted(s["labels"].items()))): s
              for s in reg2.state_dict()["samples"]}
    for s in state["samples"]:
        assert by_key[(s["name"],
                       tuple(sorted(s["labels"].items())))] == s


def test_null_plane_is_inert_and_shared():
    assert not NULL_OBS.enabled
    i1 = NULL_OBS.metrics.counter("a", x="1")
    i2 = NULL_OBS.metrics.histogram("b", (1.0,))
    assert i1 is i2                            # one shared no-op instrument
    i1.inc()
    i2.observe(3.0)
    assert NULL_OBS.metrics.state_dict() == {"samples": []}
    with NULL_OBS.tracer.span("s") as sp:
        sp.event("e", k=1)
    assert NULL_OBS.tracer.events() == []
    assert NULL_OBS.clock.now() == 0.0


# -- tracer -------------------------------------------------------------------

def test_tracer_span_nesting_and_deterministic_ids():
    obs = Observability(clock=ManualClock())
    with obs.tracer.span("outer", a=1) as outer:
        with obs.tracer.span("inner"):
            obs.tracer.event("tick", n=2)
        outer.event("done")
    evs = obs.tracer.events()
    tree = span_tree(evs)
    inner = next(n for n in tree.values() if n["name"] == "inner")
    out = next(n for n in tree.values() if n["name"] == "outer")
    assert inner["parent"] == out["span"] and out["parent"] is None
    assert inner["span"] in out["children"]
    # sequential ids, no RNG: a second identical run is byte-identical
    obs2 = Observability(clock=ManualClock())
    with obs2.tracer.span("outer", a=1) as o2:
        with obs2.tracer.span("inner"):
            obs2.tracer.event("tick", n=2)
        o2.event("done")
    assert to_jsonl(evs) == to_jsonl(obs2.tracer.events())


def test_tracer_bounded_buffer_counts_drops():
    obs = Observability(clock=ManualClock(), max_events=3)
    for i in range(6):
        obs.tracer.event("e", i=i)
    assert len(obs.tracer) == 3
    assert obs.tracer.n_dropped == 3
    obs.tracer.clear()
    assert len(obs.tracer) == 0 and obs.tracer.n_dropped == 0


# -- exporter goldens (seeded clock => byte-stable) ---------------------------

def golden_plane() -> Observability:
    obs = Observability(clock=ManualClock(start=1.0, step=0.5))
    obs.metrics.counter("routing_requests_total").inc(3)
    obs.metrics.gauge("pipeline_queue_depth", tier="0").set(2)
    h = obs.metrics.histogram("dispatch_seconds", (0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    with obs.tracer.span("dispatch", batch=2) as sp:
        sp.event("policy", first_id=0, tiers=np.asarray([0, 1]))
    return obs


GOLDEN_JSONL = (
    '{"attrs":{"batch":2},"kind":"span_start","name":"dispatch",'
    '"parent":null,"span":1,"trace":1,"ts":1.0}\n'
    '{"attrs":{"first_id":0,"tiers":[0,1]},"kind":"event",'
    '"name":"policy","span":1,"trace":1,"ts":1.5}\n'
    '{"kind":"span_end","name":"dispatch","span":1,"trace":1,"ts":2.0}')

GOLDEN_PROM = """\
# TYPE dispatch_seconds histogram
dispatch_seconds_bucket{le="0.1"} 1
dispatch_seconds_bucket{le="1"} 2
dispatch_seconds_bucket{le="+Inf"} 2
dispatch_seconds_sum 0.55
dispatch_seconds_count 2
# TYPE pipeline_queue_depth gauge
pipeline_queue_depth{tier="0"} 2
# TYPE routing_requests_total counter
routing_requests_total 3
"""


def test_jsonl_export_golden_bytes():
    assert golden_plane().jsonl() == GOLDEN_JSONL
    # and twice over: the export is a pure function of the plane
    assert golden_plane().jsonl() == golden_plane().jsonl()


def test_prometheus_export_golden_bytes():
    assert golden_plane().prometheus() == GOLDEN_PROM


def test_export_jsonl_writes_lines(tmp_path):
    p = tmp_path / "trace.jsonl"
    n = golden_plane().export_jsonl(p)
    lines = p.read_text().strip().split("\n")
    assert n == len(lines) == 3
    for line in lines:
        json.loads(line)


# -- keys helper (satellite: ONE int-key JSON round-trip) ---------------------

def test_keyed_helpers_roundtrip():
    d = {0: 5, 3: 7}
    assert str_keyed(d) == {"0": 5, "3": 7}
    assert int_keyed(str_keyed(d)) == d
    assert int_keyed({"1": 2.5}, value=float) == {1: 2.5}


def test_pipeline_tier_counts_survive_json_roundtrip():
    session = build(mk_spec(), runners={0: lambda b: b, 1: lambda b: b},
                    obs=Observability(clock=ManualClock()))
    rng = np.random.default_rng(0)
    session.submit(desc_scores(rng, 64), list(range(64)))
    session.flush()
    t = session.pipeline.telemetry
    state = json.loads(json.dumps(t.state_dict()))
    t2 = type(t)()
    t2.load_state_dict(state)
    assert t2.tier_counts == t.tier_counts
    assert all(isinstance(k, int) for k in t2.tier_counts)


# -- dispatcher / session integration ----------------------------------------

def test_route_emits_dispatch_and_policy_events():
    obs = Observability(clock=ManualClock())
    session = build(mk_spec(), obs=obs)
    rng = np.random.default_rng(1)
    res = session.route(desc_scores(rng, 16))
    tl = request_timelines(obs.tracer.events())
    assert sorted(tl) == list(range(16))
    for rid, stages in tl.items():
        assert [s["stage"] for s in stages] == ["dispatch", "policy"]
        assert stages[1]["kind"] == "threshold"
        assert stages[1]["tier"] == int(np.asarray(res.tiers)[rid])
    # registry mirrors moved too
    assert obs.metrics.value("routing_requests_total") == 16
    tiers = np.asarray(res.tiers)
    for t in (0, 1):
        assert obs.metrics.value("routing_tier_decisions_total",
                                 tier=str(t)) == int((tiers == t).sum())


def test_obs_is_runtime_config_not_spec():
    session = build(mk_spec())
    assert session.obs is NULL_OBS
    rng = np.random.default_rng(2)
    session.route(desc_scores(rng, 8))        # no obs, no events, no error
    snap = session.snapshot()
    assert "obs" not in snap["state"]         # envelope byte-compat


def test_backend_pick_counter_tracks_crossover():
    obs = Observability(clock=ManualClock())
    session = build(mk_spec(), obs=obs)
    rng = np.random.default_rng(3)
    session.route(desc_scores(rng, 4))        # below crossover -> oracle
    session.route(desc_scores(rng, 64))       # above -> fused
    assert obs.metrics.value("backend_pick_total", path="oracle") == 1
    assert obs.metrics.value("backend_pick_total", path="fused") == 1


# -- snapshot / restore -------------------------------------------------------

def test_obs_state_rides_the_envelope_and_restores():
    obs = Observability(clock=ManualClock())
    session = build(mk_spec(), runners={0: lambda b: b, 1: lambda b: b},
                    obs=obs)
    rng = np.random.default_rng(4)
    session.submit(desc_scores(rng, 48), list(range(48)))
    session.flush()
    snap = json.loads(json.dumps(session.snapshot()))
    assert "obs" in snap["state"]

    obs2 = Observability(clock=ManualClock())
    restored = build(mk_spec(), runners={0: lambda b: b, 1: lambda b: b},
                     obs=obs2)
    restored.restore(snap)
    assert (obs2.metrics.value("pipeline_submitted_total")
            == obs2.metrics.value("routing_requests_total") == 48)
    # live mirrors keep counting from the restored values
    restored.submit(desc_scores(rng, 16), list(range(48, 64)))
    restored.flush()
    t = restored.pipeline.telemetry
    assert t.n_submitted == t.n_executed + restored.pipeline.pending() == 64
    assert obs2.metrics.value("pipeline_submitted_total") == 64
    assert obs2.metrics.value("pipeline_executed_total") == t.n_executed


def test_obs_less_restore_of_obs_snapshot_is_fine():
    obs = Observability(clock=ManualClock())
    session = build(mk_spec(), obs=obs)
    rng = np.random.default_rng(5)
    session.route(desc_scores(rng, 8))
    snap = session.snapshot()
    plain = build(mk_spec())
    plain.restore(json.loads(json.dumps(snap)))   # obs block ignored
    assert plain.stats.n_requests == 8


def test_trace_events_never_serialize():
    obs = Observability(clock=ManualClock())
    session = build(mk_spec(), obs=obs)
    rng = np.random.default_rng(6)
    session.route(desc_scores(rng, 8))
    assert len(obs.tracer) > 0
    state = json.loads(json.dumps(session.snapshot()["state"]["obs"]))
    # metric samples only — no event list, no span ids (a restored
    # replica starts a fresh timeline; counters carry the history)
    assert set(state) == {"samples"}
    assert all(set(s) >= {"name", "labels", "kind"}
               for s in state["samples"])


# -- device-program profiling -------------------------------------------------

def test_profile_program_measures_and_registers():
    import jax.numpy as jnp

    reg = MetricsRegistry()
    prof = profile_program(lambda x: jnp.sum(x * 2.0),
                           (jnp.ones((64, 32), jnp.float32),),
                           name="toy", shape="64x32", iters=2, warmup=1,
                           registry=reg)
    assert prof.wall_s > 0 and prof.compile_s > 0
    assert prof.flops >= 0 and prof.achieved_gflops >= 0
    assert reg.value("program_wall_seconds", program="toy",
                     shape="64x32") == prof.wall_s
    d = prof.to_dict()
    assert d["name"] == "toy" and json.loads(json.dumps(d)) == d


# -- mode topology (satellite: no_rag tiers skip retrieval-sized prompts) -----

def test_mode_select_pools_serve_bare_question_prompts():
    trace = canonical_trace("smoke")
    runner = canonical_load_runner(False, trace, policy="mode_select")
    assert runner.pools[0].mode == "no_rag"
    assert runner.pools[1].mode == runner.pools[2].mode == "kg_rag"
    report = runner.run(trace)
    from repro.core.cost import TOKENS_BARE_QUESTION
    lens = {t: {r.prompt_len for r in p.done}
            for t, p in runner.pools.items() if p.done}
    assert lens.get(0, {TOKENS_BARE_QUESTION}) == {TOKENS_BARE_QUESTION}
    for t in (1, 2):
        assert lens.get(t, {1873}) == {1873}
    assert report.summary["tier_modes"]["0"] == "no_rag"


def test_scheduler_mode_defaults_to_kg_rag():
    from repro.serving.scheduler import Replica, TierScheduler
    pool = TierScheduler(0, [Replica(0, 0)])
    assert pool.mode == "kg_rag"


# -- THE acceptance test: full timeline from one canonical replay -------------

def replay_with_obs(policy=None):
    trace = canonical_trace("smoke")
    obs = Observability(clock=ManualClock())
    runner = canonical_load_runner(True, trace, policy=policy, obs=obs)
    report = runner.run(trace)
    return runner, report, obs


def test_canonical_replay_yields_complete_timelines(tmp_path):
    runner, report, obs = replay_with_obs()
    path = tmp_path / "trace.jsonl"
    obs.export_jsonl(path)
    events = [json.loads(line) for line in
              path.read_text().strip().split("\n")]
    tl = request_timelines(events)

    n = report.summary["n_arrivals"]
    assert n > 0 and sorted(tl) == list(range(n))
    spilled = set()
    for rid, stages in tl.items():
        names = [s["stage"] for s in stages]
        # every request: dispatched, policy-decided, executed, completed
        assert names[0] == "dispatch"
        assert names[1] == "policy"
        assert "execute" in names and "complete" in names
        assert names.index("execute") < names.index("complete")
        # the tier the request EXECUTED on is the policy tier unless an
        # admission spill moved it — and then the spill hop is recorded
        exec_tier = stages[names.index("execute")]["tier"]
        decided = stages[1]["tier"]
        if "spill" in names:
            hop = stages[names.index("spill")]
            assert hop["tier_in"] == decided and hop["tier"] == exec_tier
            spilled.add(rid)
        else:
            assert exec_tier == decided
        # timestamps are monotone within the request's life
        ts = [s["ts"] for s in stages]
        assert ts == sorted(ts)
    # spill hops in the trace == the controller's spill counter
    assert len(spilled) == report.summary["n_spilled"] > 0

    # span forest: every submit span contains a dispatch child
    tree = span_tree(events)
    submits = [s for s in tree.values() if s["name"] == "submit"]
    assert submits
    for s in submits:
        kids = {tree[c]["name"] for c in s["children"]}
        assert "dispatch" in kids

    # the registry tells the same aggregate story as the telemetry
    t = runner.session.pipeline.telemetry
    assert obs.metrics.value("pipeline_submitted_total") == t.n_submitted
    assert obs.metrics.value("pipeline_executed_total") == t.n_executed == n
    assert sum(obs.metrics.value("load_completed_total", tier=str(k))
               for k in runner.pools) == report.summary["n_completed"]


def test_cascade_escalations_appear_in_policy_stage():
    runner, report, obs = replay_with_obs(policy="cascade")
    tl = request_timelines(obs.tracer.events())
    policy_stages = [s for stages in tl.values() for s in stages
                     if s["stage"] == "policy"]
    assert {s["kind"] for s in policy_stages} == {"cascade"}
    # a cascade escalation = the request went past tier 0; the timeline
    # carries each one (and tier_in shows rows where the cascade
    # overrode the backend's threshold decision)
    escalated = sum(1 for s in policy_stages if s["tier"] > 0)
    pol = runner.session.policy.telemetry()
    assert escalated == pol["n_escalated"] > 0
    assert any("tier_in" in s for s in policy_stages)
