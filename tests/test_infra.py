"""Infrastructure tests: checkpoint, data pipeline, fault tolerance,
compression, scheduler, sharding rules, HLO cost parser."""

import json
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, st


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step": jnp.int32(7)}
    mgr.save(7, state)
    out = mgr.restore(state)
    np.testing.assert_array_equal(out["params"]["w"], state["params"]["w"])
    assert int(out["step"]) == 7


def test_checkpoint_retention_and_latest(tmp_path):
    from repro.training.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"x": jnp.zeros(3)}
    for s in [1, 2, 3, 4]:
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async_and_atomicity(tmp_path):
    from repro.training.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path, keep=3)
    state = {"x": jnp.ones((256, 256))}
    mgr.save(1, state, blocking=False)
    mgr.wait()
    assert not list(pathlib.Path(tmp_path).glob("tmp.*"))  # committed
    out = mgr.restore(state, step=1)
    np.testing.assert_array_equal(out["x"], state["x"])


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    from repro.training.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"x": jnp.zeros(3)})
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros(4)})


# -- data pipeline -------------------------------------------------------------

def test_stream_determinism_and_restart():
    from repro.data.pipeline import ShardedStream, lm_batch_factory
    f = lm_batch_factory(2, 8, 100)
    a = ShardedStream(f, seed=1, shard_id=0)
    b1, b2, b3 = next(a), next(a), next(a)
    # restart at step 2 reproduces batch 3 exactly
    b = ShardedStream(f, seed=1, shard_id=0, start_step=2)
    np.testing.assert_array_equal(next(b)["tokens"], b3["tokens"])
    # different shards differ
    c = ShardedStream(f, seed=1, shard_id=1)
    assert not np.array_equal(next(c)["tokens"], b1["tokens"])


def test_prefetcher_preserves_order_and_errors():
    from repro.data.pipeline import Prefetcher
    out = list(Prefetcher(iter(range(10)), prefetch=3))
    assert out == list(range(10))

    def bad():
        yield 1
        raise RuntimeError("boom")
    p = Prefetcher(bad(), prefetch=2)
    assert next(p) == 1
    with pytest.raises(RuntimeError):
        next(p)
        next(p)


# -- fault tolerance ------------------------------------------------------------

def test_failure_detection_and_recovery_plan():
    from repro.distributed.fault_tolerance import FaultToleranceManager
    ftm = FaultToleranceManager(n_workers=8, data_parallel=4,
                                model_parallel=2, timeout_steps=2, n_spares=1)
    for step in range(5):
        for w in range(8):
            if w == 3 and step >= 2:
                continue  # worker 3 goes silent at step 2
            ftm.heartbeat(w, step, latency_s=0.1)
    assert 3 in ftm.dead_workers()
    plan = ftm.make_recovery_plan(latest_checkpoint_step=40)
    assert plan.restart_step == 40
    assert plan.reassigned_shards.get(3) == 8    # spare absorbed it
    assert plan.new_data_parallel == 4           # no dp shrink needed


def test_elastic_shrink_without_spares():
    from repro.distributed.fault_tolerance import FaultToleranceManager
    ftm = FaultToleranceManager(n_workers=8, data_parallel=4,
                                model_parallel=2, n_spares=0)
    for w in range(8):
        ftm.heartbeat(w, 0, latency_s=0.1)
    ftm.inject_failure(5)
    plan = ftm.make_recovery_plan(latest_checkpoint_step=10)
    assert plan.new_data_parallel == 3           # one model-column lost
    bp = ftm.elastic_batch_plan(256, plan.new_data_parallel)
    assert bp["per_shard_batch"] * bp["data_parallel"] <= 256


def test_straggler_detection():
    from repro.distributed.fault_tolerance import FaultToleranceManager
    ftm = FaultToleranceManager(n_workers=4, data_parallel=4,
                                model_parallel=1, straggler_factor=2.0)
    for w in range(4):
        ftm.heartbeat(w, 1, latency_s=1.0 if w != 2 else 5.0)
    assert ftm.stragglers() == [2]


# -- compression -----------------------------------------------------------------

@given(st.integers(0, 200))
def test_int8_quantization_error_bound(seed):
    from repro.distributed.compression import compress_decompress
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 1, (300,)).astype(np.float32))
    y = compress_decompress(x)
    blockmax = float(jnp.max(jnp.abs(x)))
    assert float(jnp.max(jnp.abs(x - y))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    from repro.distributed.compression import apply_error_feedback
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1e-3, (256,)).astype(np.float32))}
    resid = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    total_true, total_sent = jnp.zeros(256), jnp.zeros(256)
    for _ in range(20):
        sent, resid = apply_error_feedback(g, resid)
        total_true += g["w"]
        total_sent += sent["w"]
    # cumulative compressed sum tracks the true sum (error feedback)
    np.testing.assert_allclose(total_sent, total_true, atol=2e-4)


def test_cross_pod_mean_shard_map():
    from repro.distributed.compression import cross_pod_mean_int8
    from repro.launch.mesh import make_auto_mesh
    mesh = make_auto_mesh((1,), ("pod",))
    grads = {"w": jnp.arange(256.0)}
    out = cross_pod_mean_int8(mesh)(grads)
    np.testing.assert_allclose(out["w"], grads["w"], rtol=1e-2, atol=1.1)


# -- scheduler --------------------------------------------------------------------

def _mk_req(i, tier=0, now=0.0, deadline=60.0):
    from repro.serving.scheduler import Request
    return Request(request_id=i, tier=tier, prompt_len=100, max_new=10,
                   deadline=now + deadline, submitted_at=now)


def test_scheduler_completes_all():
    from repro.serving.scheduler import Replica, TierScheduler
    s = TierScheduler(0, [Replica(0, 0), Replica(1, 0)], batch_slots=4)
    for i in range(12):
        s.submit(_mk_req(i))
    t = 0.0
    for _ in range(200):
        t += 0.1
        s.step(t)
        if len(s.done) == 12:
            break
    assert len(s.done) == 12
    assert all(r.finished_at is not None for r in s.done)


def test_straggler_redispatch():
    from repro.serving.scheduler import Replica, TierScheduler
    s = TierScheduler(0, [Replica(0, 0), Replica(1, 0)], batch_slots=2)
    s.submit(_mk_req(0, deadline=1.0))
    s.step(0.01)
    victim = s.inflight[0].replica
    s.mark_unhealthy(victim)
    for t in [0.5, 1.5, 2.5, 5.0, 10.0]:
        s.step(t)
    assert len(s.done) == 1
    assert s.done[0].redispatched >= 1
    assert s.done[0].replica != victim


# -- sharding rules ----------------------------------------------------------------

def test_logical_is_identity_without_mesh():
    from repro.distributed import sharding as shd
    x = jnp.ones((4, 4))
    assert shd.logical(x, "batch", "model") is x


def test_param_rules_divisibility_guard():
    from jax.sharding import PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh()
    with shd.use_mesh(mesh):
        leaf = jax.ShapeDtypeStruct((64, 47), jnp.float32)  # 47 % 1 == 0
        spec = shd.param_pspec(
            (jax.tree_util.DictKey("attn"), jax.tree_util.DictKey("wq")), leaf)
        assert isinstance(spec, P)


# -- HLO cost parser -----------------------------------------------------------------

def test_hlo_cost_matmul_exact():
    from repro.launch import hlo_cost
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    hlo = jax.jit(lambda a, b: a @ b).lower(a, b).compile().as_text()
    r = hlo_cost.analyze(hlo)
    assert r["flops"] == 2 * 64 * 128 * 32


def test_hlo_cost_scan_multiplier():
    from repro.launch import hlo_cost
    L = 5

    def f(x, ws):
        def step(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(step, x, ws)[0]
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 16, 16), jnp.float32)
    r = hlo_cost.analyze(jax.jit(f).lower(x, ws).compile().as_text())
    assert abs(r["flops"] / (L * 2 * 16 ** 3) - 1) < 0.01


def test_hlo_cost_nested_scan():
    from repro.launch import hlo_cost
    L, M = 4, 3

    def f(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(inner, x, jnp.arange(M))[0], None
        return jax.lax.scan(outer, x, ws)[0]
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 16, 16), jnp.float32)
    r = hlo_cost.analyze(jax.jit(f).lower(x, ws).compile().as_text())
    assert abs(r["flops"] / (L * M * 2 * 16 ** 3) - 1) < 0.01
