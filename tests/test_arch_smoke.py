"""Per-assigned-architecture smoke tests (deliverable f).

Each of the 10 archs instantiates a REDUCED config of the same family
(small widths/layers/experts/tables/graphs) and runs one forward or train
step on CPU, asserting output shapes and the absence of NaNs. The FULL
configs are exercised by the dry-run only (ShapeDtypeStructs).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_arch


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(tree)
               if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating))


def _reduce_lm(cfg):
    moe = cfg.moe and dataclasses.replace(cfg.moe, n_experts=4, d_ff=64,
                                          group_size=8)
    return dataclasses.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=max(4, min(cfg.n_heads, 8) - cfg.n_heads % 2),
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16, d_ff=128, vocab=512, moe=moe, dtype=jnp.float32,
        loss_chunk=16)


def _reduce_recsys(cfg):
    embed_dim = min(cfg.embed_dim, 16)
    # DLRM invariant: the bottom-MLP output feeds the dot interaction, so
    # its last width must equal embed_dim.
    bot = (tuple(min(x, 32) for x in cfg.bot_mlp[:-1]) + (embed_dim,)
           if cfg.bot_mlp else ())
    return dataclasses.replace(
        cfg, vocab_sizes=tuple(min(v, 100) for v in cfg.vocab_sizes),
        embed_dim=embed_dim,
        bot_mlp=bot,
        top_mlp=tuple(min(x, 32) for x in cfg.top_mlp),
        deep_mlp=tuple(min(x, 32) for x in cfg.deep_mlp),
        seq_len=min(cfg.seq_len, 8) if cfg.seq_len else 0,
        gru_dim=min(cfg.gru_dim, 12) if cfg.gru_dim else 0)


LM_IDS = [a for a, s in ARCHS.items() if s.family == "lm"]
REC_IDS = [a for a, s in ARCHS.items() if s.family == "recsys"]


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_arch_smoke(arch_id):
    from repro.models import transformer as T
    arch = get_arch(arch_id)
    cfg = _reduce_lm(arch.config)
    # family-defining features survive the reduction
    assert (cfg.moe is not None) == (arch.config.moe is not None)
    assert cfg.activation == arch.config.activation
    assert cfg.tie_embeddings == arch.config.tie_embeddings
    params = T.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    loss = T.train_loss(params, {"tokens": tokens, "labels": tokens}, cfg)
    assert np.isfinite(float(loss))
    logits, cache = T.prefill(params, tokens, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert cache["k"].shape == (cfg.n_layers, 2, 16, cfg.kv_dim)
    lg, cache = T.decode_step(params, cache, tokens[:, :1], jnp.int32(16 - 1),
                              cfg)
    assert lg.shape == (2, cfg.vocab) and _finite(lg)


def test_gat_cora_smoke():
    from repro.models import gnn
    arch = get_arch("gat-cora")
    cfg = arch.config  # already tiny (2L, 8x8) — the paper's exact config
    N, E, F, C = 60, 240, 16, 7
    params = gnn.init_params(jax.random.key(0), cfg, F, C)
    batch = dict(
        feats=jax.random.normal(jax.random.key(1), (N, F)),
        src=jax.random.randint(jax.random.key(2), (E,), 0, N),
        dst=jax.random.randint(jax.random.key(3), (E,), 0, N),
        labels=jax.random.randint(jax.random.key(4), (N,), 0, C),
        label_mask=jnp.ones((N,), bool))
    loss = gnn.node_loss(params, cfg, batch, F, C)
    assert np.isfinite(float(loss))
    logits = gnn.forward(params, cfg, batch["feats"], batch["src"],
                         batch["dst"], F, C)
    assert logits.shape == (N, C) and _finite(logits)
    # graph-level (molecule) path
    gb = dict(feats=batch["feats"], src=batch["src"] % 30,
              dst=batch["dst"] % 30,
              graph_ids=jnp.repeat(jnp.arange(2), 30),
              labels=jnp.asarray([0, 1]))
    assert np.isfinite(float(gnn.graph_loss(params, cfg, gb, F, C)))


@pytest.mark.parametrize("arch_id", REC_IDS)
def test_recsys_arch_smoke(arch_id):
    from repro.models import recsys as rec
    from repro.data.pipeline import recsys_batch_factory
    arch = get_arch(arch_id)
    cfg = _reduce_recsys(arch.config)
    assert cfg.interaction == arch.config.interaction
    params = rec.init_params(jax.random.key(0), cfg)
    batch = recsys_batch_factory(cfg, 8)(np.random.default_rng(0))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    logits = rec.forward(params, cfg, batch)
    assert logits.shape == (8,) and _finite(logits)
    loss = rec.loss(params, cfg, batch)
    assert np.isfinite(float(loss))
    cand = jnp.arange(16, dtype=jnp.int32)
    scores = rec.retrieval_scores(params, cfg, batch, cand)
    assert scores.shape == (8, 16) and _finite(scores)


def test_all_40_cells_build():
    """Every (arch x shape) cell builds its specs without a mesh."""
    from repro.configs.registry import all_cells, build_cell
    cells = all_cells()
    assert len(cells) == 40
    for arch_id, shape_id in cells:
        cell = build_cell(get_arch(arch_id), shape_id)
        assert cell.meta["model_flops"] > 0
        leaves = jax.tree.leaves(cell.args)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
