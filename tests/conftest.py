import os
import sys

# Tests see ONE device (the dry-run sets its own 512-device flag in a
# separate process); keep any user XLA_FLAGS out of the suite.
os.environ.pop("XLA_FLAGS", None)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
