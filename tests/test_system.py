"""End-to-end behaviour tests of the paper's system (mini-scale)."""

import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="module")
def kgqa():
    from repro.retrieval import scorer as sc, synthetic
    data = synthetic.make_dataset("cwq", n_queries=150, n_entities=4000,
                                  seed=3)
    cfg = sc.ScorerConfig(lr=2e-3)
    params = sc.train_scorer(data, cfg, n_steps=150, seed=3)
    records = []
    for q in data.queries:
        edges, probs = sc.retrieve(params, data.kg, data.entity_emb,
                                   data.relation_emb, q, cfg)
        if len(probs) >= 10:
            gold = next((i for i, e in enumerate(edges)
                         if e in q.gold_edges), None)
            records.append((q.hops, probs, gold))
    return records


def test_skew_correlates_with_difficulty(kgqa):
    """Paper §3.2: multi-hop (difficult) queries -> lower skew."""
    from repro.core import skewness
    easy = [p for h, p, _ in kgqa if h == 1]
    hard = [p for h, p, _ in kgqa if h >= 3]
    assert len(easy) > 5 and len(hard) > 3
    area = lambda ps: np.mean([float(skewness.area_metric(
        jnp.asarray(p)[None])[0]) for p in ps])
    assert area(hard) > 1.5 * area(easy)


def test_retrieval_quality(kgqa):
    """The trained scorer puts the gold edge near the top (paper A.3.3)."""
    ranks = [g for _, _, g in kgqa if g is not None]
    assert len(ranks) / len(kgqa) > 0.8          # recall@K
    assert np.mean(ranks) < 10                    # near the head


def test_routing_beats_random_end_to_end(kgqa):
    """Paper Figs 5/6 qualitative claim at mini scale."""
    from repro.core import skewness
    hops = np.asarray([h for h, _, _ in kgqa])
    pads = np.stack([np.pad(p, (0, 100 - len(p))) for _, p, _ in kgqa])
    diff = np.asarray(skewness.difficulty_entropy(jnp.asarray(pads)))
    # synthetic quality: small fails multi-hop, large doesn't
    qs = np.where(hops == 1, 0.8, 0.35)
    ql = np.full_like(qs, 0.75)
    order = np.argsort(-diff)
    n = len(diff)
    rng = np.random.default_rng(0)
    for frac in [0.3, 0.5]:
        cut = int(frac * n)
        sel = np.zeros(n, bool)
        sel[order[:cut]] = True
        routed = np.where(sel, ql, qs).mean()
        rand = np.mean([np.where(
            np.isin(np.arange(n), rng.permutation(n)[:cut]), ql, qs).mean()
            for _ in range(20)])
        assert routed > rand, (frac, routed, rand)


def test_dispatcher_integration(kgqa):
    from repro.core import RouterConfig, calibrate_threshold
    from repro.serving.router_service import SkewRouteDispatcher
    pads = np.stack([np.pad(p, (0, 100 - len(p))) for _, p, _ in kgqa])
    theta = calibrate_threshold(jnp.asarray(pads[:60]), 0.3, "gini")
    d = SkewRouteDispatcher(RouterConfig(metric="gini", thresholds=(theta,)),
                            ["qwen7b", "qwen72b"])
    tiers = d.dispatch_batch(pads[60:])
    ratio = (tiers == 1).mean()
    assert 0.1 < ratio < 0.55
    # hot recalibration shifts the mix
    d.recalibrate(pads[:60], [0.2, 0.8])
    tiers2 = d.dispatch_batch(pads[60:])
    assert (tiers2 == 1).mean() > ratio
