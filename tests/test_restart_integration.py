"""Fault-tolerance integration: killing and restoring training mid-run
reproduces the uninterrupted loss trajectory EXACTLY (checkpoint + data
pipeline determinism together), and the serving engine generates
identical tokens across engine instances with the same weights."""

import jax
import jax.numpy as jnp
import numpy as np


def _mk(cfg_dtype=jnp.float32):
    from repro.models import transformer as T
    from repro.models.layers import LMConfig
    from repro.training import optimizer as opt_lib, train_loop
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=128, dtype=cfg_dtype,
                   loss_chunk=8)
    opt_cfg = opt_lib.OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=2,
                                      total_steps=50)
    params = T.init_params(jax.random.key(0), cfg)
    state = train_loop.init_train_state(params, opt_cfg)
    step = jax.jit(train_loop.make_train_step(
        lambda p, b: T.train_loss(p, b, cfg), opt_cfg))
    return cfg, state, step


def test_restart_reproduces_trajectory(tmp_path):
    from repro.data.pipeline import ShardedStream, lm_batch_factory
    from repro.training.checkpoint import CheckpointManager

    cfg, state, step = _mk()
    factory = lm_batch_factory(4, 16, cfg.vocab)

    # uninterrupted 8-step run
    losses_ref = []
    s = state
    stream = ShardedStream(factory, seed=7)
    for _ in range(8):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        s, m = step(s, batch)
        losses_ref.append(float(m["loss"]))

    # run 4 steps, checkpoint, "crash", restore, resume from the stream step
    mgr = CheckpointManager(tmp_path)
    s = state
    stream = ShardedStream(factory, seed=7)
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        s, m = step(s, batch)
    mgr.save(4, s)
    del s                                             # crash
    _, fresh_state, step2 = _mk()                     # new process state
    s2 = mgr.restore(fresh_state)
    assert int(np.asarray(s2["step"])) == 4
    stream2 = ShardedStream(factory, seed=7, start_step=4)
    losses_resumed = []
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in next(stream2).items()}
        s2, m = step2(s2, batch)
        losses_resumed.append(float(m["loss"]))
    np.testing.assert_allclose(losses_resumed, losses_ref[4:], rtol=1e-6)


def test_engine_generation_deterministic():
    from repro.models.layers import LMConfig
    from repro.serving.engine import LMEngine
    from repro.models import transformer as T
    cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                   head_dim=16, d_ff=64, vocab=128, dtype=jnp.float32)
    params = T.init_params(jax.random.key(3), cfg)
    prompts = np.asarray([[5, 9, 2, 7], [1, 1, 4, 8]], np.int32)
    out1 = LMEngine(cfg, params).generate(prompts, max_new=6)
    out2 = LMEngine(cfg, params).generate(prompts, max_new=6)
    assert out1.tokens.shape == (2, 6)
    np.testing.assert_array_equal(out1.tokens, out2.tokens)
    # greedy decode must match argmax of a fresh prefill for token 1
    logits, _ = T.prefill(params, jnp.asarray(np.pad(prompts, ((0, 0), (0, 12)))), cfg)
    # (engine pads to bucket 16 as well)
    np.testing.assert_array_equal(out1.tokens[:, 0],
                                  np.argmax(np.asarray(logits), -1))
