"""Transformer correctness: prefill/decode equivalence, attention paths,
flash custom-VJP gradients, training sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import flash
from repro.models import transformer as T
from repro.models.layers import LMConfig, MoEConfig, gqa_attention, causal_mask

TINY = LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                head_dim=16, d_ff=128, vocab=256, dtype=jnp.float32,
                loss_chunk=8)
TINY_MOE = LMConfig(name="tiny-moe", n_layers=2, d_model=64, n_heads=4,
                    n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
                    dtype=jnp.float32, loss_chunk=8,
                    moe=MoEConfig(n_experts=4, top_k=2, d_ff=96,
                                  shared_expert=True, capacity_factor=8.0,
                                  group_size=8))
TINY_GEMMA = LMConfig(name="tiny-gemma", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, head_dim=16, d_ff=128, vocab=256,
                      dtype=jnp.float32, loss_chunk=8, activation="geglu",
                      tie_embeddings=True, scale_embed=True)


@pytest.mark.parametrize("cfg", [TINY, TINY_MOE, TINY_GEMMA],
                         ids=["dense", "moe", "gemma"])
def test_prefill_decode_equivalence(cfg):
    params = T.init_params(jax.random.key(0), cfg)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab)
    logits_pf, cache_pf = T.prefill(params, tokens, cfg)
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    step = jax.jit(lambda c, t, i: T.decode_step(params, c, t, i, cfg))
    for i in range(S):
        logits, cache = step(cache, tokens[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(logits_pf, logits, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(cache_pf["k"], cache["k"], rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_full():
    params = T.init_params(jax.random.key(0), TINY)
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, 256)
    import dataclasses
    full = dataclasses.replace(TINY, attn_impl="full")
    chunked = dataclasses.replace(TINY, attn_impl="chunked")
    lf, _ = T.prefill(params, tokens, full)
    lc, _ = T.prefill(params, tokens, chunked)
    np.testing.assert_allclose(lf, lc, rtol=1e-4, atol=1e-4)


def test_flash_custom_vjp_matches_reference_grads():
    B, SQ, SK, H, KV, D = 2, 32, 32, 8, 4, 16
    q = jax.random.normal(jax.random.key(0), (B, SQ, H, D))
    k = jax.random.normal(jax.random.key(1), (B, SK, KV, D))
    v = jax.random.normal(jax.random.key(2), (B, SK, KV, D))

    def ref(q, k, v):
        return gqa_attention(q, k, v, causal_mask(SQ, SK))

    lf = lambda *a: jnp.sum(jnp.sin(flash.flash_attention(*a, 8)))
    lr = lambda *a: jnp.sum(jnp.sin(ref(*a)))
    gf = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("cfg", [TINY, TINY_MOE], ids=["dense", "moe"])
def test_train_loss_decreases(cfg):
    from repro.training import optimizer as opt_lib, train_loop
    params = T.init_params(jax.random.key(0), cfg)
    opt_cfg = opt_lib.OptimizerConfig(name="adamw", lr=3e-3, warmup_steps=1,
                                      total_steps=100)
    state = train_loop.init_train_state(params, opt_cfg)
    step = jax.jit(train_loop.make_train_step(
        lambda p, b: T.train_loss(p, b, cfg), opt_cfg))
    tokens = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(8):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses


def test_grad_accumulation_matches_full_batch():
    from repro.training import optimizer as opt_lib, train_loop
    cfg = TINY
    params = T.init_params(jax.random.key(0), cfg)
    opt_cfg = opt_lib.OptimizerConfig(name="sgd", lr=1e-2, b1=0.0,
                                      warmup_steps=0, schedule="constant")
    tokens = jax.random.randint(jax.random.key(1), (8, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}
    s1 = train_loop.init_train_state(params, opt_cfg)
    s2 = train_loop.init_train_state(params, opt_cfg)
    full = train_loop.make_train_step(lambda p, b: T.train_loss(p, b, cfg),
                                      opt_cfg, accum_steps=1)
    acc = train_loop.make_train_step(lambda p, b: T.train_loss(p, b, cfg),
                                     opt_cfg, accum_steps=4)
    s1, m1 = full(s1, batch)
    s2, m2 = acc(s2, batch)
    # microbatch losses average to ~the full-batch loss; params stay close
    np.testing.assert_allclose(m1["loss"], m2["loss"], rtol=5e-2)
    a = jax.tree.leaves(s1["params"])[0]
    b = jax.tree.leaves(s2["params"])[0]
    np.testing.assert_allclose(a, b, rtol=5e-2, atol=5e-3)


def test_chunked_ce_matches_full_vocab_ce():
    import dataclasses
    cfg = dataclasses.replace(TINY, loss_chunk=16)
    cfg_small_chunk = dataclasses.replace(TINY, loss_chunk=4)
    params = T.init_params(jax.random.key(0), cfg)
    tokens = jax.random.randint(jax.random.key(1), (2, 16), 0, 256)
    batch = {"tokens": tokens, "labels": tokens}
    l1 = T.train_loss(params, batch, cfg)
    l2 = T.train_loss(params, batch, cfg_small_chunk)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)
