"""Quickstart: the full SkewRoute pipeline end-to-end in one script.

Builds a small synthetic KG, trains the SubgraphRAG scorer, calibrates a
training-free router to a 40% large-tier budget, and serves queries
through two REAL (small-config) transformer tiers — everything routing-
side goes through the declarative `repro.api` surface:

    spec    = RouteSpec(...)          # the whole policy, as data
    session = build(spec, runners=...)
    session.submit(scores, prompts)   # route + micro-batch + generate

  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import RouteSpec, build
from repro.core import calibrate_threshold
from repro.models.layers import LMConfig
from repro.retrieval import scorer as sc
from repro.retrieval import synthetic
from repro.serving.engine import EngineBank, make_engine


def main():
    # 1. Knowledge graph + queries + trained retrieval scorer --------------
    print("== building synthetic KG-RAG stack ==")
    data = synthetic.make_dataset("cwq", n_queries=120, n_entities=4000)
    cfg = sc.ScorerConfig(lr=2e-3)
    params = sc.train_scorer(data, cfg, n_steps=120)

    # 2. Retrieval score distributions + training-free calibration ---------
    score_rows = []
    for q in data.queries[:80]:
        _, probs = sc.retrieve(params, data.kg, data.entity_emb,
                               data.relation_emb, q, cfg)
        score_rows.append(np.pad(probs, (0, 100 - len(probs))))
    scores = jnp.asarray(np.stack(score_rows))
    theta = calibrate_threshold(scores, target_large_ratio=0.4, metric="gini")
    print(f"calibrated gini threshold: {theta:.4f} (40% large budget)")

    # 3. The policy as one declarative, JSON-round-trippable spec ----------
    spec = RouteSpec(metric="gini", thresholds=(theta,),
                     tier_names=("qwen7b", "qwen72b"), micro_batch=4)
    assert RouteSpec.from_json(spec.to_json()) == spec  # ships as bytes

    # 4. Two real LM tiers behind the session ------------------------------
    bank = EngineBank({
        0: make_engine(LMConfig(name="small", n_layers=2, d_model=64,
                                n_heads=4, n_kv_heads=2, head_dim=16,
                                d_ff=128, vocab=512, dtype=jnp.float32)),
        1: make_engine(LMConfig(name="large", n_layers=4, d_model=128,
                                n_heads=8, n_kv_heads=4, head_dim=16,
                                d_ff=256, vocab=512, dtype=jnp.float32)),
    }, max_new=8)
    session = build(spec, runners=bank)

    # 5. Route + generate ---------------------------------------------------
    print("== serving ==")
    queries = data.queries[80:90]
    batch_scores, prompts = [], []
    for q in queries:
        _, probs = sc.retrieve(params, data.kg, data.entity_emb,
                               data.relation_emb, q, cfg)
        batch_scores.append(np.pad(probs, (0, 100 - len(probs))))
        prompts.append(np.abs(np.frombuffer(q.query_emb.tobytes(),
                                            np.uint8)[:24])
                       .astype(np.int32) % 512)
    res = session.submit(np.stack(batch_scores), prompts)
    session.flush()  # drain partial micro-batches
    for i, (q, rec) in enumerate(zip(queries, res.records)):
        print(f"q{i} hops={q.hops} difficulty={rec.difficulty:+.3f} -> "
              f"tier {rec.tier} ({session.tier_names[rec.tier]})")
    generated = sum(b.result.generated_tokens for b in session.executed)
    s = session.stats
    print(f"\nrouted {s.n_requests} requests / generated {generated} tokens; "
          f"tier mix {s.tier_counts}; large ratio {s.large_call_ratio:.2f}; "
          f"est cost ${s.total_cost:.6f}")


if __name__ == "__main__":
    main()
