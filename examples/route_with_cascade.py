"""Cascade routing end-to-end: cheap-tier-first with calibrated
escalation and per-stage cost accounting.

The default (threshold) policy BUYS exactly one tier per query: easy
queries go straight to the small model, hard queries straight to the
large one — the skew threshold decides up front. The cascade policy
instead runs EVERY query through the cheap tier and escalates only when
the routed difficulty clears its calibrated cutoff OR the engine's own
self-score says the cheap answer is shaky. That changes the bill: an
escalated query pays BOTH stages (cumulative cost), a kept query pays
only the cheap one — and the per-request escalation bill flows into the
session's cost telemetry (`session.stats.total_cost`), the admission
controller's $/query EWMA, and the snapshot envelope.

This example routes the same seeded batch under both policies and walks
through where every dollar went.

  PYTHONPATH=src python examples/route_with_cascade.py
"""

import numpy as np

from repro.api import CascadePolicySpec, RouteSpec, build


def skewed_scores(rng, n, k=100):
    """Descending retrieval-score rows with a hardness mix: ~70% peaked
    (easy — the skew metric sees a clear winner) / ~30% latently hard.
    Hard queries draw a RANGE of flatness — some look unambiguously
    hard to the skew metric, some look deceptively easy (the paper's
    correlation is strong, not perfect): exactly the queries only the
    engine's own self-score can catch."""
    hard = rng.random(n) < 0.3
    alpha = np.where(hard, rng.uniform(0.2, 2.4, n), 2.5)
    raw = rng.random((n, k)).astype(np.float32) ** alpha[:, None]
    return -np.sort(-raw, axis=1), hard


def main():
    rng = np.random.default_rng(42)
    scores, latent_hard = skewed_scores(rng, 512)
    # a (simulated) engine self-score: high = the cheap model is unsure
    self_scores = np.clip(latent_hard * 0.8
                          + rng.normal(0, 0.15, 512), 0, 1)

    base = dict(metric="entropy", thresholds=(6.1,), top_k=100,
                tier_names=("qwen7b", "qwen72b"))
    threshold = build(RouteSpec(**base))
    cascade = build(RouteSpec(**base, policy=CascadePolicySpec(
        escalation_cutoffs=(6.1,),      # difficulty above this escalates
        self_score_cutoff=0.6)))        # ... as does an unsure engine

    rt = threshold.route(scores)
    rc = cascade.route(scores, self_scores=self_scores)

    print("spec round-trip:",
          RouteSpec.from_json(cascade.spec.to_json()) == cascade.spec)

    # -- decisions ----------------------------------------------------------
    tiers_t, tiers_c = np.asarray(rt.tiers), np.asarray(rc.tiers)
    print(f"\nthreshold: {np.bincount(tiers_t, minlength=2).tolist()} "
          f"per tier (one stage each)")
    print(f"cascade:   {np.bincount(tiers_c, minlength=2).tolist()} "
          f"final tiers (every query ran the cheap stage first)")
    tel = cascade.policy.telemetry()
    print(f"escalated {tel['n_escalated']}/{tel['n_decided']} "
          f"({tel['escalation_rate']:.1%}), {tel['self_score_bumps']} of "
          f"them on the self-score alone")

    # -- the bill -----------------------------------------------------------
    cm = cascade.spec.cost_model()
    c_cheap, c_big = (cm.request_cost(m) for m in base["tier_names"])
    # threshold: one stage per query; cascade: request_cost is CUMULATIVE
    cost_t = float(np.where(tiers_t == 0, c_cheap, c_big).sum())
    cost_c = float(np.asarray(rc.request_cost).sum())
    kept = int((tiers_c == 0).sum())
    esc = int((tiers_c == 1).sum())
    print(f"\nthreshold bill: ${cost_t:.4f} "
          f"({(tiers_t == 0).sum()} x ${c_cheap:.6f} cheap-only + "
          f"{(tiers_t == 1).sum()} x ${c_big:.6f} big-only)")
    print(f"cascade bill:   ${cost_c:.4f} "
          f"({kept} x ${c_cheap:.6f} kept + "
          f"{esc} x ${c_cheap + c_big:.6f} BOTH stages)")
    assert abs(cost_c - (kept * c_cheap + esc * (c_cheap + c_big))) < 1e-9

    # the same numbers land in the session's cost telemetry
    print(f"session.stats.total_cost: threshold "
          f"${threshold.stats.total_cost:.4f}, cascade "
          f"${cascade.stats.total_cost:.4f}")
    assert abs(cascade.stats.total_cost - cost_c) < 1e-9

    # -- hard-query coverage ------------------------------------------------
    caught_t = (tiers_t[latent_hard] == 1).mean()
    caught_c = (tiers_c[latent_hard] == 1).mean()
    print(f"\nlatent-hard queries reaching the big model: "
          f"threshold {caught_t:.1%}, cascade {caught_c:.1%} "
          f"(the self-score catches hard queries whose skew looks easy)")

    # -- the policy state rides in the snapshot envelope --------------------
    snap = cascade.snapshot()
    from repro.api import SkewRouteSession
    replica = SkewRouteSession.from_snapshot(snap)
    assert replica.policy.telemetry() == cascade.policy.telemetry()
    print(f"\nsnapshot: policy_state "
          f"{sorted(snap['state']['policy_state'])} restores "
          f"escalation counters into a cold replica")


if __name__ == "__main__":
    main()
