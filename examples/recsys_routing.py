"""Beyond-paper generalization (DESIGN §5): SkewRoute for recsys ranking.

The paper routes KG-RAG queries on retrieval-score skewness; the same
math applies to ANY per-request candidate-score distribution. Here the
small DeepFM ranker scores candidate items per request; confident
requests (skewed scores — one clear winner) are served from it, while
ambiguous requests (flat scores) escalate to the large DCN-v2 ranker.

  PYTHONPATH=src python examples/recsys_routing.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import RouteSpec, build
from repro.core import calibrate_threshold
from repro.models import recsys as rec


def main():
    rng = np.random.default_rng(0)
    small_cfg = rec.RecsysConfig(
        name="deepfm-small", model="deepfm", n_dense=0, n_sparse=8,
        embed_dim=10, vocab_sizes=(2000,) * 8, deep_mlp=(64, 64),
        interaction="fm")
    small = rec.init_params(jax.random.key(0), small_cfg)

    # score 64 requests x 100 candidate items with the small ranker
    n_req, n_cand = 64, 100
    user_fields = rng.integers(0, 2000, (n_req, small_cfg.n_sparse))
    cand_ids = rng.integers(0, small_cfg.padded_vocab, (n_cand,))
    batches = {"sparse": jnp.asarray(user_fields, jnp.int32)}
    scores = rec.retrieval_scores(small, small_cfg, batches,
                                  jnp.asarray(cand_ids, jnp.int32))
    scores_desc = jnp.sort(scores, axis=1)[:, ::-1]

    theta = calibrate_threshold(scores_desc, target_large_ratio=0.3,
                                metric="entropy")
    session = build(RouteSpec(metric="entropy", thresholds=(theta,),
                              top_k=n_cand,
                              tier_names=("deepfm-small", "dcnv2-large")))
    res = session.route(np.asarray(scores_desc))
    escalate = res.tiers > 0
    print(f"requests: {n_req}; escalated to the large ranker: "
          f"{escalate.sum()} ({escalate.mean():.0%}; budget 30%)")
    ent = res.difficulty  # metric="entropy": difficulty IS score-entropy
    print(f"mean score-entropy served-small: {ent[~escalate].mean():.3f} "
          f"vs escalated: {ent[escalate].mean():.3f}")
    assert ent[escalate].mean() > ent[~escalate].mean()
    print("flat-score (ambiguous) requests escalate; confident ones stay — "
          "the paper's routing signal transfers to ranking.")


if __name__ == "__main__":
    main()
