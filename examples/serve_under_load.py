"""Serving under load: replay the canonical bursty+drift trace through a
`SkewRouteSession` with the admission controller enabled.

The trace (``repro.serving.loadgen.CANONICAL_TRACES``) throws everything
at the router at once: a 4x arrival burst, a score-skew drift that makes
every query look hard, and a large-tier replica failure. Watch the
telemetry trajectory react: the streaming calibrator re-fits thresholds
for the drift, the budget loop tightens the expensive tier's share when
$/query burns past the budget, and tier-spill engages (with hysteresis)
while the expensive pool saturates — then everything relaxes as the
burst passes.

  PYTHONPATH=src python examples/serve_under_load.py [--policy cascade]

``--policy`` swaps the routing policy (threshold | cascade |
adaptive_depth | mode_select) via the canonical per-policy spec
(`repro.serving.loadgen.canonical_policy_spec`) — same trace, same
pools, different decision economics.
"""

import argparse

from repro.serving.loadgen import canonical_load_runner, canonical_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None,
                    choices=["threshold", "cascade", "adaptive_depth",
                             "mode_select"],
                    help="routing policy (default: threshold)")
    args = ap.parse_args()
    trace = canonical_trace("bursty_drift_saturation")
    runner = canonical_load_runner(with_admission=True, trace=trace,
                                   policy=args.policy)
    session = runner.session
    if args.policy:
        print(f"routing policy: {args.policy} "
              f"({session.spec.policy.to_dict() if session.spec.policy else 'default threshold'})")
    print(f"trace {trace.name!r}: {trace.steps} steps, "
          f"burst x{trace.bursts[0].multiplier:.0f} at step "
          f"{trace.bursts[0].start}, drift at step {trace.drift[1].start}, "
          f"replica failure at step {trace.failures[0].down_at}")
    print(f"admission: budget "
          f"${session.spec.admission.cost_budget_per_query}/query, "
          f"p99 SLO {session.spec.admission.p99_slo}s\n")

    report = runner.run(trace)

    print(f"{'step':>5} {'arrv':>4} {'q0':>5} {'q1':>5} {'theta':>7} "
          f"{'top%':>5} {'spill':>5} {'$/query':>9}")
    for row in report.steps[::25]:
        print(f"{row['step']:>5} {row['arrivals']:>4} "
              f"{row['queue_depths']['0']:>5} "
              f"{row['queue_depths']['1']:>5} "
              f"{row['thresholds'][0]:>7.3f} "
              f"{row['target_shares'][1] * 100:>4.0f}% "
              f"{'ON' if row['spill_active'] else '-':>5} "
              f"{(row['cost_per_query'] or 0):>9.6f}")

    s = report.summary
    adm = s["admission"]
    print(f"\n{s['n_arrivals']} requests, {s['n_completed']} completed; "
          f"SLO attainment {s['slo_attainment']:.1%} "
          f"(p99 {s['latency_p99']:.2f}s vs {s['slo_latency']:.0f}s SLO)")
    print(f"cost ${s['cost_per_query']:.6f}/query "
          f"(budget ${session.spec.admission.cost_budget_per_query}); "
          f"executed expensive share "
          f"{s['expensive_share_executed']:.1%} "
          f"(decisions {s['expensive_share_decision']:.1%})")
    print(f"spilled {s['n_spilled']} marginal requests down-tier; "
          f"{adm['n_tighten']} tighten / {adm['n_relax']} relax actions; "
          f"{s['n_recalibrations']} threshold hot-swaps; "
          f"{s['n_redispatched']} failure re-dispatches")
    pol = s.get("policy", {})
    if pol.get("kind", "threshold") != "threshold":
        print(f"policy telemetry: {pol}")

    # the controller's whole trajectory rides in the session snapshot —
    # a replica restored from these bytes resumes mid-spill
    snap = session.snapshot()
    print(f"snapshot: {len(str(snap))} chars, admission state "
          f"{sorted(snap['state']['admission'])}")


if __name__ == "__main__":
    main()
