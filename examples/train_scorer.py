"""Train the SubgraphRAG scorer + calibrate SkewRoute + checkpoint it.

The "train ~100M model for a few hundred steps" driver of this repo is
launch/train.py (LM training on the production mesh); this example covers
the paper-specific training path: the retrieval scorer (the only trained
component SkewRoute depends on), its evaluation (answer-position metric,
paper A.3.3), threshold calibration, and checkpoint save/restore.

  PYTHONPATH=src python examples/train_scorer.py
"""

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import calibrate_multi_tier
from repro.retrieval import scorer as sc
from repro.retrieval import synthetic
from repro.training.checkpoint import CheckpointManager


def main():
    data = synthetic.make_dataset("cwq", n_queries=300, n_entities=6000)
    cfg = sc.ScorerConfig(lr=2e-3)
    print("== training scorer ==")
    params = sc.train_scorer(data, cfg, n_steps=300, log_every=100)

    # evaluation: answer position in the retrieved top-K (paper A.3.3)
    ranks, scores_rows = [], []
    for q in data.queries[:150]:
        edges, probs = sc.retrieve(params, data.kg, data.entity_emb,
                                   data.relation_emb, q, cfg)
        gold = next((i for i, e in enumerate(edges) if e in q.gold_edges), None)
        ranks.append(gold if gold is not None else len(edges))
        scores_rows.append(np.pad(probs, (0, 100 - len(probs))))
    print(f"mean answer position: {np.mean(ranks):.2f} "
          f"(hit@1 {np.mean(np.asarray(ranks) == 0):.2f})")

    # training-free 3-tier calibration (50/30/20 traffic split)
    router = calibrate_multi_tier(jnp.asarray(np.stack(scores_rows)),
                                  [0.5, 0.3, 0.2], metric="entropy")
    print(f"3-tier thresholds (entropy): {router.thresholds}")

    # checkpoint round trip
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        mgr.save(300, {"params": params, "router": list(router.thresholds)})
        restored = mgr.restore({"params": params,
                                "router": list(router.thresholds)})
        same = all(bool(jnp.allclose(a, b)) for a, b in
                   zip(jnp.ravel(params["w1_t"]),
                       jnp.ravel(restored["params"]["w1_t"]))) or True
        print(f"checkpoint saved+restored at step {mgr.latest_step()} "
              f"(weights match: {bool(jnp.allclose(params['w1_t'], restored['params']['w1_t']))})")


if __name__ == "__main__":
    main()
