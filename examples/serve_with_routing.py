"""End-to-end serving driver: dispatcher + continuous-batching scheduler +
straggler mitigation + cost telemetry under a simulated request stream.

Demonstrates the serving-side deliverables working together: SkewRoute
tier dispatch through the declarative `repro.api` session, per-tier
replica pools, a replica failure mid-stream whose in-flight requests get
re-dispatched, and the resulting cost/quality telemetry vs an all-large
baseline.

  PYTHONPATH=src python examples/serve_with_routing.py
"""

import numpy as np

from repro.api import RouteSpec, build
from repro.core import calibrate_threshold
from repro.core.cost import CostModel
from repro.retrieval import scorer as sc
from repro.retrieval import synthetic
from repro.serving.scheduler import Replica, Request, TierScheduler


def main():
    data = synthetic.make_dataset("cwq", n_queries=240, n_entities=4000)
    cfg = sc.ScorerConfig(lr=2e-3)
    params = sc.train_scorer(data, cfg, n_steps=120)

    # score distributions for calibration + traffic
    all_scores = []
    for q in data.queries:
        _, probs = sc.retrieve(params, data.kg, data.entity_emb,
                               data.relation_emb, q, cfg)
        all_scores.append(np.pad(probs, (0, 100 - len(probs))))
    all_scores = np.stack(all_scores)

    import jax.numpy as jnp
    theta = calibrate_threshold(jnp.asarray(all_scores[:100]), 0.35, "entropy")
    session = build(RouteSpec(metric="entropy", thresholds=(theta,),
                              tier_names=("qwen7b", "qwen72b")))

    # replica pools: 4 small, 2 large (cost-proportional provisioning)
    pools = {
        0: TierScheduler(0, [Replica(i, 0, speed=1.0) for i in range(4)],
                         batch_slots=8),
        1: TierScheduler(1, [Replica(i, 1, speed=0.35) for i in range(2)],
                         batch_slots=4),
    }

    now = 0.0
    for i, scores in enumerate(all_scores[100:220]):
        rec = session.route_one(scores)
        req = Request(request_id=rec.request_id, tier=rec.tier,
                      prompt_len=1873, max_new=120,
                      deadline=now + 30.0, submitted_at=now)
        pools[rec.tier].submit(req)
        if i == 60:  # inject a large-tier replica failure mid-stream
            pools[1].mark_unhealthy(0)
            print(f"t={now:.1f}s: large-tier replica 0 FAILED")
        if i == 90:
            pools[1].mark_healthy(0, speed=0.35)
            print(f"t={now:.1f}s: large-tier replica 0 recovered")
        now += 0.05
        for p in pools.values():
            p.step(now)
    # drain
    for _ in range(int(1e4)):
        now += 0.5
        if not any(p.pending or p.inflight for p in pools.values()):
            break
        for p in pools.values():
            p.step(now)

    cm = CostModel()
    stats = session.stats
    routed_cost = stats.total_cost
    all_large_cost = cm.request_cost("qwen72b") * stats.n_requests
    redispatched = sum(1 for p in pools.values() for r in p.done
                       if r.redispatched)
    print(f"\nrequests: {stats.n_requests}; tier mix: {stats.tier_counts}; "
          f"large ratio {stats.large_call_ratio:.2f}")
    print(f"re-dispatched after failure: {redispatched}")
    for t, p in pools.items():
        print(f"tier {t}: completed {len(p.done)}, p99 latency "
              f"{p.p99_latency():.2f}s")
    print(f"cost: ${routed_cost:.4f} routed vs ${all_large_cost:.4f} "
          f"all-large ({100 * (1 - routed_cost / all_large_cost):.1f}% saved)")


if __name__ == "__main__":
    main()
