"""Scaling out: a fleet of routing replicas kept consistent by the
replica-sync fabric.

Three `SkewRouteSession` replicas run behind a simulated sticky load
balancer — each step's arrivals are sorted by their top retrieval score
and split contiguously, so replica 0 only ever sees easy traffic and
replica 2 only hard. Left alone, per-replica streaming calibration
happily converges each replica onto ITS slice and the fleet's
thresholds walk apart. A `ReplicaFabric` sync round every 10 steps
exchanges delta-compressed calibrator windows and merges them with a
deterministic weighted quantile, so all replicas hold IDENTICAL
thresholds — including a cold replica that joins mid-run, bootstrapped
from a peer's snapshot state-half.

  PYTHONPATH=src python examples/serve_fleet.py
"""

import math

import numpy as np

from repro.api import CalibrationSpec, RouteSpec, build
from repro.serving import ReplicaFabric
from repro.serving.loadgen import canonical_trace, generate


def main():
    trace = canonical_trace("smoke")
    spec = RouteSpec(
        metric="entropy", thresholds=(0.8 * math.log2(trace.top_k),),
        top_k=trace.top_k, tier_names=("qwen7b", "qwen72b"),
        calibration=CalibrationSpec(policy="streaming",
                                    target_shares=(0.7, 0.3), window=512,
                                    min_samples=64, tolerance=0.08,
                                    cooldown=128))
    fab = ReplicaFabric()
    names = ["r0", "r1", "r2"]
    for name in names:
        fab.add_replica(name, build(spec))
    join_at = trace.steps // 2
    print(f"trace {trace.name!r}: {trace.steps} steps, {len(names)} "
          f"replicas on biased slices, cold join at step {join_at}, "
          f"sync every 10 steps\n")

    print(f"{'step':>5} {'merged thresholds':>32}  replicas")
    for step in generate(trace):
        if step.step == join_at:
            # a new replica joins mid-run: state half + fleet view from
            # r0, then it starts taking a slice of traffic like any peer
            fab.add_replica("cold", build(spec), bootstrap_from="r0")
            names.append("cold")
            print(f"{step.step:>5} cold replica joined "
                  f"(bootstrap_from='r0')")
        if step.n_arrivals:
            order = np.argsort(-step.scores[:, 0], kind="stable")
            for name, chunk in zip(names,
                                   np.array_split(step.scores[order],
                                                  len(names))):
                if chunk.shape[0]:
                    fab.sessions[name].route(chunk)
        if step.step % 10 == 9:
            rep = fab.sync_round()
            ths = {tuple(r["thresholds"])
                   for r in rep["replicas"].values()}
            assert len(ths) == 1, "replicas diverged after a sync round"
            print(f"{step.step:>5} {str(list(ths)[0]):>32}  "
                  f"{sorted(rep['replicas'])}")

    tel = fab.telemetry()
    print(f"\n{tel['n_rounds']} sync rounds, {tel['n_replicas']} "
          f"replicas; wire {tel['bytes_sent']}B int8 deltas vs "
          f"{tel['bytes_sent_raw']}B raw f32 "
          f"(x{tel['bytes_sent_raw'] / max(tel['bytes_sent'], 1):.1f} "
          f"compression)")
    for name, ep in sorted(tel["endpoints"].items()):
        print(f"  {name:5s}: thresholds {ep['thresholds']}, "
              f"{ep['n_merges']} merges, buffers "
              f"{ {o: v['buffered'] for o, v in ep['origins'].items()} }")


if __name__ == "__main__":
    main()
